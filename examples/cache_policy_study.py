"""Cache-eviction policy study — the paper's §6.2 open question.

Replays the same Zipfian workload against FIFO / LRU / LFU caches that are
much smaller than the topic universe, and reports hit rates.  This is a
beyond-paper extension: the paper ships append-only and explicitly defers
eviction policies.

  PYTHONPATH=src python examples/cache_policy_study.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.data import WorkloadGenerator
from repro.models.embedder import init_embedder, tiny_embedder_config, encode
from repro.tokenizer import HashWordTokenizer
from repro.training.embedder_train import train_embedder

VOCAB = 8192
THRESHOLD = 0.7


def run_policy(policy: str, embs, capacity=96):
    cfg = cache_lib.CacheConfig(capacity=capacity, dim=embs.shape[1],
                                policy=policy, topk=1,
                                max_query_tokens=4, max_response_tokens=4)
    state = cache_lib.init_cache(cfg)
    z = jnp.zeros((4,), jnp.int32)
    m = jnp.ones((4,), jnp.float32)
    lookup = jax.jit(lambda s, q: cache_lib.lookup(s, cfg, q))
    insert = jax.jit(lambda s, e: cache_lib.insert(s, cfg, e, z, m, z, m))
    hits = 0
    for i in range(embs.shape[0]):
        q = embs[i][None]
        scores, idx = lookup(state, q)
        if float(scores[0, 0]) >= THRESHOLD:
            hits += 1
            state = cache_lib.touch(state, cfg, idx[0, :1])
        else:
            state = insert(state, embs[i])
    return hits / embs.shape[0]


def main():
    tok = HashWordTokenizer(VOCAB)
    ecfg = tiny_embedder_config(VOCAB)
    eparams = init_embedder(jax.random.PRNGKey(0), ecfg)
    print("training embedder...")
    eparams, _ = train_embedder(eparams, ecfg, tok, steps=50, batch=16)
    wl = WorkloadGenerator(profile="lmsys", seed=0)
    queries = [q.text for q in wl.sample(500)]
    t, m = tok.encode_batch(queries, 32)
    embs = np.asarray(jax.jit(lambda t, m: encode(eparams, t, m, ecfg))(
        jnp.asarray(t), jnp.asarray(m)))

    print(f"workload: 500 queries, cache capacity 96, threshold {THRESHOLD}")
    for policy in ("fifo", "lru", "lfu"):
        hr = run_policy(policy, embs)
        print(f"  {policy.upper():5s} hit rate: {hr:.1%}")


if __name__ == "__main__":
    main()
