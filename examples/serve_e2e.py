"""End-to-end serving driver: a TweakLLM deployment with REAL generation.

Pretrains tiny Big/Small LMs on the synthetic corpus (big deeper than
small), trains the embedder contrastively, then replays a Zipfian arrival
trace through the continuous-batching scheduler (DESIGN.md §6) over the
full router: misses generate with the Big LM and populate the cache,
paraphrase hits run the Appendix-A tweak prompt through the Small LM,
exact repeats return verbatim, and identical in-flight requests join one
dispatch.

  PYTHONPATH=src python examples/serve_e2e.py [--queries 120]
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import CacheConfig, RouterConfig, TweakLLMEngine
from repro.data import WorkloadGenerator, token_stream_batches
from repro.models import ModelConfig, build_model
from repro.models.embedder import init_embedder, tiny_embedder_config
from repro.serving import (GenerateConfig, Generator, SamplerConfig,
                           Scheduler, SchedulerConfig, SimClock,
                           poisson_trace, replay_trace)
from repro.tokenizer import HashWordTokenizer
from repro.training import AdamWConfig, init_opt_state, make_train_step
from repro.training.embedder_train import train_embedder

VOCAB = 8192


def pretrain_lm(cfg, steps, seed, tok):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3),
                                   total_steps=steps))
    opt = init_opt_state(params)
    stream = token_stream_batches(tok, 8, 64, seed=seed)
    first = last = None
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, m = step(params, opt, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    print(f"  {cfg.name}: loss {first:.2f} -> {last:.2f} over {steps} steps")
    return model, params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    tok = HashWordTokenizer(VOCAB)
    print("pretraining Big and Small LMs on the synthetic corpus...")
    big_cfg = ModelConfig(name="big-lm", num_layers=4, d_model=128,
                          num_heads=8, num_kv_heads=4, d_ff=256,
                          vocab_size=VOCAB, max_seq_len=1024, dtype="float32")
    # fixed-block flash attention qualifies the small model for the
    # engine's shared-prefix KV reuse on TWEAK hits (DESIGN.md §9)
    small_cfg = big_cfg.replace(name="small-lm", num_layers=2, d_model=96,
                                num_heads=4, num_kv_heads=2, d_ff=192,
                                attention_impl="xla_flash",
                                flash_block_q=32, flash_block_k=32)
    big_m, big_p = pretrain_lm(big_cfg, args.steps, 1, tok)
    small_m, small_p = pretrain_lm(small_cfg, args.steps, 2, tok)

    print("training embedder contrastively...")
    ecfg = tiny_embedder_config(VOCAB)
    eparams = init_embedder(jax.random.PRNGKey(0), ecfg)
    eparams, losses = train_embedder(eparams, ecfg, tok, steps=60, batch=16)
    print(f"  InfoNCE {losses[0]:.2f} -> {losses[-1]:.2f}")

    gen_cfg = GenerateConfig(max_new_tokens=12,
                             sampler=SamplerConfig(vocab_size=VOCAB))
    eng = TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=Generator(big_m, big_p, gen_cfg),
        small=Generator(small_m, small_p, gen_cfg),
        cache_cfg=CacheConfig(capacity=1024, dim=ecfg.d_model),
        router_cfg=RouterConfig(tweak_threshold=0.7))

    wl = WorkloadGenerator(profile="lmsys", seed=0)
    texts = [q.text for q in wl.sample(args.queries)]
    trace = poisson_trace(texts, rate=100.0, seed=0)
    sched = Scheduler(
        eng, SchedulerConfig(max_wait=0.1, max_batch=args.batch,
                             max_new_tokens=12),
        clock=SimClock())
    print(f"replaying {args.queries} arrivals through the scheduler "
          f"(max_batch={args.batch})...")
    t0 = time.time()
    done = replay_trace(sched, trace)
    dt = time.time() - t0
    assert len(done) == len(texts) - sched.stats.rejected

    s, ss = eng.stats, sched.stats
    print(f"\n== serving report ==")
    print(f"requests {ss.completed} in {dt:.1f}s "
          f"({dt/max(ss.completed,1)*1e3:.0f} ms/req wall CPU)")
    print(f"scheduler: batches={ss.batches} mean_batch={ss.mean_batch:.1f} "
          f"dedup_joined={ss.joined}")
    print(f"routing: miss={s.miss} tweak={s.tweak} exact={s.exact} "
          f"(hit rate {s.hit_rate:.1%})")
    print(f"generated tokens: big={s.big_tokens} small={s.small_tokens}")
    print(f"cost: {s.cost:,.0f} vs all-big {s.baseline_cost:,.0f} "
          f"= {s.cost/max(s.baseline_cost,1):.1%} of baseline "
          f"(paper: 35% on LMSYS)")


if __name__ == "__main__":
    main()
