"""TweakLLM quickstart: the Figure-1 pipeline in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import build_engine

DECISIONS = {0: "MISS->big LLM", 1: "TWEAK->small LLM", 2: "EXACT->cache"}


def main():
    print("building TweakLLM stack (tiny models, contrastive embedder)...")
    eng = build_engine(train_embedder_steps=40, capacity=256)

    queries = [
        "how do i learn python setup",           # fresh -> MISS
        "how do i learn python setup",           # repeat -> EXACT
        "what is the best way to learn python setup",  # paraphrase -> TWEAK
        "why is keto diet bad",                  # fresh -> MISS
        "what are the downsides of keto diet",   # paraphrase
    ]
    for q in queries:
        resp, meta = eng.handle_batch([q], max_new_tokens=8, collect_meta=True)
        m = meta[0]
        print(f"  sim={m['sim']:+.3f}  {DECISIONS[m['decision']]:18s}  {q!r}")
    s = eng.stats
    print(f"\nrouting: miss={s.miss} tweak={s.tweak} exact={s.exact}")
    print(f"cost: {s.cost:.0f} vs all-big {s.baseline_cost:.0f} "
          f"({s.cost/max(s.baseline_cost,1):.0%})")


if __name__ == "__main__":
    main()
