# Repro tooling. `make test` is the tier-1 verify command from ROADMAP.md.

PYTHONPATH_PREFIX := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-sanitize test-multidevice analyze bench bench-scheduler bench-replicas bench-index bench-generate bench-prefill bench-frontier bench-speculative bench-smoke bench-baseline dev-deps lint

test:
	$(PYTHONPATH_PREFIX) python -m pytest -x -q

# multi-device CI lane (DESIGN.md §12): the distributed / replica /
# scheduler / engine suites on 8 forced host devices, so the sharded
# bank's shard_map paths run IN-PROCESS (the subprocess device scripts
# in test_distributed.py force their own device count regardless)
test-multidevice:
	$(PYTHONPATH_PREFIX) XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		python -m pytest -q tests/test_distributed.py tests/test_replicas.py \
		tests/test_scheduler.py tests/test_engine_e2e.py

# hot-path invariant analyzer (DESIGN.md §10): AST lint + registry parity,
# then jaxpr/HLO contract checks traced over the bucket sets
analyze:
	$(PYTHONPATH_PREFIX) python -m repro.analysis.lint
	$(PYTHONPATH_PREFIX) python -m repro.analysis.contracts

# tier-1 subset under runtime sanitizers: transfer_guard("disallow"),
# rank_promotion="raise", checking_leaks, debug_nans (DESIGN.md §10)
test-sanitize:
	$(PYTHONPATH_PREFIX) python -m pytest -q --sanitize \
		tests/test_sanitize.py tests/test_cache_router.py \
		tests/test_index.py tests/test_generate.py

bench:
	$(PYTHONPATH_PREFIX) python -m benchmarks.microbench

bench-scheduler:
	$(PYTHONPATH_PREFIX) python -m benchmarks.bench_scheduler

# multi-replica scaling + shared-bank hit convergence (DESIGN.md §12)
bench-replicas:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only replicas --json BENCH_replicas.json

# full IVF-vs-flat sweep; emits the repo-standard trajectory file
bench-index:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only index --json BENCH_index.json

# fused-vs-host decode loop sweep; emits the repo-standard trajectory file
bench-generate:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only generate --json BENCH_generate.json

# prefix-KV-reuse + suffix-bucketed vs full-bucket tweak prefill sweep
bench-prefill:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only prefill --json BENCH_prefill.json

# router cost-quality frontier: single-stage vs cascade operating points
# (DESIGN.md §13); emits the repo-standard trajectory file
bench-frontier:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only frontier --json BENCH_frontier.json

# cached-response draft-verify vs plain fused decode, swept over draft
# overlap x batch x spec_k, plus TWEAK-stream acceptance (DESIGN.md §14)
bench-speculative:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only speculative --json BENCH_speculative.json

# the CI perf gate, runnable locally: scaled-down suites + regression check
bench-smoke:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --smoke --json BENCH_ci.json
	$(PYTHONPATH_PREFIX) python -m benchmarks.check_regression BENCH_ci.json BENCH_baseline.json

# refresh the checked-in gate baseline (commit the result with the PR
# that legitimately moves a gated metric)
bench-baseline:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --smoke --json BENCH_baseline.json

lint:
	ruff check .

dev-deps:
	pip install -r requirements-dev.txt
