# Repro tooling. `make test` is the tier-1 verify command from ROADMAP.md.

PYTHONPATH_PREFIX := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench bench-scheduler dev-deps

test:
	$(PYTHONPATH_PREFIX) python -m pytest -x -q

bench:
	$(PYTHONPATH_PREFIX) python -m benchmarks.microbench

bench-scheduler:
	$(PYTHONPATH_PREFIX) python -m benchmarks.bench_scheduler

dev-deps:
	pip install -r requirements-dev.txt
