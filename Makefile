# Repro tooling. `make test` is the tier-1 verify command from ROADMAP.md.

PYTHONPATH_PREFIX := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench bench-scheduler bench-index bench-generate bench-prefill bench-smoke bench-baseline dev-deps lint

test:
	$(PYTHONPATH_PREFIX) python -m pytest -x -q

bench:
	$(PYTHONPATH_PREFIX) python -m benchmarks.microbench

bench-scheduler:
	$(PYTHONPATH_PREFIX) python -m benchmarks.bench_scheduler

# full IVF-vs-flat sweep; emits the repo-standard trajectory file
bench-index:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only index --json BENCH_index.json

# fused-vs-host decode loop sweep; emits the repo-standard trajectory file
bench-generate:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only generate --json BENCH_generate.json

# prefix-KV-reuse + suffix-bucketed vs full-bucket tweak prefill sweep
bench-prefill:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --only prefill --json BENCH_prefill.json

# the CI perf gate, runnable locally: scaled-down suites + regression check
bench-smoke:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --smoke --json BENCH_ci.json
	$(PYTHONPATH_PREFIX) python -m benchmarks.check_regression BENCH_ci.json BENCH_baseline.json

# refresh the checked-in gate baseline (commit the result with the PR
# that legitimately moves a gated metric)
bench-baseline:
	$(PYTHONPATH_PREFIX) python -m benchmarks.run --smoke --json BENCH_baseline.json

lint:
	ruff check .

dev-deps:
	pip install -r requirements-dev.txt
