"""Speculative decode differential tests (DESIGN.md §14).

The contract under test: greedy speculative decode is **token-for-token
and length-for-length identical** to the plain fused loop — for ANY
draft content.  The draft only changes how many forwards it takes to
produce the stream, never the stream itself, because every accepted
token is one the plain loop would have emitted (verified greedy argmax)
and every rejected cache position is rewound before it can influence a
later step.

Layers covered here:
* ``Generator.generate_with_lengths(..., drafts=)`` — spec vs plain
  fused vs host-stepped oracle, dense AND paged, across draft-overlap
  patterns and k ∈ {1, 2, 4, 8} (seeded deterministic sweep + a
  hypothesis property when hypothesis is installed).
* ``DecodeSession(spec_k=...)`` — mid-flight join/leave churn with
  per-slot drafts matches the plain session token-for-token, and the
  page pool returns to zero leaked pages.
* Config/call-path validation (satellite 2) and the sampler's explicit
  greedy tie-break (satellite 1) that the whole §14 contract rests on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from repro.models import ModelConfig, build_model
from repro.serving import GenerateConfig, Generator, SamplerConfig
from repro.serving.continuous import DecodeSession, leaked_pages
from repro.serving.sampler import SamplerConfig as SC
from repro.serving.sampler import greedy_ids, sample

VOCAB = 128
EOS = 2
MNT = 8


@pytest.fixture(scope="module")
def model_and_params():
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
                      d_ff=64, vocab_size=VOCAB, max_seq_len=256,
                      dtype="float32", attention_impl="xla_flash",
                      flash_block_q=16, flash_block_k=16)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _gen(model_and_params, *, spec_k=1, paged=False, page_size=4,
         mnt=MNT, temp=0.0, fused=True):
    model, params = model_and_params
    gc = GenerateConfig(
        max_new_tokens=mnt, eos_id=EOS,
        sampler=SamplerConfig(temperature=temp, vocab_size=VOCAB),
        paged=paged, page_size=page_size, spec_k=spec_k, fused=fused)
    return Generator(model, params, gc)


def _prompts(batch, s, seed=0):
    rng = np.random.default_rng(seed)
    return np.asarray(rng.integers(3, VOCAB, size=(batch, s)), np.int32)


def _triple(gen, toks, **kw):
    t, l, e = gen.generate_with_lengths({"tokens": jnp.asarray(toks)}, **kw)
    return np.asarray(t), np.asarray(l), np.asarray(e)


PATTERNS = ("perfect", "zero", "diverge", "short", "empty", "mixed")


def _drafts(ref_toks, pattern, rng):
    """Build a (ids, lens) draft pair with a given agreement pattern
    against the plain loop's reference output."""
    b, w = ref_toks.shape
    ids = np.zeros((b, w), np.int32)
    lens = np.zeros((b,), np.int32)
    garbage = rng.integers(3, VOCAB, size=(b, w)).astype(np.int32)
    if pattern == "perfect":
        ids[:], lens[:] = ref_toks, w
    elif pattern == "zero":
        # force disagreement at every position (mod-vocab shift keeps
        # ids in range and never equal to the reference)
        ids[:] = (ref_toks + 1 - 3) % (VOCAB - 3) + 3
        lens[:] = w
    elif pattern == "diverge":
        ids[:] = ref_toks
        ids[:, w // 2:] = garbage[:, w // 2:]
        lens[:] = w
    elif pattern == "short":
        ids[:, :3], lens[:] = ref_toks[:, :3], 3
    elif pattern == "empty":
        pass
    elif pattern == "mixed":
        # one row of each flavour, cycling over the batch
        for r in range(b):
            ids[r], lens[r] = ref_toks[r], w
            if r % 4 == 1:
                ids[r] = (ref_toks[r] + 1 - 3) % (VOCAB - 3) + 3
            elif r % 4 == 2:
                ids[r, w // 2:] = garbage[r, w // 2:]
            elif r % 4 == 3:
                lens[r] = 2
    return ids, lens


# -------------------------------------------- spec == plain == oracle
@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("k", [2, 4, 8])
def test_spec_matches_plain_and_oracle(model_and_params, paged, k):
    plain = _gen(model_and_params, paged=paged)
    toks = _prompts(3, 6, seed=k)
    ref = _triple(plain, toks, seed=0)
    oracle = _triple(plain, toks, seed=0, fused=False)
    for a, b in zip(ref, oracle):
        np.testing.assert_array_equal(a, b)
    spec = _gen(model_and_params, spec_k=k, paged=paged)
    rng = np.random.default_rng(100 + k)
    for pattern in PATTERNS:
        out = _triple(spec, toks, seed=0,
                      drafts=_drafts(ref[0], pattern, rng))
        for a, b in zip(ref, out):
            np.testing.assert_array_equal(a, b, err_msg=pattern)


@pytest.mark.parametrize("page_size", [1, 4, 16])
def test_spec_paged_page_sizes(model_and_params, page_size):
    plain = _gen(model_and_params, paged=True, page_size=page_size)
    toks = _prompts(2, 5, seed=page_size)
    ref = _triple(plain, toks, seed=0)
    spec = _gen(model_and_params, spec_k=4, paged=True, page_size=page_size)
    rng = np.random.default_rng(page_size)
    for pattern in ("perfect", "diverge", "mixed"):
        out = _triple(spec, toks, seed=0,
                      drafts=_drafts(ref[0], pattern, rng))
        for a, b in zip(ref, out):
            np.testing.assert_array_equal(a, b, err_msg=pattern)


def test_spec_k1_block_loop_matches_plain(model_and_params):
    """k=1 degenerates to the block-form plain loop — still identical."""
    plain = _gen(model_and_params)
    toks = _prompts(2, 4)
    ref = _triple(plain, toks, seed=0)
    spec = _gen(model_and_params, spec_k=1)
    out = _triple(spec, toks, seed=0,
                  drafts=(np.zeros((2, 1), np.int32),
                          np.zeros((2,), np.int32)))
    for a, b in zip(ref, out):
        np.testing.assert_array_equal(a, b)


def test_spec_counters_account_perfect_draft(model_and_params):
    """A perfect draft is fully accepted; counters reflect it."""
    plain = _gen(model_and_params)
    toks = _prompts(2, 5, seed=9)
    ref = _triple(plain, toks, seed=0)
    spec = _gen(model_and_params, spec_k=4)
    _triple(spec, toks, seed=0, drafts=(ref[0], np.full((2,), MNT, np.int32)))
    st_ = spec.last_spec_stats
    assert st_["proposed"] > 0
    assert st_["accepted"] == st_["proposed"]   # lossless + perfect draft
    assert st_["spec_steps"] > 0
    # a non-matching draft proposes but accepts nothing
    bad = (ref[0] + 1 - 3) % (VOCAB - 3) + 3
    _triple(spec, toks, seed=0, drafts=(bad, np.full((2,), MNT, np.int32)))
    assert spec.last_spec_stats["accepted"] == 0
    assert spec.spec_stats["proposed"] >= st_["proposed"]  # cumulative


# ------------------------------------------------- hypothesis property
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.sampled_from([1, 2, 4, 8]),
       st.booleans())
def test_spec_identity_random_agreement(model_and_params, seed, k, paged):
    """Random per-row agreement prefixes never change the stream."""
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 4))
    toks = _prompts(b, int(rng.integers(3, 8)), seed=seed % 1000)
    plain = _gen(model_and_params, paged=paged)
    ref = _triple(plain, toks, seed=0)
    ids = rng.integers(3, VOCAB, size=(b, MNT)).astype(np.int32)
    lens = rng.integers(0, MNT + 1, size=(b,)).astype(np.int32)
    for r in range(b):
        agree = int(rng.integers(0, MNT + 1))
        ids[r, :agree] = ref[0][r, :agree]
    spec = _gen(model_and_params, spec_k=k, paged=paged)
    out = _triple(spec, toks, seed=0, drafts=(ids, lens))
    for a, c in zip(ref, out):
        np.testing.assert_array_equal(a, c)


# ------------------------------------------------ DecodeSession churn
def test_session_spec_matches_plain_with_churn(model_and_params):
    """Mid-flight joins with per-slot drafts: spec session ≡ plain
    session token-for-token, and no page leaks after full drain."""
    model, params = model_and_params
    cap = 6 + MNT + 1
    mk = lambda: _gen(model_and_params, paged=True)

    def run(spec_k, drafts1=None, drafts2=None):
        gen = mk()
        sess = DecodeSession(gen, slots=3, capacity=cap, seed=7,
                             spec_k=spec_k)
        kw1 = {"drafts": drafts1} if drafts1 is not None else {}
        sess.admit(_prompts(2, 6, seed=1), tags=["a", "b"], slots=[0, 1],
                   **kw1)
        sess.run_chunk(2)
        kw2 = {"drafts": drafts2} if drafts2 is not None else {}
        sess.admit(_prompts(1, 6, seed=2), tags=["c"], slots=[2], **kw2)
        fin = {r["tag"]: r for r in sess.drain(chunk=3)}
        leak = sess.pool.live_pages - sess.pool.pinned_pages
        return fin, leak, leaked_pages(gen), sess

    ref, leak0, gleak0, _ = run(1)
    # drafts: row a gets its true continuation, row b garbage, c (mid-
    # flight join) its true continuation — joins speculate too.
    rng = np.random.default_rng(3)
    d1 = (np.stack([ref["a"]["tokens"],
                    rng.integers(3, VOCAB, size=(MNT,)).astype(np.int32)]),
          np.asarray([MNT, MNT], np.int32))
    d2 = (ref["c"]["tokens"][None, :], np.asarray([MNT], np.int32))
    out, leak1, gleak1, sess = run(4, d1, d2)
    for tag in ("a", "b", "c"):
        for key in ("tokens", "length", "ended"):
            np.testing.assert_array_equal(ref[tag][key], out[tag][key],
                                          err_msg=f"{tag}/{key}")
    assert leak0 == leak1 == gleak0 == gleak1 == 0
    stats = sess.spec_stats
    assert stats["proposed"] >= stats["accepted"] >= 0


def test_session_spec_stats_and_draftless_rows(model_and_params):
    """Rows admitted without drafts decode plainly inside a spec session."""
    gen = _gen(model_and_params, paged=True)
    sess = DecodeSession(gen, slots=2, capacity=6 + MNT + 1, spec_k=2)
    sess.admit(_prompts(2, 6), tags=["x", "y"])
    fin = sess.drain()
    assert {r["tag"] for r in fin} == {"x", "y"}
    assert sess.spec_stats == {"proposed": 0, "accepted": 0, "spec_steps": 0}
    assert sess.pool.live_pages - sess.pool.pinned_pages == 0


# ------------------------------------------------- validation (sat. 2)
def test_generate_config_rejects_incoherent_spec():
    with pytest.raises(ValueError, match="spec_k"):
        GenerateConfig(spec_k=0)
    with pytest.raises(ValueError, match="max_new_tokens"):
        GenerateConfig(max_new_tokens=4, spec_k=8)
    with pytest.raises(ValueError, match="greedy|temperature"):
        GenerateConfig(spec_k=2, sampler=SamplerConfig(temperature=0.7))


def test_generator_rejects_unsupported_arch():
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
                      d_ff=64, vocab_size=VOCAB, max_seq_len=128,
                      dtype="float32", sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert not model.supports_spec_decode
    with pytest.raises(ValueError, match="spec"):
        Generator(model, params,
                  GenerateConfig(max_new_tokens=MNT, spec_k=2,
                                 sampler=SamplerConfig(vocab_size=VOCAB)))


def test_drafts_call_path_validation(model_and_params):
    toks = _prompts(1, 4)
    d = (np.zeros((1, 2), np.int32), np.zeros((1,), np.int32))
    gen = _gen(model_and_params, spec_k=2)
    with pytest.raises(ValueError, match="fused"):
        gen.generate_with_lengths({"tokens": jnp.asarray(toks)},
                                  drafts=d, fused=False)
    with pytest.raises(ValueError, match="spec_k|budget|max_new"):
        gen.generate_with_lengths({"tokens": jnp.asarray(toks)},
                                  drafts=d, max_new_tokens=1)
    hot = _gen(model_and_params, temp=0.8)
    with pytest.raises(ValueError, match="greedy|temperature"):
        hot.generate_with_lengths({"tokens": jnp.asarray(toks)}, drafts=d)


def test_session_spec_validation(model_and_params):
    gen = _gen(model_and_params, paged=True)
    with pytest.raises(ValueError, match="spec_k"):
        DecodeSession(gen, slots=2, capacity=32, spec_k=0)
    with pytest.raises(ValueError, match="greedy|temperature"):
        DecodeSession(_gen(model_and_params, paged=True, temp=0.5),
                      slots=2, capacity=32, spec_k=2)
    sess = DecodeSession(gen, slots=2, capacity=6 + MNT + 1)
    with pytest.raises(ValueError, match="drafts"):
        sess.admit(_prompts(1, 6),
                   drafts=(np.zeros((1, 2), np.int32),
                           np.ones((1,), np.int32)))


# ------------------------------------------------- tie-break (sat. 1)
def test_greedy_tiebreak_lowest_id_wins():
    logits = np.full((2, 7), -1.0, np.float32)
    logits[0, [2, 5]] = 3.0           # tie between ids 2 and 5
    logits[1, [0, 3, 6]] = 1.5        # three-way tie
    ids = np.asarray(greedy_ids(jnp.asarray(logits)))
    np.testing.assert_array_equal(ids, [2, 0])
    # block-shaped logits (B, k, V) — the verify loop's shape
    blk = np.broadcast_to(logits[:, None, :], (2, 3, 7)).copy()
    np.testing.assert_array_equal(np.asarray(greedy_ids(jnp.asarray(blk))),
                                  [[2, 2, 2], [0, 0, 0]])
    # sample() at temperature 0 routes through the same tie-break
    got = np.asarray(sample(jax.random.PRNGKey(0), jnp.asarray(logits),
                            SC(temperature=0.0)))
    np.testing.assert_array_equal(got, ids)
