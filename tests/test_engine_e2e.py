"""End-to-end TweakLLM behaviour tests (paper Figure-1 pipeline)."""
import jax
import numpy as np
import pytest

from repro.core import (CacheConfig, RouterConfig, TweakLLMEngine, router)
from repro.core.baseline import BaselineConfig, GPTCacheBaseline
from repro.models import ModelConfig, build_model
from repro.models.embedder import init_embedder, tiny_embedder_config
from repro.models.reranker import init_reranker, tiny_reranker_config
from repro.serving import GenerateConfig, Generator, SamplerConfig
from repro.tokenizer import HashWordTokenizer

VOCAB = 4096


@pytest.fixture(scope="module")
def stack():
    tok = HashWordTokenizer(VOCAB)
    ecfg = tiny_embedder_config(VOCAB)
    eparams = init_embedder(jax.random.PRNGKey(0), ecfg)
    lm = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                     d_ff=128, vocab_size=VOCAB, max_seq_len=512,
                     dtype="float32")
    gc = GenerateConfig(max_new_tokens=6, sampler=SamplerConfig(vocab_size=VOCAB))
    big_m = build_model(lm)
    small_m = build_model(lm.replace(num_layers=1))
    big = Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gc)
    small = Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gc)
    return tok, ecfg, eparams, big, small


def _engine(stack, **router_kw):
    tok, ecfg, eparams, big, small = stack
    return TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=64, dim=ecfg.d_model, topk=4),
        router_cfg=RouterConfig(**router_kw))


def test_miss_then_exact_hit(stack):
    eng = _engine(stack)
    r1 = eng.handle_batch(["how do i learn python setup"], max_new_tokens=4)
    assert eng.stats.miss == 1 and eng.stats.exact == 0
    assert isinstance(r1[0], str) and len(r1[0]) > 0
    r2, meta = eng.handle_batch(["how do i learn python setup"],
                                max_new_tokens=4, collect_meta=True)
    assert eng.stats.exact == 1
    assert meta[0]["decision"] == router.EXACT
    assert meta[0]["sim"] > 0.999


def test_tweak_path_uses_small_llm(stack):
    eng = _engine(stack, tweak_threshold=0.3)  # aggressive for tiny embedder
    eng.handle_batch(["why is keto diet good"], max_new_tokens=4)
    _, meta = eng.handle_batch(["what makes keto diet worthwhile"],
                               max_new_tokens=4, collect_meta=True)
    assert meta[0]["decision"] in (router.TWEAK, router.EXACT)
    assert eng.stats.tweak >= 1 or eng.stats.exact >= 1
    assert eng.stats.small_tokens > 0 or eng.stats.exact >= 1


def test_cost_accounting(stack):
    eng = _engine(stack)
    eng.handle_batch(["a unique question about rust installation"],
                     max_new_tokens=4)
    eng.handle_batch(["a unique question about rust installation"],
                     max_new_tokens=4)
    s = eng.stats
    assert s.total == 2
    assert s.cost < s.baseline_cost or s.exact > 0
    assert 0.0 <= s.hit_rate <= 1.0


def test_batch_routing_split(stack):
    """A mixed batch must route per-request, not per-batch."""
    eng = _engine(stack)
    eng.handle_batch(["how do i learn guitar practice"], max_new_tokens=4)
    rs, meta = eng.handle_batch(
        ["how do i learn guitar practice",   # exact repeat
         "what is the price of solar installation"],  # fresh
        max_new_tokens=4, collect_meta=True)
    assert meta[0]["decision"] == router.EXACT
    assert meta[1]["decision"] == router.MISS
    assert all(isinstance(r, str) for r in rs)


def test_exact_hit_updates_eviction_bookkeeping(stack):
    """EXACT hits must touch last_used/hits (the seed dropped them, so
    LRU/LFU evicted the hottest entries)."""
    eng = _engine(stack)
    eng.handle_batch(["how do i learn piano chords"], max_new_tokens=4)
    hits_before = np.asarray(eng.state["hits"]).copy()
    _, meta = eng.handle_batch(["how do i learn piano chords"],
                               max_new_tokens=4, collect_meta=True)
    assert meta[0]["decision"] == router.EXACT
    hits_after = np.asarray(eng.state["hits"])
    assert hits_after.sum() == hits_before.sum() + 1
    slot = int(np.argmax(hits_after - hits_before))
    assert int(eng.state["last_used"][slot]) == int(eng.state["clock"]) - 1


def test_token_accounting_counts_real_tokens(stack):
    """big/small_tokens must count EOS-stripped generated tokens, not the
    padded bucket length, and decoded responses must stop at EOS."""
    eng = _engine(stack)
    rs = eng.handle_batch(["a question about quantum computing basics"],
                          max_new_tokens=8)
    assert 1 <= eng.stats.big_tokens <= 8
    assert "<eos>" not in rs[0]
    # cached copy must carry a mask covering only the stored tokens
    rm = np.asarray(eng.state["r_mask"])
    row = int(np.asarray(eng.state["valid"]).nonzero()[0][0])
    assert rm[row].sum() <= 8


def test_populate_batched(stack):
    eng = _engine(stack)
    qs = [f"unique population question number {i}" for i in range(5)]
    eng.populate(qs, [f"answer {i}" for i in range(5)])
    assert int(eng.state["size"]) == 5
    r, meta = eng.handle_batch([qs[3]], max_new_tokens=4, collect_meta=True)
    assert meta[0]["decision"] == router.EXACT
    assert r[0] == "answer 3"


def test_empty_batch_is_a_noop(stack):
    """handle_batch([]) / populate([], []) must not crash (regression:
    the seed padded/embedded an n=0 batch)."""
    eng = _engine(stack)
    assert eng.handle_batch([]) == []
    rs, meta = eng.handle_batch([], collect_meta=True)
    assert rs == [] and meta == []
    eng.populate([], [])
    assert eng.stats.total == 0
    assert int(eng.state["size"]) == 0
    # engine still works after the no-ops
    assert len(eng.handle_batch(["a real query after empties"],
                                max_new_tokens=4)) == 1


def test_populate_length_mismatch_raises(stack):
    eng = _engine(stack)
    with pytest.raises(ValueError, match="populate"):
        eng.populate(["one query"], [])


def test_tweak_rejects_oversized_max_new_tokens(stack):
    """Regression: max_new_tokens + 1 >= small max_seq_len used to send a
    non-positive encode length into the tokenizer."""
    eng = _engine(stack, tweak_threshold=-1.0)   # force the TWEAK path
    eng.populate(["a seeded question about sailing"], ["a cached answer"])
    msl = eng.small.model.cfg.max_seq_len
    stats_before = (eng.stats.total, eng.stats.exact, eng.stats.tweak)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.handle_batch(["anything routes to tweak now"],
                         max_new_tokens=msl + 88)
    with pytest.raises(ValueError, match="max_new_tokens"):
        # positive budget, but even the smallest length bucket overflows
        eng.handle_batch(["still routes to tweak"], max_new_tokens=msl - 12)
    # validation happens BEFORE lookup/serve: nothing was billed
    assert (eng.stats.total, eng.stats.exact, eng.stats.tweak) == stats_before


def test_tweak_encode_len_clamps_to_fitting_bucket(stack):
    eng = _engine(stack)
    msl = eng.small.model.cfg.max_seq_len          # 512 in this stack
    # naive budget 507 would bucket-round to 512 and overflow; clamp picks
    # the largest bucket that still fits alongside generation
    clamped = eng._tweak_encode_len(4)
    assert clamped + 4 + 1 <= msl
    from repro.serving.batcher import bucket_len
    assert bucket_len(clamped) == clamped          # a true bucket: no re-round


def test_handle_batch_result_metadata(stack):
    eng = _engine(stack)
    res = eng.handle_batch_result(
        ["metadata question alpha", "metadata question alpha"],
        max_new_tokens=4)
    assert len(res.responses) == 2 and len(res.meta) == 2
    assert {m["decision"] for m in res.meta} <= {router.MISS, router.TWEAK,
                                                 router.EXACT}
    assert all(set(m) == {"sim", "decision", "band", "gen_tokens",
                          "cost", "stage2"}
               for m in res.meta)
    # single-stage engine at the default operating point: every row is
    # routed at the configured default cost and never hits stage 2
    assert all(m["cost"] == eng.router_cfg.default_cost for m in res.meta)
    assert not any(m["stage2"] for m in res.meta)
    assert res.big_tokens + res.small_tokens == \
        sum(m["gen_tokens"] for m in res.meta)
    assert res.big_tokens == eng.stats.big_tokens


def test_engine_max_new_tokens_zero_bills_nothing(stack):
    """Regression: an explicit max_new_tokens=0 used to fall back to the
    config default (32 tokens generated and billed)."""
    eng = _engine(stack)
    rs = eng.handle_batch(["a question served with a zero token budget"],
                          max_new_tokens=0)
    assert rs == [""]
    assert eng.stats.big_tokens == 0 and eng.stats.small_tokens == 0
    assert eng.stats.miss == 1


class _SeedSpy:
    """Wraps a Generator, recording the seed threaded into each call."""

    def __init__(self, inner):
        self._inner = inner
        self.model = inner.model
        self.seeds = []

    def generate_with_lengths(self, batch, *, seed=None, **kw):
        self.seeds.append(seed)
        return self._inner.generate_with_lengths(batch, seed=seed, **kw)


def test_per_batch_seed_threading(stack):
    """Regression: every generate call defaulted to seed=0, so stochastic
    serve batches all sampled from identical key streams.  The engine now
    threads a distinct counter-derived seed into every Big/Small call."""
    eng = _engine(stack)
    eng.big = big_spy = _SeedSpy(eng.big)
    eng.small = small_spy = _SeedSpy(eng.small)
    eng.handle_batch(["seed stream question about tides"], max_new_tokens=4)
    eng.handle_batch(["completely different topic entirely volcano lava"],
                     max_new_tokens=4)
    seeds = big_spy.seeds + small_spy.seeds
    assert len(seeds) == 2
    assert None not in seeds
    assert seeds[0] != seeds[1]


def test_tweak_prompt_survives_text_store_miss(stack, monkeypatch):
    """Regression: a slot live in the device cache but absent from the host
    text mirror built the Appendix-A tweak prompt from empty strings.  The
    engine must fall back to decoding the cached tokens."""
    from repro.core import tweak as tweak_lib
    eng = _engine(stack, tweak_threshold=-1.0)   # force the TWEAK path
    eng.populate(["a seeded question about gardening"], ["a cached answer"])
    slot = int(np.asarray(eng.state["valid"]).nonzero()[0][0])
    cached_resp = eng._decode_cached(slot)
    assert cached_resp                       # the device cache has the text
    eng._text_store.clear()                  # simulate restored checkpoint
    captured = []
    # Every prompt-assembly path (text oracle, full-token, prefix-suffix)
    # derives from tweak_segments — the one seam that sees the field values.
    real_build = tweak_lib.tweak_segments
    monkeypatch.setattr(tweak_lib, "tweak_segments",
                        lambda q, cq, cr: captured.append((q, cq, cr))
                        or real_build(q, cq, cr))
    rs, meta = eng.handle_batch(["an unrelated question about sailing"],
                                max_new_tokens=4, collect_meta=True)
    assert meta[0]["decision"] == router.TWEAK
    (q, cq, cr), = captured
    assert cr == cached_resp                 # cached response, not ""
    assert cq != ""                          # cached query decoded too
    assert isinstance(rs[0], str) and rs[0]


def test_gptcache_baseline_verbatim(stack):
    tok, ecfg, eparams, big, small = stack
    rcfg = tiny_reranker_config(VOCAB)
    rparams = init_reranker(jax.random.PRNGKey(5), rcfg)
    bl = GPTCacheBaseline(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        reranker_params=rparams, reranker_cfg=rcfg,
        cache_cfg=CacheConfig(capacity=32, dim=ecfg.d_model, topk=4),
        cfg=BaselineConfig(similarity_threshold=0.7))
    bl.put("how do i learn chess strategy", "practice endgames daily")
    cq, cr, score = bl.get("how do i learn chess strategy")
    assert cr == "practice endgames daily"   # verbatim, no tweak
    assert score > 0.999
    cq2, cr2, s2 = bl.get("completely unrelated mortgage question")
    assert cr2 is None


def test_engine_band_zero_decisions_match_legacy_route(stack):
    """Byte-identity satellite: at band=0 + default calibration, the full
    handle_batch path makes exactly the legacy per-score decisions and
    never enters stage 2."""
    import jax.numpy as jnp
    eng = _engine(stack)
    assert not eng.bank.cascading
    eng.handle_batch(["identity question one", "identity question two"],
                     max_new_tokens=4)
    res = eng.handle_batch_result(
        ["identity question one", "identity question two",
         "identity question one", "a brand new identity question"],
        max_new_tokens=4)
    for m in res.meta:
        want = int(router.route(jnp.asarray([m["sim"]], jnp.float32),
                                eng.router_cfg)[0])
        assert m["decision"] == want
        assert not m["stage2"]
    assert eng.stats.uncertain == 0


def test_engine_band_without_reranker_rejected(stack):
    with pytest.raises(ValueError, match="reranker"):
        _engine(stack, band=0.2)


def test_engine_cascade_resolves_uncertain_rows(stack):
    """band > 0 + reranker: uncertain rows cross stage 2 and come back
    with a terminal decision; the serve path still completes."""
    tok, ecfg, eparams, big, small = stack
    rr_cfg = tiny_reranker_config(VOCAB)
    rr_params = init_reranker(jax.random.PRNGKey(9), rr_cfg)
    eng = TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=64, dim=ecfg.d_model, topk=4),
        # a band wide enough that every non-EXACT score is uncertain:
        # stage 2 must fire and resolve on this batch deterministically
        router_cfg=RouterConfig(tweak_threshold=0.5, band=2.0),
        reranker=(rr_params, rr_cfg))
    assert eng.bank.cascading
    eng.handle_batch(["how to cook pasta sauce quickly"], max_new_tokens=4)
    res = eng.handle_batch_result(
        ["how to cook a pasta sauce fast", "unrelated zebra migration"],
        max_new_tokens=4)
    assert eng.stats.uncertain >= 1
    assert any(m["stage2"] for m in res.meta)
    assert all(m["decision"] in (router.MISS, router.TWEAK, router.EXACT)
               for m in res.meta)
    assert all(isinstance(r, str) and r != "" for r in res.responses)


def test_engine_cost_threshold_moves_operating_point(stack):
    """The per-request cost threshold selects the operating point: cost=1
    pins tau at 1.0 (nothing short of exact hits), cost=0 relaxes it, and
    decisions stay monotone across operating points."""
    seed_q = "the capital city of france is paris"
    probe = ["the capital town of france is paris"]
    res = {}
    for c in (0.0, 1.0):
        eng = _engine(stack)            # fresh bank per operating point
        eng.handle_batch([seed_q], max_new_tokens=4)
        r = eng.handle_batch_result(probe, max_new_tokens=4,
                                    cost_thresholds=c)
        res[c] = r.meta[0]
    assert res[0.0]["cost"] == 0.0 and res[1.0]["cost"] == 1.0
    assert res[0.0]["sim"] == res[1.0]["sim"]   # same state, same embedder
    if res[1.0]["sim"] < RouterConfig().exact_threshold:
        assert res[1.0]["decision"] == router.MISS
    hit = lambda d: d != router.MISS
    assert hit(res[0.0]["decision"]) or not hit(res[1.0]["decision"])


# ------------------------------------------- speculative TWEAK drafts (§14)
def test_tweak_speculative_drafts_match_plain(stack):
    """A spec-enabled small generator serves byte-identical TWEAK
    responses, threads cached-response drafts into the verify loop, and
    bills the speculation counters into EngineStats (DESIGN.md §14)."""
    from repro.core.engine import EngineStats

    tok, ecfg, eparams, big, small = stack
    small_spec = Generator(
        small.model, small.params,
        GenerateConfig(max_new_tokens=6,
                       sampler=SamplerConfig(vocab_size=VOCAB), spec_k=3))
    assert small_spec.speculation_ready

    def mk(s):
        return TweakLLMEngine(
            tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
            big=big, small=s,
            cache_cfg=CacheConfig(capacity=64, dim=ecfg.d_model, topk=4),
            router_cfg=RouterConfig(tweak_threshold=0.3))

    e_plain, e_spec = mk(small), mk(small_spec)
    seen, outs = [], []
    orig = small_spec.generate_with_lengths

    def spy(batch, **kw):
        out = orig(batch, **kw)
        seen.append(kw.get("drafts") is not None)
        outs.append(out)
        return out

    small_spec.generate_with_lengths = spy
    seeds = ["how do i learn python setup", "best way to cook rice fast"]
    probes = ["how do i learn python install", "best way to cook rice quickly"]
    for e in (e_plain, e_spec):
        e.handle_batch(seeds, max_new_tokens=6)
    r_plain = e_plain.handle_batch_result(probes, max_new_tokens=6)
    r_spec = e_spec.handle_batch_result(probes, max_new_tokens=6)
    assert ([m["decision"] for m in r_plain.meta]
            == [m["decision"] for m in r_spec.meta])
    assert r_plain.responses == r_spec.responses
    assert e_plain.stats.small_tokens == e_spec.stats.small_tokens
    tweaked = e_spec.stats.tweak > 0
    assert tweaked, "probe queries must route TWEAK for this test to bite"
    assert seen and all(seen)       # every tweak call carried drafts
    # Re-serving the same tweak with the previous small output as the
    # cached draft makes the draft exact: acceptance must show up.
    t, l, en = outs[-1]
    vis = t[0][: l[0] - 1 if en[0] else l[0]].tolist()
    for s in list(e_spec.bank.draft_store):
        e_spec.bank.draft_store[s] = vis
    before = e_spec.stats.accepted
    e_spec.handle_batch([probes[0]], max_new_tokens=6)
    assert e_spec.stats.proposed > 0
    assert e_spec.stats.accepted > before
    assert 0.0 < e_spec.stats.acceptance_rate <= 1.0
    # replica aggregation sums the speculation counters
    agg = EngineStats.aggregate([e_plain.stats, e_spec.stats])
    assert agg.proposed == e_spec.stats.proposed
    assert agg.accepted == e_spec.stats.accepted
    assert agg.spec_steps == e_spec.stats.spec_steps
