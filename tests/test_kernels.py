"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
always against the pure-jnp ref.py oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels.cosine_topk.ops import cosine_topk, cosine_topk_gather
from repro.kernels.cosine_topk.ref import (cosine_topk_gather_ref,
                                           cosine_topk_ref)
from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_block)
from repro.kernels.decode_attention.ref import (decode_attention_block_ref,
                                                decode_attention_ref)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ops import (paged_decode_attention,
                                               paged_decode_attention_block)
from repro.kernels.paged_attention.ref import (
    paged_decode_attention_block_ref, paged_decode_attention_ref)


def _unit(key, shape, dtype=jnp.float32):
    x = jax.random.normal(key, shape, dtype)
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


# ------------------------------------------------------------ cosine_topk

@pytest.mark.parametrize("b,n,d,k,bn", [
    (1, 128, 16, 1, 64), (4, 256, 64, 4, 64), (2, 512, 384, 8, 128),
    (3, 256, 32, 16, 256), (8, 1024, 128, 2, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cosine_topk_matches_ref(b, n, d, k, bn, dtype):
    q = _unit(jax.random.PRNGKey(0), (b, d)).astype(dtype)
    db = _unit(jax.random.PRNGKey(1), (n, d)).astype(dtype)
    valid = jax.random.bernoulli(jax.random.PRNGKey(2), 0.85, (n,))
    s1, i1 = cosine_topk(q, db, valid, k=k, impl="pallas", block_n=bn)
    s2, i2 = cosine_topk_ref(q, db, k, valid)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 4), logn=st.integers(6, 9), d=st.sampled_from([8, 32, 128]),
       k=st.integers(1, 8), seed=st.integers(0, 2 ** 16))
def test_cosine_topk_property(b, logn, d, k, seed):
    n = 2 ** logn
    q = _unit(jax.random.PRNGKey(seed), (b, d))
    db = _unit(jax.random.PRNGKey(seed + 1), (n, d))
    s1, i1 = cosine_topk(q, db, None, k=k, impl="pallas", block_n=min(n, 128))
    s2, i2 = cosine_topk_ref(q, db, k, None)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    # scores sorted descending; indices in range
    s1 = np.asarray(s1)
    assert np.all(np.diff(s1, axis=1) <= 1e-6)
    assert np.all((np.asarray(i1) >= 0) & (np.asarray(i1) < n))


@pytest.mark.parametrize("b,n,m,d,k,bm", [
    (1, 128, 32, 16, 1, 16), (4, 256, 96, 64, 4, 32),
    (2, 512, 100, 384, 8, 64),  # M not divisible by block_m -> pad path
    (3, 256, 48, 32, 16, 48),
])
def test_cosine_topk_gather_matches_ref(b, n, m, d, k, bm):
    q = _unit(jax.random.PRNGKey(0), (b, d))
    db = _unit(jax.random.PRNGKey(1), (n, d))
    # distinct candidate rows per query, some marked stale, some padding
    rng = np.random.default_rng(2)
    cand = np.stack([rng.choice(n, size=m, replace=False) for _ in range(b)])
    cand_valid = rng.random((b, m)) < 0.8
    cand[rng.random((b, m)) < 0.1] = -1
    cand = jnp.asarray(cand, jnp.int32)
    cand_valid = jnp.asarray(cand_valid)
    s1, i1 = cosine_topk_gather(q, db, cand, cand_valid, k=k, impl="pallas",
                                block_m=bm)
    emb = jnp.take(db, jnp.clip(cand, 0, None), axis=0)
    s2, i2 = cosine_topk_gather_ref(q, emb, cand, cand_valid & (cand >= 0), k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))


def test_cosine_topk_gather_full_shortlist_matches_flat():
    """With every row shortlisted, the gather path must equal the flat scan."""
    b, n, d, k = 3, 128, 32, 4
    q = _unit(jax.random.PRNGKey(5), (b, d))
    db = _unit(jax.random.PRNGKey(6), (n, d))
    valid = jax.random.bernoulli(jax.random.PRNGKey(7), 0.9, (n,))
    cand = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (b, n))
    cand_valid = jnp.broadcast_to(valid, (b, n))
    for impl in ("xla", "pallas"):
        s1, i1 = cosine_topk_gather(q, db, cand, cand_valid, k=k, impl=impl,
                                    block_m=32)
        s2, i2 = cosine_topk_ref(q, db, k, valid)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-5, atol=1e-5)
        finite = np.isfinite(np.asarray(s2))
        assert np.array_equal(np.asarray(i1)[finite], np.asarray(i2)[finite])


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), m=st.sampled_from([16, 40, 64]),
       k=st.integers(1, 6), seed=st.integers(0, 2 ** 16))
def test_cosine_topk_gather_property(b, m, k, seed):
    n, d = 256, 32
    q = _unit(jax.random.PRNGKey(seed), (b, d))
    db = _unit(jax.random.PRNGKey(seed + 1), (n, d))
    cand = jax.random.randint(jax.random.PRNGKey(seed + 2), (b, m), 0, n)
    cand_valid = jax.random.bernoulli(jax.random.PRNGKey(seed + 3), 0.7, (b, m))
    s1, i1 = cosine_topk_gather(q, db, cand, cand_valid, k=k, impl="pallas",
                                block_m=16)
    emb = jnp.take(db, cand, axis=0)
    s2, i2 = cosine_topk_gather_ref(q, emb, cand, cand_valid, k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    s1 = np.asarray(s1)
    assert np.all(np.diff(np.where(np.isfinite(s1), s1, -1e30), axis=1) <= 1e-6)


def test_cosine_topk_self_retrieval():
    """Property: a db vector queried against its own bank wins top-1."""
    db = _unit(jax.random.PRNGKey(3), (64, 32))
    s, i = cosine_topk(db[:8], db, None, k=1, impl="pallas", block_n=64)
    assert np.array_equal(np.asarray(i)[:, 0], np.arange(8))
    np.testing.assert_allclose(np.asarray(s)[:, 0], 1.0, atol=1e-5)


# --------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,sq,sk,h,hk,dh,bq,bk,causal,win", [
    (2, 64, 64, 4, 2, 32, 16, 16, True, 0),
    (1, 48, 48, 6, 6, 16, 32, 16, True, 12),
    (2, 33, 33, 4, 1, 8, 16, 16, True, 0),
    (1, 16, 40, 2, 2, 16, 16, 8, False, 0),
    (1, 128, 128, 8, 4, 64, 64, 32, True, 32),
])
def test_flash_matches_ref(b, sq, sk, h, hk, dh, bq, bk, causal, win):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, sq, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, hk, dh))
    o1 = flash_attention(q, k, v, causal=causal, window=win,
                         block_q=bq, block_k=bk)
    o2 = flash_attention_ref(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([16, 32, 48]), h=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]), dh=st.sampled_from([8, 16]),
       causal=st.booleans(), seed=st.integers(0, 2 ** 16))
def test_flash_property(s, h, g, dh, causal, seed):
    hk = h // g
    q = jax.random.normal(jax.random.PRNGKey(seed), (1, s, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, s, hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (1, s, hk, dh))
    o1 = flash_attention(q, k, v, causal=causal, window=0, block_q=16, block_k=16)
    o2 = flash_attention_ref(q, k, v, causal=causal, window=0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 32, 4, 16), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 2, 16), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 2, 16), jnp.bfloat16)
    o1 = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    o2 = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=3e-2, atol=3e-2)


# --------------------------------------------------------- decode attention

@pytest.mark.parametrize("b,t,h,hk,dh,bt", [
    (2, 128, 8, 2, 32, 32), (3, 100, 4, 4, 16, 64), (1, 64, 6, 1, 8, 16),
    (4, 256, 16, 8, 64, 128),
])
def test_decode_matches_ref(b, t, h, hk, dh, bt):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, hk, dh))
    cl = jax.random.randint(jax.random.PRNGKey(3), (b,), 1, t + 1)
    o1 = decode_attention(q, k, v, cl, block_t=bt)
    o2 = decode_attention_ref(q, k, v, cl)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([32, 64, 96]), g=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 2 ** 16))
def test_decode_property(t, g, seed):
    b, hk, dh = 2, 2, 16
    h = hk * g
    q = jax.random.normal(jax.random.PRNGKey(seed), (b, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t, hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (b, t, hk, dh))
    cl = jnp.asarray([1, t])
    o1 = decode_attention(q, k, v, cl, block_t=32)
    o2 = decode_attention_ref(q, k, v, cl)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)
    # cache_len=1 row attends only to slot 0 -> output == v[:, 0] broadcast
    np.testing.assert_allclose(
        np.asarray(o1)[0], np.asarray(v)[0, 0].repeat(g, axis=0), rtol=1e-4)


# -------------------------------------------- q-block (speculative) decode

@pytest.mark.parametrize("b,kq,t,h,hk,dh,bt", [
    (2, 4, 128, 8, 2, 32, 32), (3, 2, 100, 4, 4, 16, 64),
    (1, 8, 64, 6, 1, 8, 16), (2, 1, 96, 4, 2, 16, 32),
])
def test_decode_block_matches_ref(b, kq, t, h, hk, dh, bt):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, kq, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, hk, dh))
    cl = jax.random.randint(jax.random.PRNGKey(3), (b,), 1, t - kq)
    o1 = decode_attention_block(q, k, v, cl, block_t=bt)
    o2 = decode_attention_block_ref(q, k, v, cl)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_decode_block_k1_equals_single_decode():
    """A 1-wide verify block IS single-token decode (limit cache_len + 1)."""
    b, t, h, hk, dh = 3, 64, 4, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, 1, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, t, hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, t, hk, dh))
    cl = jnp.asarray([5, 31, 62])
    o1 = decode_attention_block(q, k, v, cl, block_t=32)[:, 0]
    o2 = decode_attention(q[:, 0], k, v, cl + 1, block_t=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(kq=st.sampled_from([1, 2, 4, 8]), g=st.sampled_from([1, 2]),
       seed=st.integers(0, 2 ** 16))
def test_decode_block_rowwise_equals_sequential(kq, g, seed):
    """Each block query i must equal a single-token decode over the prefix
    grown by i — the in-block causal mask IS the sequential semantics."""
    b, hk, dh, t = 2, 2, 16, 64
    h = hk * g
    q = jax.random.normal(jax.random.PRNGKey(seed), (b, kq, h, dh))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, t, hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (b, t, hk, dh))
    cl = jnp.asarray([3, t - kq - 1])
    blk = decode_attention_block(q, k, v, cl, block_t=32)
    for i in range(kq):
        one = decode_attention(q[:, i], k, v, cl + i + 1, block_t=32)
        np.testing.assert_allclose(np.asarray(blk[:, i]), np.asarray(one),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------- paged decode attention

def _paged_case(b, h, hk, dh, page, npg, num_pages, cap, lens, seed):
    """Random pool + RAGGED block tables (a permutation slice per batch):
    physically scattered pages, garbage in unallocated/trash pages."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, dh))
    kp = jax.random.normal(ks[1], (num_pages + 1, page, hk, dh))
    vp = jax.random.normal(ks[2], (num_pages + 1, page, hk, dh))
    rng = np.random.default_rng(seed)
    tbl = rng.permutation(num_pages)[:b * npg].reshape(b, npg).astype(np.int32)
    sp = np.full((b, cap), -1, np.int32)
    for i, ln in enumerate(lens):
        sp[i, :ln] = np.arange(ln)
    return q, kp, vp, jnp.asarray(tbl), jnp.asarray(sp)


@pytest.mark.parametrize("b,h,hk,dh,page,npg,num_pages,cap,lens", [
    # partially filled last page + ragged per-row lengths
    (3, 4, 2, 16, 8, 4, 32, 30, (30, 17, 5)),
    # degenerate one-page sequence
    (2, 2, 1, 8, 16, 1, 8, 13, (13, 1)),
    # GQA g=4, cap == npg * page exactly (no tail slice)
    (2, 8, 2, 32, 4, 8, 64, 32, (32, 9)),
    # page_size=1 pathological: one slot per page
    (2, 2, 2, 8, 1, 12, 24, 12, (12, 7)),
])
def test_paged_decode_matches_ref(b, h, hk, dh, page, npg, num_pages, cap,
                                  lens):
    q, kp, vp, tbl, sp = _paged_case(b, h, hk, dh, page, npg, num_pages,
                                     cap, lens, seed=b * 7 + npg)
    o1 = paged_decode_attention(q, kp, vp, tbl, sp)
    o2 = paged_decode_attention_ref(q, kp, vp, tbl, sp)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_matches_dense_decode_kernel():
    """Paging is pure indirection: gathering the pages back into a dense
    cache and running the DENSE decode kernel gives the same answer."""
    from repro.kernels.paged_attention.ref import gather_pages
    q, kp, vp, tbl, sp = _paged_case(2, 4, 2, 16, 8, 3, 16, 20, (20, 11),
                                     seed=5)
    o1 = paged_decode_attention(q, kp, vp, tbl, sp)
    kd = gather_pages(kp, tbl, 20)
    vd = gather_pages(vp, tbl, 20)
    o2 = decode_attention(q, kd, vd, jnp.asarray([20, 11]), block_t=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kq,page,npg", [(1, 8, 4), (2, 4, 6), (4, 8, 4),
                                         (8, 1, 16)])
def test_paged_decode_block_matches_ref(kq, page, npg):
    b, h, hk, dh = 2, 4, 2, 16
    num_pages = max(b * npg, 8)
    cap = npg * page
    rng = np.random.default_rng(kq * 13 + page)
    lens = tuple(int(x) for x in rng.integers(kq, cap + 1, size=b))
    q1, kp, vp, tbl, sp = _paged_case(b, h, hk, dh, page, npg, num_pages,
                                      cap, lens, seed=kq + page)
    q = jax.random.normal(jax.random.PRNGKey(99), (b, kq, h, dh))
    qpos = jnp.asarray([ln - kq for ln in lens], jnp.int32)
    o1 = paged_decode_attention_block(q, kp, vp, tbl, sp, qpos)
    o2 = paged_decode_attention_block_ref(q, kp, vp, tbl, sp, qpos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_block_matches_dense_block_kernel():
    """Paging is pure indirection for the block variant too: gather the
    pages dense and the DENSE block kernel must agree."""
    from repro.kernels.paged_attention.ref import gather_pages
    kq = 4
    lens = (20, 11)
    q1, kp, vp, tbl, sp = _paged_case(2, 4, 2, 16, 8, 3, 16, 20, lens,
                                      seed=5)
    q = jax.random.normal(jax.random.PRNGKey(42), (2, kq, 4, 16))
    qpos = jnp.asarray([ln - kq for ln in lens], jnp.int32)
    o1 = paged_decode_attention_block(q, kp, vp, tbl, sp, qpos)
    kd = gather_pages(kp, tbl, 20)
    vd = gather_pages(vp, tbl, 20)
    o2 = decode_attention_block(q, kd, vd, qpos, block_t=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(page=st.sampled_from([1, 4, 8]), npg=st.integers(1, 6),
       g=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2 ** 16))
def test_paged_decode_property(page, npg, g, seed):
    b, hk, dh = 2, 2, 16
    h = hk * g
    num_pages = max(b * npg, 4)
    cap = npg * page
    rng = np.random.default_rng(seed)
    lens = tuple(int(x) for x in rng.integers(1, cap + 1, size=b))
    q, kp, vp, tbl, sp = _paged_case(b, h, hk, dh, page, npg, num_pages,
                                     cap, lens, seed=seed)
    o1 = paged_decode_attention(q, kp, vp, tbl, sp)
    o2 = paged_decode_attention_ref(q, kp, vp, tbl, sp)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)
