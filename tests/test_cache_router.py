"""Semantic cache + router invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import cache as cache_lib
from repro.core import router as router_lib


def _cfg(**kw):
    d = dict(capacity=16, dim=8, max_query_tokens=4, max_response_tokens=4,
             topk=4)
    d.update(kw)
    return cache_lib.CacheConfig(**d)


def _rand_entry(key, cfg):
    e = jax.random.normal(key, (cfg.dim,))
    qt = jnp.zeros((cfg.max_query_tokens,), jnp.int32)
    qm = jnp.ones((cfg.max_query_tokens,), jnp.float32)
    rt = jnp.zeros((cfg.max_response_tokens,), jnp.int32)
    rm = jnp.ones((cfg.max_response_tokens,), jnp.float32)
    return e, qt, qm, rt, rm


def test_insert_then_lookup_exact():
    cfg = _cfg()
    st_ = cache_lib.init_cache(cfg)
    e, *rest = _rand_entry(jax.random.PRNGKey(0), cfg)
    st_ = cache_lib.insert(st_, cfg, e, *rest)
    q = (e / jnp.linalg.norm(e))[None]
    scores, idx = cache_lib.lookup(st_, cfg, q)
    assert int(idx[0, 0]) == 0
    np.testing.assert_allclose(float(scores[0, 0]), 1.0, atol=1e-5)


def test_empty_cache_no_hits():
    cfg = _cfg()
    st_ = cache_lib.init_cache(cfg)
    q = jax.random.normal(jax.random.PRNGKey(0), (2, cfg.dim))
    scores, idx = cache_lib.lookup(st_, cfg, q)
    assert np.all(np.asarray(scores) == -np.inf)


def test_fifo_eviction_order():
    cfg = _cfg(capacity=4, policy="fifo")
    st_ = cache_lib.init_cache(cfg)
    embs = []
    for i in range(6):  # two past capacity
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        embs.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    # entries 0,1 evicted; 2..5 present at slots 2,3,0,1
    s, i = cache_lib.lookup(st_, cfg, jnp.stack(embs))
    top = np.asarray(s)[:, 0]
    assert top[0] < 0.999 and top[1] < 0.999  # evicted
    np.testing.assert_allclose(top[2:], 1.0, atol=1e-5)


def test_lru_eviction_keeps_touched():
    cfg = _cfg(capacity=2, policy="lru")
    st_ = cache_lib.init_cache(cfg)
    es = []
    for i in range(2):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        es.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    st_ = cache_lib.touch(st_, cfg, jnp.asarray([0]))  # entry 0 recently used
    e, *rest = _rand_entry(jax.random.PRNGKey(99), cfg)
    st_ = cache_lib.insert(st_, cfg, e, *rest)  # should evict slot 1
    s, i = cache_lib.lookup(st_, cfg, jnp.stack(es))
    assert float(s[0, 0]) > 0.999   # kept
    assert float(s[1, 0]) < 0.999   # evicted


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 12), seed=st.integers(0, 2 ** 16))
def test_size_never_exceeds_capacity(n, seed):
    cfg = _cfg(capacity=8)
    st_ = cache_lib.init_cache(cfg)
    for i in range(n):
        e, *rest = _rand_entry(jax.random.PRNGKey(seed + i), cfg)
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    assert int(st_["size"]) == min(n, 8)
    assert int(jnp.sum(st_["valid"])) == min(n, 8)


def test_lfu_eviction_keeps_hit():
    cfg = _cfg(capacity=2, policy="lfu")
    st_ = cache_lib.init_cache(cfg)
    es = []
    for i in range(2):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        es.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    st_ = cache_lib.touch(st_, cfg, jnp.asarray([1]))  # entry 1 is hot
    e, *rest = _rand_entry(jax.random.PRNGKey(99), cfg)
    st_ = cache_lib.insert(st_, cfg, e, *rest)  # should evict cold slot 0
    s, _ = cache_lib.lookup(st_, cfg, jnp.stack(es))
    assert float(s[0, 0]) < 0.999   # evicted
    assert float(s[1, 0]) > 0.999   # kept


@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_exact_hit_survives_eviction_via_fused_touch(policy):
    """An entry hit through lookup_and_touch (the EXACT/TWEAK serve path)
    must outlive untouched entries under eviction pressure."""
    cfg = _cfg(capacity=3, policy=policy)
    rcfg = router_lib.RouterConfig(tweak_threshold=0.7, exact_threshold=0.999)
    st_ = cache_lib.init_cache(cfg)
    es = []
    for i in range(3):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        es.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    # exact-hit entry 0 (its own embedding -> sim 1.0 -> EXACT)
    st_, scores, idx, dec = cache_lib.lookup_and_touch(st_, cfg, rcfg,
                                                       es[0][None])
    assert int(dec[0]) == router_lib.EXACT
    assert int(st_["hits"][int(idx[0, 0])]) == 1
    # inserts under pressure: untouched entries are the victims, never the
    # hit one (LFU ties break to the first zero-hit slot, so the second
    # pressure insert may evict the first — at least one original goes)
    for i in (7, 8):
        e, *rest = _rand_entry(jax.random.PRNGKey(100 + i), cfg)
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    s, _ = cache_lib.lookup(st_, cfg, jnp.stack(es))
    assert float(s[0, 0]) > 0.999            # the hit entry survived
    assert sum(float(s[i, 0]) < 0.999 for i in (1, 2)) >= 1


def test_touch_negative_index_is_noop():
    """Regression: raw -1 indices WRAP in jax scatters, so an unguarded
    touch on an empty/all-invalid cache (pallas lookup reports top-1 -1)
    silently touched the LAST slot and corrupted LRU/LFU ordering."""
    cfg = _cfg(capacity=8)
    st_ = cache_lib.init_cache(cfg)
    for i in range(8):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    before_lu = np.asarray(st_["last_used"]).copy()
    before_h = np.asarray(st_["hits"]).copy()
    touched = cache_lib.touch(st_, cfg, jnp.asarray([-1, -1]))
    np.testing.assert_array_equal(np.asarray(touched["last_used"]), before_lu)
    np.testing.assert_array_equal(np.asarray(touched["hits"]), before_h)
    assert int(touched["clock"]) == int(st_["clock"]) + 1
    # mixed batch: valid index still touches, -1 still doesn't
    touched = cache_lib.touch(st_, cfg, jnp.asarray([3, -1]))
    assert int(touched["hits"][3]) == before_h[3] + 1
    assert int(touched["last_used"][-1]) == before_lu[-1]


def test_lookup_and_touch_miss_does_not_touch():
    cfg = _cfg(capacity=4)
    rcfg = router_lib.RouterConfig(tweak_threshold=0.7, exact_threshold=0.999)
    st_ = cache_lib.init_cache(cfg)
    e, *rest = _rand_entry(jax.random.PRNGKey(0), cfg)
    st_ = cache_lib.insert(st_, cfg, e, *rest)
    far = jnp.ones((1, cfg.dim)) * jnp.asarray([[1, -1] * (cfg.dim // 2)])
    far = far / jnp.linalg.norm(far)
    new, scores, idx, dec = cache_lib.lookup_and_touch(st_, cfg, rcfg, far)
    if int(dec[0]) == router_lib.MISS:
        np.testing.assert_array_equal(np.asarray(new["hits"]),
                                      np.asarray(st_["hits"]))
        np.testing.assert_array_equal(np.asarray(new["last_used"]),
                                      np.asarray(st_["last_used"]))


@pytest.mark.parametrize("policy", ["fifo", "lru", "lfu"])
def test_insert_batch_matches_sequential(policy):
    """insert_batch must be state-identical to N sequential inserts,
    including when the batch is padded past ``count`` and laps the ring."""
    cfg = _cfg(capacity=8, policy=policy)
    n, padded = 12, 16  # 12 real rows (laps capacity 8), 4 padding rows
    key = jax.random.PRNGKey(42)
    embs = jax.random.normal(key, (padded, cfg.dim))
    qt = jnp.arange(padded * cfg.max_query_tokens, dtype=jnp.int32).reshape(
        padded, cfg.max_query_tokens)
    qm = jnp.ones((padded, cfg.max_query_tokens), jnp.float32)
    rt = qt[:, :cfg.max_response_tokens] + 7
    rm = jnp.ones((padded, cfg.max_response_tokens), jnp.float32)

    ref = cache_lib.init_cache(cfg)
    for i in range(n):
        ref = cache_lib.insert(ref, cfg, embs[i], qt[i], qm[i], rt[i], rm[i])

    jitted = cache_lib.make_insert_batch(cfg, donate=False)
    got, slots = jitted(cache_lib.init_cache(cfg), embs, qt, qm, rt, rm, n)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(got[k]),
                                      err_msg=f"{policy}:{k}")
    slots = np.asarray(slots)
    assert np.all(slots[:n] >= 0) and np.all(slots[n:] == -1)


def test_insert_batch_count_clamped_to_batch():
    """count > B must not advance ptr/clock/size past the rows written."""
    cfg = _cfg(capacity=8)
    b = 4
    embs = jax.random.normal(jax.random.PRNGKey(0), (b, cfg.dim))
    qt = jnp.zeros((b, cfg.max_query_tokens), jnp.int32)
    qm = jnp.ones((b, cfg.max_query_tokens), jnp.float32)
    rt = jnp.zeros((b, cfg.max_response_tokens), jnp.int32)
    rm = jnp.ones((b, cfg.max_response_tokens), jnp.float32)
    ref, _ = cache_lib.insert_batch(cache_lib.init_cache(cfg), cfg,
                                    embs, qt, qm, rt, rm, b)
    got, _ = cache_lib.insert_batch(cache_lib.init_cache(cfg), cfg,
                                    embs, qt, qm, rt, rm, 12)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(got[k]),
                                      err_msg=k)


# ------------------------------------------------------------------ router

def test_route_thresholds():
    cfg = router_lib.RouterConfig(tweak_threshold=0.7, exact_threshold=0.999)
    s = jnp.asarray([0.2, 0.69, 0.7, 0.9, 0.999, 1.0])
    d = np.asarray(router_lib.route(s, cfg))
    assert list(d) == [router_lib.MISS, router_lib.MISS, router_lib.TWEAK,
                       router_lib.TWEAK, router_lib.EXACT, router_lib.EXACT]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1, 1.0), min_size=1, max_size=32),
       st.floats(0.3, 0.95))
def test_router_monotone_in_threshold(scores, t):
    """Raising the threshold never increases the number of hits."""
    s = jnp.asarray(scores, jnp.float32)
    lo = router_lib.route(s, router_lib.RouterConfig(tweak_threshold=t))
    hi = router_lib.route(s, router_lib.RouterConfig(tweak_threshold=min(t + 0.1, 1.0)))
    hits_lo = int(jnp.sum(lo != router_lib.MISS))
    hits_hi = int(jnp.sum(hi != router_lib.MISS))
    assert hits_hi <= hits_lo


def test_band_of():
    b = np.asarray(router_lib.band_of(jnp.asarray([0.5, 0.7, 0.85, 0.95, 1.0])))
    assert list(b) == [-1, 0, 1, 2, 2]
