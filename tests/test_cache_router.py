"""Semantic cache + router invariants (unit + hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import cache as cache_lib
from repro.core import router as router_lib


def _cfg(**kw):
    d = dict(capacity=16, dim=8, max_query_tokens=4, max_response_tokens=4,
             topk=4)
    d.update(kw)
    return cache_lib.CacheConfig(**d)


def _rand_entry(key, cfg):
    e = jax.random.normal(key, (cfg.dim,))
    qt = jnp.zeros((cfg.max_query_tokens,), jnp.int32)
    qm = jnp.ones((cfg.max_query_tokens,), jnp.float32)
    rt = jnp.zeros((cfg.max_response_tokens,), jnp.int32)
    rm = jnp.ones((cfg.max_response_tokens,), jnp.float32)
    return e, qt, qm, rt, rm


def test_insert_then_lookup_exact():
    cfg = _cfg()
    st_ = cache_lib.init_cache(cfg)
    e, *rest = _rand_entry(jax.random.PRNGKey(0), cfg)
    st_ = cache_lib.insert(st_, cfg, e, *rest)
    q = (e / jnp.linalg.norm(e))[None]
    scores, idx = cache_lib.lookup(st_, cfg, q)
    assert int(idx[0, 0]) == 0
    np.testing.assert_allclose(float(scores[0, 0]), 1.0, atol=1e-5)


def test_empty_cache_no_hits():
    cfg = _cfg()
    st_ = cache_lib.init_cache(cfg)
    q = jax.random.normal(jax.random.PRNGKey(0), (2, cfg.dim))
    scores, idx = cache_lib.lookup(st_, cfg, q)
    assert np.all(np.asarray(scores) == -np.inf)


def test_fifo_eviction_order():
    cfg = _cfg(capacity=4, policy="fifo")
    st_ = cache_lib.init_cache(cfg)
    embs = []
    for i in range(6):  # two past capacity
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        embs.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    # entries 0,1 evicted; 2..5 present at slots 2,3,0,1
    s, i = cache_lib.lookup(st_, cfg, jnp.stack(embs))
    top = np.asarray(s)[:, 0]
    assert top[0] < 0.999 and top[1] < 0.999  # evicted
    np.testing.assert_allclose(top[2:], 1.0, atol=1e-5)


def test_lru_eviction_keeps_touched():
    cfg = _cfg(capacity=2, policy="lru")
    st_ = cache_lib.init_cache(cfg)
    es = []
    for i in range(2):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        es.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    st_ = cache_lib.touch(st_, cfg, jnp.asarray([0]))  # entry 0 recently used
    e, *rest = _rand_entry(jax.random.PRNGKey(99), cfg)
    st_ = cache_lib.insert(st_, cfg, e, *rest)  # should evict slot 1
    s, i = cache_lib.lookup(st_, cfg, jnp.stack(es))
    assert float(s[0, 0]) > 0.999   # kept
    assert float(s[1, 0]) < 0.999   # evicted


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 12), seed=st.integers(0, 2 ** 16))
def test_size_never_exceeds_capacity(n, seed):
    cfg = _cfg(capacity=8)
    st_ = cache_lib.init_cache(cfg)
    for i in range(n):
        e, *rest = _rand_entry(jax.random.PRNGKey(seed + i), cfg)
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    assert int(st_["size"]) == min(n, 8)
    assert int(jnp.sum(st_["valid"])) == min(n, 8)


def test_lfu_eviction_keeps_hit():
    cfg = _cfg(capacity=2, policy="lfu")
    st_ = cache_lib.init_cache(cfg)
    es = []
    for i in range(2):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        es.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    st_ = cache_lib.touch(st_, cfg, jnp.asarray([1]))  # entry 1 is hot
    e, *rest = _rand_entry(jax.random.PRNGKey(99), cfg)
    st_ = cache_lib.insert(st_, cfg, e, *rest)  # should evict cold slot 0
    s, _ = cache_lib.lookup(st_, cfg, jnp.stack(es))
    assert float(s[0, 0]) < 0.999   # evicted
    assert float(s[1, 0]) > 0.999   # kept


@pytest.mark.parametrize("policy", ["lru", "lfu"])
def test_exact_hit_survives_eviction_via_fused_touch(policy):
    """An entry hit through lookup_and_touch (the EXACT/TWEAK serve path)
    must outlive untouched entries under eviction pressure."""
    cfg = _cfg(capacity=3, policy=policy)
    rcfg = router_lib.RouterConfig(tweak_threshold=0.7, exact_threshold=0.999)
    st_ = cache_lib.init_cache(cfg)
    es = []
    for i in range(3):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        es.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    # exact-hit entry 0 (its own embedding -> sim 1.0 -> EXACT)
    st_, scores, idx, dec = cache_lib.lookup_and_touch(st_, cfg, rcfg,
                                                       es[0][None])
    assert int(dec[0]) == router_lib.EXACT
    assert int(st_["hits"][int(idx[0, 0])]) == 1
    # inserts under pressure: untouched entries are the victims, never the
    # hit one (LFU ties break to the first zero-hit slot, so the second
    # pressure insert may evict the first — at least one original goes)
    for i in (7, 8):
        e, *rest = _rand_entry(jax.random.PRNGKey(100 + i), cfg)
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    s, _ = cache_lib.lookup(st_, cfg, jnp.stack(es))
    assert float(s[0, 0]) > 0.999            # the hit entry survived
    assert sum(float(s[i, 0]) < 0.999 for i in (1, 2)) >= 1


def test_touch_negative_index_is_noop():
    """Regression: raw -1 indices WRAP in jax scatters, so an unguarded
    touch on an empty/all-invalid cache (pallas lookup reports top-1 -1)
    silently touched the LAST slot and corrupted LRU/LFU ordering."""
    cfg = _cfg(capacity=8)
    st_ = cache_lib.init_cache(cfg)
    for i in range(8):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    before_lu = np.asarray(st_["last_used"]).copy()
    before_h = np.asarray(st_["hits"]).copy()
    touched = cache_lib.touch(st_, cfg, jnp.asarray([-1, -1]))
    np.testing.assert_array_equal(np.asarray(touched["last_used"]), before_lu)
    np.testing.assert_array_equal(np.asarray(touched["hits"]), before_h)
    assert int(touched["clock"]) == int(st_["clock"]) + 1
    # mixed batch: valid index still touches, -1 still doesn't
    touched = cache_lib.touch(st_, cfg, jnp.asarray([3, -1]))
    assert int(touched["hits"][3]) == before_h[3] + 1
    assert int(touched["last_used"][-1]) == before_lu[-1]


def test_lookup_and_touch_miss_does_not_touch():
    cfg = _cfg(capacity=4)
    rcfg = router_lib.RouterConfig(tweak_threshold=0.7, exact_threshold=0.999)
    st_ = cache_lib.init_cache(cfg)
    e, *rest = _rand_entry(jax.random.PRNGKey(0), cfg)
    st_ = cache_lib.insert(st_, cfg, e, *rest)
    far = jnp.ones((1, cfg.dim)) * jnp.asarray([[1, -1] * (cfg.dim // 2)])
    far = far / jnp.linalg.norm(far)
    new, scores, idx, dec = cache_lib.lookup_and_touch(st_, cfg, rcfg, far)
    if int(dec[0]) == router_lib.MISS:
        np.testing.assert_array_equal(np.asarray(new["hits"]),
                                      np.asarray(st_["hits"]))
        np.testing.assert_array_equal(np.asarray(new["last_used"]),
                                      np.asarray(st_["last_used"]))


@pytest.mark.parametrize("policy", ["fifo", "lru", "lfu"])
def test_insert_batch_matches_sequential(policy):
    """insert_batch must be state-identical to N sequential inserts,
    including when the batch is padded past ``count`` and laps the ring."""
    cfg = _cfg(capacity=8, policy=policy)
    n, padded = 12, 16  # 12 real rows (laps capacity 8), 4 padding rows
    key = jax.random.PRNGKey(42)
    embs = jax.random.normal(key, (padded, cfg.dim))
    qt = jnp.arange(padded * cfg.max_query_tokens, dtype=jnp.int32).reshape(
        padded, cfg.max_query_tokens)
    qm = jnp.ones((padded, cfg.max_query_tokens), jnp.float32)
    rt = qt[:, :cfg.max_response_tokens] + 7
    rm = jnp.ones((padded, cfg.max_response_tokens), jnp.float32)

    ref = cache_lib.init_cache(cfg)
    for i in range(n):
        ref = cache_lib.insert(ref, cfg, embs[i], qt[i], qm[i], rt[i], rm[i])

    jitted = cache_lib.make_insert_batch(cfg, donate=False)
    got, slots = jitted(cache_lib.init_cache(cfg), embs, qt, qm, rt, rm, n)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(got[k]),
                                      err_msg=f"{policy}:{k}")
    slots = np.asarray(slots)
    assert np.all(slots[:n] >= 0) and np.all(slots[n:] == -1)


def test_insert_batch_count_clamped_to_batch():
    """count > B must not advance ptr/clock/size past the rows written."""
    cfg = _cfg(capacity=8)
    b = 4
    embs = jax.random.normal(jax.random.PRNGKey(0), (b, cfg.dim))
    qt = jnp.zeros((b, cfg.max_query_tokens), jnp.int32)
    qm = jnp.ones((b, cfg.max_query_tokens), jnp.float32)
    rt = jnp.zeros((b, cfg.max_response_tokens), jnp.int32)
    rm = jnp.ones((b, cfg.max_response_tokens), jnp.float32)
    ref, _ = cache_lib.insert_batch(cache_lib.init_cache(cfg), cfg,
                                    embs, qt, qm, rt, rm, b)
    got, _ = cache_lib.insert_batch(cache_lib.init_cache(cfg), cfg,
                                    embs, qt, qm, rt, rm, 12)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(ref[k]), np.asarray(got[k]),
                                      err_msg=k)


# ------------------------------------------------------------------ router

def test_route_thresholds():
    cfg = router_lib.RouterConfig(tweak_threshold=0.7, exact_threshold=0.999)
    s = jnp.asarray([0.2, 0.69, 0.7, 0.9, 0.999, 1.0])
    d = np.asarray(router_lib.route(s, cfg))
    assert list(d) == [router_lib.MISS, router_lib.MISS, router_lib.TWEAK,
                       router_lib.TWEAK, router_lib.EXACT, router_lib.EXACT]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1, 1.0), min_size=1, max_size=32),
       st.floats(0.3, 0.95))
def test_router_monotone_in_threshold(scores, t):
    """Raising the threshold never increases the number of hits."""
    s = jnp.asarray(scores, jnp.float32)
    lo = router_lib.route(s, router_lib.RouterConfig(tweak_threshold=t))
    hi = router_lib.route(s, router_lib.RouterConfig(tweak_threshold=min(t + 0.1, 1.0)))
    hits_lo = int(jnp.sum(lo != router_lib.MISS))
    hits_hi = int(jnp.sum(hi != router_lib.MISS))
    assert hits_hi <= hits_lo


def test_band_of():
    b = np.asarray(router_lib.band_of(jnp.asarray([0.5, 0.7, 0.85, 0.95, 1.0])))
    assert list(b) == [-1, 0, 1, 2, 2]


def test_band_of_derives_from_active_config():
    """Regression: the band edges were hardcoded 0.7/0.8/0.9, so a run at
    tweak_threshold=0.55 misattributed every sim in [0.55, 0.7) to "no
    band" and squeezed real TWEAK traffic out of the band table."""
    assert router_lib.band_edges() == (0.7, 0.8, 0.9, 1.01)   # paper default
    cfg = router_lib.RouterConfig(tweak_threshold=0.55)
    assert router_lib.band_edges(cfg) == (0.55, 0.7, 0.85, 1.01)
    scores = jnp.asarray([0.56, 0.72, 0.9, 1.0])
    # active config: 0.56 is real hit traffic and lands in band 0
    assert list(np.asarray(router_lib.band_of(scores, cfg))) == [0, 1, 2, 2]
    # the old hardcoded behaviour (no config) drops it on the floor
    assert int(router_lib.band_of(scores)[0]) == -1


def test_threshold_for_default_cost_snaps_to_legacy_threshold():
    cfg = router_lib.RouterConfig()
    tau = router_lib.threshold_for(
        jnp.full((3,), cfg.default_cost, jnp.float32), cfg)
    # bit-exact at the default operating point (in float32, the dtype the
    # routing comparison runs in) — the byte-identity anchor
    assert all(t == np.float32(cfg.tweak_threshold)
               for t in np.asarray(tau))
    taus = np.asarray(router_lib.threshold_for(
        jnp.linspace(0.0, 1.0, 11).astype(jnp.float32), cfg))
    assert np.all(np.diff(taus) >= 0)                 # monotone in cost
    np.testing.assert_allclose(taus[0], cfg.tweak_threshold - cfg.cal_span,
                               atol=1e-6)
    np.testing.assert_allclose(taus[-1], 1.0, atol=1e-6)


def test_threshold_for_explicit_knots():
    cfg = router_lib.RouterConfig(cal_costs=(0.0, 1.0), cal_taus=(0.6, 0.95))
    taus = np.asarray(router_lib.threshold_for(
        jnp.asarray([0.0, 0.5, 1.0], jnp.float32), cfg))
    np.testing.assert_allclose(taus, [0.6, 0.775, 0.95], atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-1, 1.0), min_size=1, max_size=32),
       st.floats(0.3, 0.95))
def test_cascade_band_zero_is_legacy_route(scores, t):
    """band=0 statically elides the uncertainty stage: route_cascade must
    be decision-identical to the legacy route at tau=tweak_threshold."""
    cfg = router_lib.RouterConfig(tweak_threshold=t)
    s = jnp.asarray(scores, jnp.float32)
    tau = router_lib.threshold_for(
        jnp.full(s.shape, cfg.default_cost, jnp.float32), cfg)
    np.testing.assert_array_equal(
        np.asarray(router_lib.route_cascade(s, tau, cfg)),
        np.asarray(router_lib.route(s, cfg)))


def test_cascade_band_marks_uncertain():
    cfg = router_lib.RouterConfig(tweak_threshold=0.7, band=0.1)
    s = jnp.asarray([0.5, 0.66, 0.74, 0.76, 0.9999, 1.0])
    tau = jnp.full(s.shape, 0.7, jnp.float32)
    d = list(np.asarray(router_lib.route_cascade(s, tau, cfg)))
    assert d == [router_lib.MISS, router_lib.UNCERTAIN,
                 router_lib.UNCERTAIN, router_lib.TWEAK,
                 router_lib.EXACT, router_lib.EXACT]


@pytest.mark.parametrize("index", ["flat", "ivf"])
def test_lookup_route_touch_byte_identical_to_legacy(index):
    """The cascade entry point at band=0 + default calibration + default
    cost must reproduce cache.lookup_and_touch BYTE-for-byte: decisions,
    scores, shortlist, and every touched state array."""
    kw = dict(capacity=16, dim=8, topk=4)
    if index == "ivf":
        kw.update(index="ivf", nclusters=4, nprobe=4)
    cfg = _cfg(**kw)
    rcfg = router_lib.RouterConfig()
    st_ = cache_lib.init_cache(cfg)
    for i in range(12):
        e, *rest = _rand_entry(jax.random.PRNGKey(i), cfg)
        st_ = cache_lib.insert(st_, cfg, e, *rest)
    if index == "ivf":
        from repro.core import index as index_lib
        st_ = index_lib.build_index(st_, cfg, seed=0)
    # exact hits, near-band perturbations, cold misses
    q = jnp.concatenate([
        st_["emb"][:3],
        0.9 * st_["emb"][3:6]
        + 0.3 * jax.random.normal(jax.random.PRNGKey(50), (3, cfg.dim)),
        jax.random.normal(jax.random.PRNGKey(51), (3, cfg.dim))])
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    ref_state, ref_s, ref_i, ref_d = cache_lib.lookup_and_touch(
        dict(st_), cfg, rcfg, q)
    cost = jnp.full((q.shape[0],), rcfg.default_cost, jnp.float32)
    new, s, i, d, tau, cluster, admit = cache_lib.lookup_route_touch(
        dict(st_), cfg, rcfg, q, cost)
    np.testing.assert_array_equal(np.asarray(ref_d), np.asarray(d))
    np.testing.assert_array_equal(np.asarray(ref_s), np.asarray(s))
    np.testing.assert_array_equal(np.asarray(ref_i), np.asarray(i))
    for k in ref_state:
        if k in cache_lib.ADM_KEYS:
            continue        # legacy never updates the admission EMA
        np.testing.assert_array_equal(np.asarray(ref_state[k]),
                                      np.asarray(new[k]), err_msg=k)
    # admission defaults: everything admitted
    assert bool(np.all(np.asarray(admit)))


def test_admission_update_closed_form_and_gating():
    cfg = router_lib.RouterConfig(admit_alpha=0.5, admit_floor=0.4,
                                  admit_min=2)
    ema = jnp.ones((4,), jnp.float32)
    cnt = jnp.zeros((4,), jnp.int32)
    cluster = jnp.asarray([0, 0, 1, -1])
    hit = jnp.asarray([False, False, True, True])
    obs = jnp.ones((4,), bool)
    ema2, cnt2 = router_lib.admission_update(ema, cnt, cluster, hit, obs,
                                             cfg)
    # cluster 0 took 2 misses: (1-a)^2 * 1 + (1-(1-a)^2) * 0 = 0.25
    # cluster 1 took 1 hit:    (1-a) * 1 + a * 1           = 1.0
    # cluster -1 (flat / no cluster) is dropped entirely
    np.testing.assert_allclose(np.asarray(ema2), [0.25, 1.0, 1.0, 1.0],
                               atol=1e-6)
    assert list(np.asarray(cnt2)) == [2, 1, 0, 0]
    # the batched closed form == two sequential single-row updates
    e_seq, c_seq = jnp.ones((4,), jnp.float32), jnp.zeros((4,), jnp.int32)
    for r in range(2):
        e_seq, c_seq = router_lib.admission_update(
            e_seq, c_seq, cluster[r:r + 1], hit[r:r + 1], obs[r:r + 1], cfg)
    np.testing.assert_allclose(float(e_seq[0]), float(ema2[0]), atol=1e-6)
    # gating: cluster 0 is shut (count >= admit_min, ema < floor);
    # cluster 1 stays open; unclustered rows are always admitted
    adm = np.asarray(router_lib.admission_admit(
        ema2, cnt2, jnp.asarray([0, 1, -1]), cfg))
    assert list(adm) == [False, True, True]
    # below admit_min observations, never shut (cold clusters get a chance)
    adm_cold = np.asarray(router_lib.admission_admit(
        jnp.zeros((4,), jnp.float32), jnp.asarray([1, 0, 0, 0]),
        jnp.asarray([0]), cfg))
    assert list(adm_cold) == [True]


def test_admission_floor_zero_admits_everything():
    cfg = router_lib.RouterConfig()          # admit_floor defaults to 0
    adm = router_lib.admission_admit(
        jnp.zeros((4,), jnp.float32), jnp.full((4,), 100, jnp.int32),
        jnp.asarray([0, 1, 2, 3]), cfg)
    assert bool(np.all(np.asarray(adm)))


def test_stage2_combine_commit_and_recovery():
    cfg = router_lib.RouterConfig(band=0.1)
    tau = jnp.asarray([0.7, 0.7], jnp.float32)
    # row 0: strong agreement + confident reranker -> commit, and the
    # blended-evidence argmax (slot 2) beats the cosine top-1 (misroute
    # fix); row 1: no live candidates -> never commits
    scores = jnp.asarray([[0.74, 0.73, 0.72, 0.1],
                          [-np.inf] * 4], jnp.float32)
    rerank = jnp.asarray([[2.0, 1.0, 6.0, -3.0], [0.0] * 4], jnp.float32)
    live = jnp.asarray([[True, True, True, True], [False] * 4])
    commit, best, conf = router_lib.stage2_combine(scores, rerank, live,
                                                   tau, cfg)
    assert bool(commit[0]) and not bool(commit[1])
    assert int(best[0]) == 2
    assert 0.0 <= float(conf[1]) <= float(conf[0]) <= 1.0
