"""Property tests for serving/batcher.py bucket math and padding.

Hypothesis-driven where available (skip cleanly otherwise via
``_hypothesis_shim``); the deterministic cases below cover the same
invariants at fixed points so tier-1 always exercises them.
"""
import numpy as np

from _hypothesis_shim import given, settings, st
from repro.serving.batcher import (BATCH_BUCKETS, LEN_BUCKETS, bucket_batch,
                                   bucket_len, floor_len_bucket,
                                   pad_to_buckets)


# ----------------------------------------------------- deterministic
def test_bucket_fixed_points():
    for b in BATCH_BUCKETS:
        assert bucket_batch(b) == b
    for l in LEN_BUCKETS:
        assert bucket_len(l) == l
        assert floor_len_bucket(l) == l


def test_bucket_rounding_direction():
    assert bucket_batch(3) == 4 and bucket_batch(65) == 128
    assert bucket_len(17) == 32 and bucket_len(1025) == 2048
    assert floor_len_bucket(17) == 16 and floor_len_bucket(1025) == 1024
    assert floor_len_bucket(7) == 7      # below smallest bucket: identity


def test_pad_to_buckets_round_trip_fixed():
    rng = np.random.default_rng(0)
    toks = rng.integers(1, 100, size=(3, 17)).astype(np.int32)
    mask = (rng.random((3, 17)) > 0.3).astype(np.float32)
    out_t, out_m, b = pad_to_buckets(toks, mask)
    assert b == 3
    assert out_t.shape == (4, 32) and out_m.shape == (4, 32)
    np.testing.assert_array_equal(out_t[:3, :17], toks)
    np.testing.assert_array_equal(out_m[:3, :17], mask)
    assert (out_m[:3, 17:] == 0).all()        # real rows: tail mask is zero
    np.testing.assert_array_equal(out_t[3], out_t[0])   # pad rows copy row 0


# -------------------------------------------------------- properties
@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=5000),
       st.integers(min_value=0, max_value=5000))
def test_bucket_functions_monotone(m, n):
    lo, hi = sorted((m, n))
    assert bucket_batch(lo) <= bucket_batch(hi)
    assert bucket_len(lo) <= bucket_len(hi)
    assert floor_len_bucket(lo) <= floor_len_bucket(hi)


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=5000))
def test_bucket_functions_idempotent_and_bounding(n):
    assert bucket_batch(bucket_batch(n)) == bucket_batch(n)
    assert bucket_len(bucket_len(n)) == bucket_len(n)
    assert bucket_batch(n) >= n and bucket_len(n) >= n
    f = floor_len_bucket(n)
    assert f <= n
    assert floor_len_bucket(f) == f
    if n >= LEN_BUCKETS[0]:
        # the clamp engine paths rely on: floor buckets never round back up
        assert bucket_len(f) == f


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=70),
       st.integers(min_value=1, max_value=1030),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_pad_to_buckets_round_trips_real_rows(b, l, seed):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, 4096, size=(b, l)).astype(np.int32)
    mask = (rng.random((b, l)) > 0.5).astype(np.float32)
    out_t, out_m, rb = pad_to_buckets(toks, mask)
    assert rb == b
    assert out_t.shape == (bucket_batch(b), bucket_len(l))
    assert out_m.shape == out_t.shape
    np.testing.assert_array_equal(out_t[:b, :l], toks)
    np.testing.assert_array_equal(out_m[:b, :l], mask)
    assert (out_m[:b, l:] == 0).all()
    assert out_m.dtype == mask.dtype and out_t.dtype == toks.dtype
