"""Fused on-device decode loop vs the host-loop oracle (DESIGN.md §8).

The fused ``lax.while_loop`` decode must be decision- and byte-identical
to the retained host-driven loop: same tokens, same per-row lengths, same
ended flags — under greedy sampling and under temperature sampling with
fixed keys — across batch/length buckets, early-EOS patterns, and both
transformer and non-transformer (SSM) architectures.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from repro.configs import get_config
from repro.models import ModelConfig, build_model
from repro.serving import GenerateConfig, Generator, SamplerConfig

VOCAB = 512
EOS = 2


@dataclasses.dataclass(frozen=True)
class _StubCfg:
    num_prefix_tokens: int = 0
    max_seq_len: int = 1024


class _ScriptedModel:
    """Deterministic stub: decode step t emits logits peaked on script[:, t].

    Gives exact control over per-row early-EOS patterns, which a randomly
    initialised LM cannot produce on demand.  Satisfies the Model decode
    contract (pure, shape-stable caches) so it runs inside the fused loop.
    """

    def __init__(self, script: np.ndarray, vocab: int = VOCAB):
        self.script = jnp.asarray(script, jnp.int32)   # (B, T)
        self.vocab = vocab
        self.cfg = _StubCfg()

    def _logits(self, step):
        idx = jnp.minimum(step, self.script.shape[1] - 1)
        return jax.nn.one_hot(self.script[:, idx], self.vocab) * 100.0

    def prefill(self, params, batch, capacity):
        return self._logits(jnp.int32(0)), {"step": jnp.zeros((), jnp.int32)}

    def decode_step(self, params, token, caches):
        step = caches["step"] + 1
        return self._logits(step), {"step": step}


def _tiny_lm(vocab=VOCAB):
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=vocab, max_seq_len=256,
                      dtype="float32")
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _generator(model, params, *, mnt=8, temperature=0.0, vocab=VOCAB):
    gc = GenerateConfig(max_new_tokens=mnt, eos_id=EOS,
                        sampler=SamplerConfig(temperature=temperature,
                                              vocab_size=vocab))
    return Generator(model, params, gc)


def _assert_equiv(gen, batch, *, mnt, seed=0):
    ft, fl, fe = gen.generate_with_lengths(batch, max_new_tokens=mnt,
                                           seed=seed, fused=True)
    ht, hl, he = gen.generate_with_lengths(batch, max_new_tokens=mnt,
                                           seed=seed, fused=False)
    np.testing.assert_array_equal(ft, ht)
    np.testing.assert_array_equal(fl, hl)
    np.testing.assert_array_equal(fe, he)
    return ft, fl, fe


def _prompt(b, s, vocab=VOCAB, seed=1):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (b, s),
                                         5, vocab)}


# ------------------------------------------------- transformer equivalence
@pytest.mark.parametrize("b,s,mnt", [(1, 8, 1), (2, 8, 6), (4, 16, 8)])
def test_fused_matches_host_greedy(b, s, mnt):
    m, p = _tiny_lm()
    gen = _generator(m, p, mnt=mnt)
    _assert_equiv(gen, _prompt(b, s), mnt=mnt)


@pytest.mark.parametrize("seed", [0, 3])
def test_fused_matches_host_temperature_fixed_keys(seed):
    m, p = _tiny_lm()
    gen = _generator(m, p, mnt=8, temperature=0.8)
    _assert_equiv(gen, _prompt(2, 8), mnt=8, seed=seed)


# ------------------------------------------------- non-transformer (SSM)
def test_fused_matches_host_mamba():
    cfg = get_config("mamba2-130m", smoke=True)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    gen = _generator(m, p, mnt=6, vocab=cfg.vocab_size)
    _assert_equiv(gen, _prompt(2, 8, vocab=cfg.vocab_size), mnt=6)


# ------------------------------------------------- early-EOS patterns
@pytest.mark.parametrize("pattern", [
    [0],              # single row, EOS at the very first token
    [2, 5, 0, 99],    # staggered finishes + one row that never finishes
    [99, 99],         # nobody finishes within budget
    [1, 1, 1],        # all rows finish together (early loop exit)
])
def test_fused_matches_host_early_eos(pattern):
    mnt = 8
    b = len(pattern)
    script = np.full((b, mnt), 7, np.int32)
    for r, at in enumerate(pattern):
        if at < mnt:
            script[r, at] = EOS
    gen = _generator(_ScriptedModel(script), None, mnt=mnt)
    toks, lengths, ended = _assert_equiv(gen, _prompt(b, 4), mnt=mnt)
    for r, at in enumerate(pattern):
        if at < mnt:
            assert ended[r] and lengths[r] == at + 1
            assert (toks[r, at:] == EOS).all()       # EOS-padded past the end
            assert (toks[r, :at] == 7).all()
        else:
            assert not ended[r] and lengths[r] == mnt


def test_finished_rows_keep_emitting_eos_while_others_run():
    """In-loop done-masking: a row whose script would resume emitting real
    tokens after its EOS must stay EOS to the end of the block."""
    mnt = 6
    script = np.array([[7, EOS, 9, 9, 9, 9],      # EOS then junk: masked
                       [7, 7, 7, 7, 7, 7]], np.int32)
    gen = _generator(_ScriptedModel(script), None, mnt=mnt)
    toks, lengths, ended = _assert_equiv(gen, _prompt(2, 4), mnt=mnt)
    assert lengths.tolist() == [2, mnt]
    assert (toks[0, 1:] == EOS).all()
    assert (toks[1] == 7).all()


# ------------------------------------------------- explicit zero budget
def test_max_new_tokens_zero_returns_empty_block():
    """Regression: `max_new_tokens or cfg.max_new_tokens` silently turned an
    explicit 0 into the config default (32 generated tokens)."""
    m, p = _tiny_lm()
    gen = _generator(m, p, mnt=8)
    toks, lengths, ended = gen.generate_with_lengths(_prompt(2, 8),
                                                     max_new_tokens=0)
    assert toks.shape == (2, 0)
    assert lengths.tolist() == [0, 0] and not ended.any()
    assert gen.generate(_prompt(2, 8), max_new_tokens=0).shape == (2, 0)
    with pytest.raises(ValueError, match="max_new_tokens"):
        gen.generate(_prompt(2, 8), max_new_tokens=-1)


def test_default_max_new_tokens_still_applies():
    m, p = _tiny_lm()
    gen = _generator(m, p, mnt=5)
    assert gen.generate(_prompt(1, 8)).shape == (1, 5)


# ------------------------------------------------- per-call seed streams
def test_unseeded_calls_use_fresh_key_streams():
    """Regression: every generate() defaulted to seed=0, so all stochastic
    serve batches replayed the identical key stream."""
    m, p = _tiny_lm()
    gen = _generator(m, p, mnt=12, temperature=1.0)
    a = gen.generate(_prompt(2, 8))
    b = gen.generate(_prompt(2, 8))
    assert (a != b).any()
    # explicit seeds remain reproducible
    c = gen.generate(_prompt(2, 8), seed=11)
    d = gen.generate(_prompt(2, 8), seed=11)
    np.testing.assert_array_equal(c, d)


# ------------------------------------------------- hypothesis property
@given(st.data())
@settings(max_examples=12, deadline=None)
def test_fused_host_equivalence_property(data):
    """Fused == host across sampled batch shapes, EOS scripts, and sampler
    temperatures (fixed keys).  Shapes are drawn from a small fixed grid so
    jit compiles stay bounded."""
    b = data.draw(st.sampled_from([1, 2, 4]), label="batch")
    mnt = data.draw(st.sampled_from([1, 4, 8]), label="mnt")
    temp = data.draw(st.sampled_from([0.0, 0.7]), label="temperature")
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 20), label="seed")
    eos_at = data.draw(st.lists(st.integers(min_value=0, max_value=mnt + 2),
                                min_size=b, max_size=b), label="eos_at")
    script = np.full((b, max(mnt, 1)), 7, np.int32)
    for r, at in enumerate(eos_at):
        if at < mnt:
            script[r, at] = EOS
    gen = _generator(_ScriptedModel(script), None, mnt=mnt,
                     temperature=temp)
    _assert_equiv(gen, _prompt(b, 8), mnt=mnt, seed=seed)
