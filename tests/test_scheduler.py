"""Continuous-batching scheduler semantics, under deterministic simulation.

Everything here runs on ``SimClock`` — zero sleeps, fully reproducible.
The acceptance properties (DESIGN.md §6):
  (a) K duplicate concurrent misses -> exactly ONE Big-LLM generation,
  (b) scheduler responses byte-identical to sequential ``handle_batch``
      on the same trace,
plus backpressure, deadlines, bucket flushes, and the service model.
"""
import jax
import pytest

from _hypothesis_shim import given, settings, st
from repro.core import CacheConfig, RouterConfig, TweakLLMEngine, router
from repro.models import ModelConfig, build_model
from repro.models.embedder import init_embedder, tiny_embedder_config
from repro.serving import (GenerateConfig, Generator, QueueFull,
                           SamplerConfig, Scheduler, SchedulerConfig,
                           SimClock, poisson_trace, replay_trace)
from repro.tokenizer import HashWordTokenizer

VOCAB = 4096


@pytest.fixture(scope="module")
def stack():
    tok = HashWordTokenizer(VOCAB)
    ecfg = tiny_embedder_config(VOCAB)
    eparams = init_embedder(jax.random.PRNGKey(0), ecfg)
    lm = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                     d_ff=64, vocab_size=VOCAB, max_seq_len=512,
                     dtype="float32")
    gc = GenerateConfig(max_new_tokens=4,
                        sampler=SamplerConfig(vocab_size=VOCAB))
    big_m = build_model(lm)
    small_m = build_model(lm)
    big = Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gc)
    small = Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gc)
    return tok, ecfg, eparams, big, small


def _engine(stack, **router_kw):
    tok, ecfg, eparams, big, small = stack
    return TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=128, dim=ecfg.d_model, topk=4),
        router_cfg=RouterConfig(**router_kw))


def _scheduler(stack, *, clock=None, service_model=None, router_kw=None,
               **cfg_kw):
    cfg_kw.setdefault("max_new_tokens", 4)
    return Scheduler(_engine(stack, **(router_kw or {})),
                     SchedulerConfig(**cfg_kw),
                     clock=clock or SimClock(), service_model=service_model)


def _sequential(stack, texts, router_kw=None):
    """Reference: one handle_batch call per request, in arrival order."""
    eng = _engine(stack, **(router_kw or {}))
    return [eng.handle_batch([t], max_new_tokens=4)[0] for t in texts], eng


# Routing config under which coalescing is provably response-preserving:
# with the TWEAK band collapsed (tweak == exact threshold), every request
# is a pure MISS (novel text) or an EXACT hit (identical text, cosine 1.0),
# and an EXACT hit returns the exact string the MISS stored.  The TWEAK
# band inherently depends on cache-visibility *timing* — a sequential
# caller sees entries inserted one request earlier, a coalesced batch does
# not — so byte-identity across dispatch shapes only holds outside it.
EXACT_OR_MISS = {"tweak_threshold": 0.9999}


class _CountingGenerator:
    """Wraps a Generator, counting generation calls and rows."""

    def __init__(self, inner):
        self._inner = inner
        self.model = inner.model
        self.calls = 0
        self.rows = 0

    def generate_with_lengths(self, batch, **kw):
        self.calls += 1
        self.rows += int(batch["tokens"].shape[0])
        return self._inner.generate_with_lengths(batch, **kw)

    def generate(self, batch, **kw):
        self.calls += 1
        self.rows += int(batch["tokens"].shape[0])
        return self._inner.generate(batch, **kw)


# ------------------------------------------------------------ (a) dedup
def test_k_duplicate_misses_one_big_generation(stack):
    sched = _scheduler(stack, max_wait=1.0, max_batch=8)
    big = _CountingGenerator(sched.engine.big)
    sched.engine.big = big
    K = 5
    reqs = [sched.submit("a novel question about orbital mechanics")
            for _ in range(K)]
    assert sched.poll() == []           # deadline not reached, bucket not full
    sched.clock.advance(1.0)
    done = sched.poll()
    assert len(done) == K and all(r.done for r in reqs)
    # exactly one Big-LLM generation for all K copies
    assert big.calls == 1 and big.rows == 1
    assert sched.engine.stats.miss == 1 and sched.engine.stats.total == 1
    # one miss + K-1 joined hits
    assert sched.stats.joined == K - 1
    assert sched.stats.dispatched == 1 and sched.stats.batches == 1
    rs = {r.response for r in reqs}
    assert len(rs) == 1 and reqs[0].response
    assert [r.joined for r in sorted(reqs, key=lambda r: r.rid)] == \
        [False] + [True] * (K - 1)


def test_dedup_never_crosses_distinct_texts(stack):
    sched = _scheduler(stack, max_wait=1.0, max_batch=8)
    big = _CountingGenerator(sched.engine.big)
    sched.engine.big = big
    a = [sched.submit("first unique question about glaciers")
         for _ in range(3)]
    b = [sched.submit("second unique question about volcanoes")
         for _ in range(2)]
    sched.clock.advance(1.0)
    sched.poll()
    # distinct texts stay distinct engine rows: 2 misses in 1 generation
    # call of 2 rows — never cross-joined into one
    assert sched.engine.stats.miss == 2
    assert big.calls == 1 and big.rows == 2
    assert sched.stats.dispatched == 2 and sched.stats.joined == 3
    # every request completed with its own text's group (primary first)
    assert [r.joined for r in a] == [False, True, True]
    assert [r.joined for r in b] == [False, True]
    assert len({r.response for r in a}) == 1
    assert len({r.response for r in b}) == 1


def test_dedup_disabled_dispatches_every_copy(stack):
    sched = _scheduler(stack, max_wait=1.0, max_batch=8, dedup=False)
    for _ in range(3):
        sched.submit("repeated question about tides")
    sched.clock.advance(1.0)
    done = sched.poll()
    assert len(done) == 3
    assert sched.stats.joined == 0 and sched.stats.dispatched == 3
    # same batch, duplicates all looked up pre-insert: each one misses
    assert sched.engine.stats.total == 3


# ------------------------------------------- (b) sequential equivalence
def test_responses_byte_identical_to_sequential(stack):
    texts = [f"numbered question {i} about area {i}" for i in range(6)]
    trace = [(0.00, texts[0]), (0.01, texts[1]), (0.02, texts[0]),
             (0.03, texts[2]), (0.30, texts[3]), (0.31, texts[0]),
             (0.32, texts[4]), (0.60, texts[5]), (0.61, texts[5])]
    sched = _scheduler(stack, max_wait=0.05, max_batch=4,
                       router_kw=EXACT_OR_MISS)
    done = sorted(replay_trace(sched, trace), key=lambda r: r.rid)
    seq, ref = _sequential(stack, [t for _, t in trace],
                           router_kw=EXACT_OR_MISS)
    assert [r.response for r in done] == seq     # byte-identical
    # stats-consistency: same misses; sequential EXACT hits show up as
    # scheduler EXACT hits or in-flight joins
    s, e = sched.stats, sched.engine.stats
    assert e.miss == ref.stats.miss
    assert e.exact + s.joined == ref.stats.exact
    assert s.completed == len(trace) and s.rejected == 0


def test_exact_repeat_after_window_hits_cache(stack):
    sched = _scheduler(stack, max_wait=0.01, max_batch=4)
    q = "question answered in an earlier window"
    done1 = replay_trace(sched, [(0.0, q)], drain=True)
    done2 = replay_trace(sched, [(10.0, q)], drain=True)
    assert done1[0].meta["decision"] == router.MISS
    assert done2[0].meta["decision"] == router.EXACT
    assert done2[0].response == done1[0].response


# ------------------------------------------------- flush triggers, time
def test_deadline_flush_and_next_wakeup(stack):
    sched = _scheduler(stack, max_wait=0.5, max_batch=8)
    assert sched.next_wakeup() is None
    r = sched.submit("waiting on the deadline")
    assert sched.next_wakeup() == pytest.approx(0.5)
    sched.clock.advance(0.49)
    assert sched.poll() == [] and not r.done
    sched.clock.advance(0.02)
    assert [x.rid for x in sched.poll()] == [r.rid]
    assert r.finish == pytest.approx(sched.clock.now())
    assert r.latency == pytest.approx(0.51)


def test_full_bucket_dispatches_immediately(stack):
    sched = _scheduler(stack, max_wait=100.0, max_batch=2)
    sched.submit("bucket filler one")
    assert sched.poll() == []
    sched.submit("bucket filler two")
    assert sched.next_wakeup() == pytest.approx(0.0)
    done = sched.poll()                  # no clock advance needed
    assert len(done) == 2 and sched.stats.batches == 1


def test_max_batch_snaps_to_bucket(stack):
    assert SchedulerConfig(max_batch=5).max_batch == 8
    assert SchedulerConfig(max_batch=8).max_batch == 8


def test_service_model_serializes_dispatches(stack):
    sched = _scheduler(stack, max_wait=0.0, max_batch=1,
                       service_model=lambda b: 1.0)
    r1 = sched.submit("served while engine busy one")
    sched.poll()
    r2 = sched.submit("served while engine busy two")
    assert sched.poll() == []            # engine busy until t=1.0
    assert sched.next_wakeup() == pytest.approx(1.0)
    sched.clock.advance_to(1.0)
    sched.poll()
    assert r1.finish == pytest.approx(1.0)
    assert r2.finish == pytest.approx(2.0)   # queued behind r1's service
    assert sched.stats.busy_time == pytest.approx(2.0)
    assert r2.latency == pytest.approx(2.0)


# ------------------------------------------------------- backpressure
def test_bounded_queue_backpressure(stack):
    sched = _scheduler(stack, max_wait=10.0, max_batch=8, queue_capacity=3)
    for i in range(3):
        sched.submit(f"queued request {i}")
    with pytest.raises(QueueFull):
        sched.submit("one too many")
    assert sched.stats.rejected == 1 and sched.stats.submitted == 3
    # duplicates count against capacity too (each holds a slot)
    sched.clock.advance(10.0)
    sched.poll()
    assert sched.pending == 0
    sched.submit("admitted again after drain")


def test_replay_sheds_rejected_arrivals(stack):
    sched = _scheduler(stack, max_wait=5.0, max_batch=64, queue_capacity=2)
    trace = [(0.0, f"flood request {i}") for i in range(4)]
    done = replay_trace(sched, trace)
    assert len(done) == 2
    assert sched.stats.rejected == 2


def test_flush_drains_everything_now(stack):
    sched = _scheduler(stack, max_wait=100.0, max_batch=2)
    reqs = [sched.submit(f"flushed request {i}") for i in range(5)]
    done = sched.flush()
    assert len(done) == 5 and all(r.done for r in reqs)
    assert sched.stats.batches == 3      # 2 + 2 + 1
    assert sched.pending == 0


class _FlakyEngine:
    """Fails the first N handle_batch_result calls, then delegates."""

    def __init__(self, inner, failures: int):
        self._inner = inner
        self._failures = failures

    def handle_batch_result(self, queries, **kw):
        if self._failures > 0:
            self._failures -= 1
            raise RuntimeError("transient engine failure")
        return self._inner.handle_batch_result(queries, **kw)


def test_engine_failure_leaves_queue_intact(stack):
    """A raising dispatch must not drop requests or leak queue capacity."""
    sched = _scheduler(stack, max_wait=0.5, max_batch=8, queue_capacity=4)
    sched.engine = _FlakyEngine(_engine(stack), failures=1)
    reqs = [sched.submit(f"retryable request {i}") for i in range(3)]
    sched.clock.advance(0.5)
    with pytest.raises(RuntimeError, match="transient"):
        sched.poll()
    # everything is still pending and countable — no capacity leak
    assert sched.pending == 3 and not any(r.done for r in reqs)
    sched.submit("fits in the remaining slot")
    with pytest.raises(QueueFull):
        sched.submit("over capacity")
    # the retry serves every original request
    done = sched.poll()
    assert len(done) == 4 and all(r.done for r in reqs)
    assert sched.pending == 0 and sched.stats.completed == 4


def test_completions_survive_a_later_dispatch_failure(stack):
    """Batch 1 completes, batch 2 raises in the SAME poll: batch 1's
    requests must still be delivered (by the next successful call)."""
    sched = _scheduler(stack, max_wait=0.0, max_batch=1)
    inner = sched.engine
    calls = {"n": 0}

    class _SecondCallFails:
        def handle_batch_result(self, queries, **kw):
            calls["n"] += 1
            if calls["n"] == 2:
                raise RuntimeError("transient engine failure")
            return inner.handle_batch_result(queries, **kw)

    sched.engine = _SecondCallFails()
    r1 = sched.submit("first batch completes fine")
    r2 = sched.submit("second batch fails transiently")
    with pytest.raises(RuntimeError, match="transient"):
        sched.poll()                     # dispatches r1, then fails on r2
    assert r1.done and not r2.done and sched.pending == 1
    done = sched.poll()                  # retry: r1 delivered late, r2 now
    assert [r.rid for r in done] == [r1.rid, r2.rid]
    assert sched.stats.completed == 2


def test_oversized_max_new_tokens_fails_before_any_state_change(stack):
    sched = _scheduler(stack, max_wait=0.0, max_batch=1,
                       max_new_tokens=10_000)
    r = sched.submit("doomed dispatch")
    with pytest.raises(ValueError, match="max_new_tokens"):
        sched.poll()
    # engine billed nothing: the dispatch failed before lookup/serve
    e = sched.engine.stats
    assert (e.total, e.miss, e.exact, e.tweak) == (0, 0, 0, 0)
    assert sched.pending == 1 and not r.done


def test_requests_carry_engine_meta(stack):
    sched = _scheduler(stack, max_wait=0.0, max_batch=1)
    r = sched.submit("request with metadata attached")
    sched.poll()
    assert r.meta["decision"] == router.MISS
    assert r.meta["gen_tokens"] >= 1
    assert sched.stats.big_tokens == r.meta["gen_tokens"]


# ------------------------------------------------- property tests
@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(min_value=0, max_value=4),
                          st.sampled_from([0.0, 0.01, 0.2])),
                min_size=1, max_size=8))
def test_property_equivalent_to_sequential(stack, trace_spec):
    """Any arrival trace: responses identical & stats consistent with the
    sequential reference, and dedup never crosses distinct texts."""
    texts = [f"property topic {i} item {i}" for i in range(5)]
    t, trace = 0.0, []
    for idx, gap in trace_spec:
        t += gap
        trace.append((t, texts[idx]))
    sched = _scheduler(stack, max_wait=0.05, max_batch=4,
                       router_kw=EXACT_OR_MISS)
    done = sorted(replay_trace(sched, trace), key=lambda r: r.rid)
    seq, ref = _sequential(stack, [q for _, q in trace],
                           router_kw=EXACT_OR_MISS)
    assert [r.response for r in done] == seq
    s, e = sched.stats, sched.engine.stats
    assert e.miss == ref.stats.miss
    assert e.exact + s.joined == ref.stats.exact
    assert s.completed == len(trace)
    # dedup never crosses distinct texts: a joined request's response is
    # always the sequential response of ITS OWN text's first occurrence
    first = {}
    for r, (_, q) in zip(done, trace):
        first.setdefault(q, r.response)
        if r.joined:
            assert r.response == first[q]


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=7))
def test_property_k_duplicates_one_generation(stack, k):
    sched = _scheduler(stack, max_wait=1.0, max_batch=8)
    big = _CountingGenerator(sched.engine.big)
    sched.engine.big = big
    for _ in range(k):
        sched.submit("property duplicate miss query")
    sched.clock.advance(1.0)
    sched.poll()
    assert big.calls == 1 and big.rows == 1
    assert sched.engine.stats.miss == 1
    assert sched.stats.joined == k - 1


# --------------------------------------------- continuous (slot) mode
@pytest.fixture(scope="module")
def paged_stack():
    """The serving stack on PAGED generators (DESIGN.md §11): same tiny
    LM, but decode runs over the page pool with the shared tweak prefix
    pinned — the stack the continuous scheduler fronts in production."""
    tok = HashWordTokenizer(VOCAB)
    ecfg = tiny_embedder_config(VOCAB)
    eparams = init_embedder(jax.random.PRNGKey(0), ecfg)
    lm = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                     d_ff=64, vocab_size=VOCAB, max_seq_len=512,
                     dtype="float32", attention_impl="xla_flash",
                     flash_block_q=16, flash_block_k=16)
    gc = GenerateConfig(max_new_tokens=4,
                        sampler=SamplerConfig(vocab_size=VOCAB),
                        paged=True, page_size=8, pool_pages=1024)
    big_m = build_model(lm)
    small_m = build_model(lm)
    big = Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gc)
    small = Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gc)
    return tok, ecfg, eparams, big, small


def test_continuous_dispatches_without_barrier(stack):
    """No max_wait hold: a lone request dispatches the moment it arrives
    if a slot is free, instead of waiting out the bucket deadline."""
    sched = _scheduler(stack, max_wait=100.0, max_batch=8,
                       continuous=True, slots=4)
    r = sched.submit("continuous request served immediately")
    assert sched.next_wakeup() == pytest.approx(0.0)
    done = sched.poll()                  # no clock advance needed
    assert [x.rid for x in done] == [r.rid] and r.done


def test_continuous_slot_occupancy_and_service_share(stack):
    """Each request holds ONE slot for service_model(slots)/slots seconds;
    a third request waits for the first slot to free, not for the whole
    batch to finish."""
    sched = _scheduler(stack, max_wait=0.0, max_batch=8, continuous=True,
                       slots=2, service_model=lambda k: 2.0 * k)
    r1 = sched.submit("slot occupant one")
    r2 = sched.submit("slot occupant two")
    r3 = sched.submit("slot occupant three")
    sched.poll()                         # r1+r2 cohort at t=0; r3 queued
    per = 2.0 * 2 / 2                    # service_model(slots)/slots
    assert r1.finish == pytest.approx(per) and r2.finish == pytest.approx(per)
    assert not r3.done
    assert sched.next_wakeup() == pytest.approx(per)
    sched.clock.advance_to(per)
    sched.poll()
    assert r3.finish == pytest.approx(2 * per)
    assert sched.stats.busy_time == pytest.approx(3 * per)


def _churn_run(paged_stack, trace, *, continuous, svc):
    cfg = (SchedulerConfig(continuous=True, slots=4, max_batch=8,
                           max_new_tokens=4)
           if continuous else
           SchedulerConfig(max_wait=0.05, max_batch=4, max_new_tokens=4))
    tok, ecfg, eparams, big, small = paged_stack
    eng = TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=128, dim=ecfg.d_model, topk=4),
        router_cfg=RouterConfig(**EXACT_OR_MISS))
    sched = Scheduler(eng, cfg, clock=SimClock(), service_model=svc)
    done = replay_trace(sched, trace)
    return {r.text: r.response for r in done}, eng, sched


def test_continuous_churn_byte_identical_to_barrier(paged_stack):
    """The satellite contract: a join/leave trace served continuously
    (requests spliced into slots as they free) yields responses AND
    EngineStats byte-identical to the batch-to-completion baseline —
    only the latency dynamics differ — with zero leaked pages."""
    texts = [f"churn workload query {i} about subject {i}" for i in range(12)]
    trace = poisson_trace(texts, rate=50.0, seed=3)
    svc = lambda k: 0.02 + 0.005 * k
    rb, eng_b, sched_b = _churn_run(paged_stack, trace, continuous=False,
                                    svc=svc)
    rc, eng_c, sched_c = _churn_run(paged_stack, trace, continuous=True,
                                    svc=svc)
    assert rb == rc and len(rb) == len(texts)
    assert eng_b.stats == eng_c.stats            # byte-identical accounting
    assert eng_b.stats.miss == len(texts)
    # zero leaked pages: every lease released at harvest
    big = paged_stack[3]
    assert big.pool is not None and big.pool.live_pages == 0
    assert sched_c.stats.completed == sched_b.stats.completed == len(texts)


def test_continuous_tweak_path_zero_leaked_pages(paged_stack):
    """Forced-TWEAK traffic through the paged small model: the pinned
    shared-prefix pages are the ONLY pages left alive after the trace."""
    tok, ecfg, eparams, big, small = paged_stack
    eng = TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=128, dim=ecfg.d_model, topk=4),
        router_cfg=RouterConfig(tweak_threshold=-1.0, exact_threshold=2.0))
    eng.populate([f"seeded question {i} on matter {i}" for i in range(3)],
                 [f"seeded answer {i}" for i in range(3)])
    sched = Scheduler(eng, SchedulerConfig(continuous=True, slots=2,
                                           max_new_tokens=4),
                      clock=SimClock())
    trace = [(0.01 * i, f"tweaked churn query {i}") for i in range(5)]
    done = replay_trace(sched, trace)
    assert len(done) == 5 and eng.stats.tweak == 5
    sp = small.pool
    assert sp is not None and sp.pinned_pages > 0
    assert sp.live_pages == sp.pinned_pages      # pins only — no leaks
    assert big.pool is None or big.pool.live_pages == 0


@settings(max_examples=5, deadline=None)
@given(st.lists(st.sampled_from([0.0, 0.005, 0.02, 0.1]),
                min_size=2, max_size=10),
       st.integers(min_value=0, max_value=2 ** 16))
def test_property_continuous_churn_equivalence(paged_stack, gaps, seed):
    """ANY arrival trace of distinct texts: continuous == barrier on
    responses and EngineStats, zero leaked pages."""
    t, trace = 0.0, []
    for i, gap in enumerate(gaps):
        t += gap
        trace.append((t, f"property churn {seed} item {i} theme {i}"))
    svc = lambda k: 0.01 + 0.002 * k
    rb, eng_b, _ = _churn_run(paged_stack, trace, continuous=False, svc=svc)
    rc, eng_c, _ = _churn_run(paged_stack, trace, continuous=True, svc=svc)
    assert rb == rc
    assert eng_b.stats == eng_c.stats
    assert paged_stack[3].pool.live_pages == 0
