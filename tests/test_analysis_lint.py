"""Layer-1 analyzer self-tests: each lint rule on a violating, a clean,
and a waived fixture — plus the repo-clean gate that makes the lint a CI
check (DESIGN.md §10).
"""
import textwrap

from repro.analysis import lint, registry
from repro.analysis.lint import check_registry, lint_source, lint_tree
from repro.analysis.registry import JitSite

HOT = "core/engine.py"      # any path inside registry.HOT_MODULES
COLD = "eval/metrics.py"    # hostsync rules must NOT fire here


def rules(src, rel=HOT):
    return [v.rule for v in lint_source(textwrap.dedent(src), rel)]


# ------------------------------------------------------------- HS1xx ----

def test_hs101_item_flagged_hot_only():
    src = """
    def f(x):
        return x.item()
    """
    assert rules(src) == ["HS101"]
    assert rules(src, rel=COLD) == []


def test_hs101_waived_on_line():
    assert rules("""
    def f(x):
        return x.item()  # hostsync: ok the one per-batch sync
    """) == []


def test_hs102_int_on_traced_flagged_static_reads_exempt():
    assert rules("""
    def f(x):
        return int(x)
    """) == ["HS102"]
    # static-under-trace spellings: literals, len(), .shape reads
    assert rules("""
    def f(x, xs):
        return int(x.shape[0]) + int(len(xs)) + int(3)
    """) == []


def test_hs103_sync_calls_flagged():
    src = """
    import numpy as np
    import jax

    def f(x):
        a = np.asarray(x)
        b = jax.device_get(x)
        x.block_until_ready()
        return a, b
    """
    assert rules(src) == ["HS103", "HS103", "HS103"]


def test_hs103_waiver_on_previous_line():
    assert rules("""
    import jax

    def f(x):
        # hostsync: ok the one per-batch sync
        return jax.device_get(x)
    """) == []


def test_hs104_bool_flagged():
    assert rules("""
    def f(x):
        return bool(x)
    """) == ["HS104"]


def test_hostsync_def_line_waiver_covers_whole_function():
    assert rules("""
    def rebuild(x):  # hostsync: ok host-driven maintenance path
        n = int(x)
        return n, x.item()
    """) == []
    # ... but it is scoped: a sibling function still gets flagged
    assert rules("""
    def rebuild(x):  # hostsync: ok host-driven maintenance path
        return int(x)

    def serve(x):
        return int(x)
    """) == ["HS102"]


# ------------------------------------------------------------- SD2xx ----

def test_sd201_hardcoded_prngkey_flagged_everywhere():
    src = """
    import jax

    def f():
        return jax.random.PRNGKey(0)
    """
    assert rules(src) == ["SD201"]
    assert rules(src, rel=COLD) == ["SD201"]     # seed rules are repo-wide


def test_sd201_threaded_seed_clean_and_waiver_works():
    assert rules("""
    import jax

    def f(seed):
        return jax.random.PRNGKey(seed)
    """) == []
    assert rules("""
    import jax

    def f():
        return jax.random.PRNGKey(1)  # seed: ok demo CLI, determinism wanted
    """) == []


def test_sd202_literal_seed_kwarg_but_not_api_default():
    assert rules("""
    def f(gen):
        return gen.generate(seed=0)
    """) == ["SD202"]
    # an API *default* is caller-overridable and stays legal
    assert rules("""
    def generate(batch, seed: int = 0):
        return batch, seed
    """) == []


def test_sd202_anchored_at_kwarg_line_in_multiline_call():
    # the waiver must work when `seed=0` sits on its own line of a
    # multi-line call — the violation anchors at the kwarg, not the call
    assert rules("""
    def f(gen, batch):
        return gen.generate(batch,
                            seed=0)  # seed: ok differential oracle replay
    """) == []


# ------------------------------------------------------------- IS301 ----

def test_is301_import_time_environ_mutation():
    src = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    """
    assert rules(src, rel=COLD) == ["IS301"]


def test_is301_config_update_and_function_scope_exempt():
    assert rules("""
    import jax
    jax.config.update("jax_enable_x64", True)
    """, rel=COLD) == ["IS301"]
    # behind a function is exactly where it should live
    assert rules("""
    import os

    def main():
        os.environ["XLA_FLAGS"] = "..."
    """, rel=COLD) == []


def test_is301_reaches_into_module_level_if():
    assert rules("""
    import os
    if "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = "..."
    """, rel=COLD) == ["IS301"]


# ------------------------------------------------------------- JR4xx ----

def _uses(src, rel=HOT):
    uses = []
    lint_source(textwrap.dedent(src), rel, collect_jit=uses)
    return uses


JIT_MODULE = """
import jax

jitted = jax.jit(lambda s, q: (s, q), donate_argnums=(0,))
"""


def test_jr401_unregistered_site():
    vs = check_registry(_uses(JIT_MODULE), table=())
    assert [v.rule for v in vs] == ["JR401"]
    assert "not in" in vs[0].msg


def test_jr402_policy_drift():
    table = (JitSite(HOT, "<module>", donate=()),)
    vs = check_registry(_uses(JIT_MODULE), table=table)
    assert [v.rule for v in vs] == ["JR402"]
    assert "donate" in vs[0].msg


def test_jr403_stale_entry():
    table = (JitSite(HOT, "<module>", donate=(0,)),
             JitSite(HOT, "gone_function"),)
    vs = check_registry(_uses(JIT_MODULE), table=table)
    assert [v.rule for v in vs] == ["JR403"]


def test_registry_match_is_clean():
    table = (JitSite(HOT, "<module>", donate=(0,)),)
    assert check_registry(_uses(JIT_MODULE), table=table) == []


def test_jr401_bare_jit_reference():
    # an aliased/stored jax.jit can't be policy-checked — flag it
    assert rules("""
    import jax
    compile_fn = jax.jit
    """, rel=COLD) == ["JR401"]


def test_decorator_and_partial_forms_are_collected():
    uses = _uses("""
    import functools
    import jax

    @jax.jit
    def plain(x):
        return x

    @functools.partial(jax.jit, static_argnames=("k",))
    def with_static(x, k):
        return x

    class Engine:
        def __init__(self):
            self._lookup = jax.jit(lambda s: s, donate_argnums=(0,))
    """)
    assert [(u.qualname, sorted(u.kwargs)) for u in uses] == [
        ("plain", []),
        ("with_static", ["static_argnames"]),
        ("Engine.__init__", ["donate_argnums"]),
    ]


# ------------------------------------------------------- repo-clean gate

def test_repo_tree_is_lint_clean():
    vs = lint_tree()
    assert vs == [], "\n".join(str(v) for v in vs)


def test_hot_set_matches_layout():
    assert registry.is_hot("core/cache.py")
    assert registry.is_hot("models/ssm.py")         # directory prefix
    assert registry.is_hot("kernels/cosine_topk/ops.py")
    assert not registry.is_hot("eval/metrics.py")
    assert not registry.is_hot("analysis/lint.py")


def test_cli_reports_clean(capsys):
    assert lint.main([]) == 0
    assert "clean" in capsys.readouterr().out
