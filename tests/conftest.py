import os
import sys

# src/ layout import without install (+ repo root for benchmarks/)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
