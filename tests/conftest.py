import os
import sys

# src/ layout import without install (+ repo root for benchmarks/,
# tests/ for the shared _hypothesis_shim helper)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))
