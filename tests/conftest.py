import os
import sys

import pytest

# src/ layout import without install (+ repo root for benchmarks/,
# tests/ for the shared _hypothesis_shim helper)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))


def pytest_addoption(parser):
    parser.addoption(
        "--sanitize", action="store_true", default=False,
        help="sanitizer-hardened mode (DESIGN.md §10): enables the "
             "@pytest.mark.sanitize tests (transfer-guard, leak-check, "
             "debug-nans) and sets jax_numpy_rank_promotion=raise "
             "process-wide so silent broadcasts fail loudly")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "sanitize: sanitizer-harness test, runs only with --sanitize")
    if config.getoption("--sanitize"):
        import jax
        jax.config.update("jax_numpy_rank_promotion", "raise")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--sanitize"):
        return
    skip = pytest.mark.skip(reason="sanitizer harness: run with --sanitize")
    for item in items:
        if "sanitize" in item.keywords:
            item.add_marker(skip)
