"""Cross-encoder reranker invariants (cascade stage 2, DESIGN.md §13).

The router cascade trusts ``score_shortlist`` to compare a query against
its cosine shortlist; these tests pin the properties that trust rests on:
scores must depend on CONTENT only (not on how inputs were padded, and
not on where a candidate sits in the shortlist), and the shortlist entry
point must agree with independent per-pair scoring.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.models.reranker import (init_reranker, score_pairs,
                                   score_shortlist, tiny_reranker_config)

CFG = tiny_reranker_config(vocab_size=512)
PARAMS = init_reranker(jax.random.PRNGKey(0), CFG)


def _tok(key, n, length, real_len=None):
    """(tokens, mask) batch with ids in [4, vocab) and ``real_len`` valid
    positions (defaults to full)."""
    toks = jax.random.randint(key, (n, length), 4, CFG.vocab_size,
                              dtype=jnp.int32)
    if real_len is None:
        mask = jnp.ones((n, length), jnp.float32)
    else:
        mask = jnp.broadcast_to(
            (jnp.arange(length)[None, :] < real_len).astype(jnp.float32),
            (n, length))
        toks = jnp.where(mask.astype(bool), toks, 0)
    return toks, mask


def test_score_pairs_shapes():
    ta, ma = _tok(jax.random.PRNGKey(1), 3, 8)
    tb, mb = _tok(jax.random.PRNGKey(2), 3, 6)
    out = score_pairs(PARAMS, ta, ma, tb, mb, CFG)
    assert out.shape == (3,)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_score_pairs_padding_independence():
    """Scores are a function of the VALID tokens only: re-padding either
    segment to a longer buffer must not move the logit (packed positions;
    float tolerance — XLA may reassociate reductions over the padding)."""
    ta, ma = _tok(jax.random.PRNGKey(3), 2, 5, real_len=5)
    tb, mb = _tok(jax.random.PRNGKey(4), 2, 4, real_len=4)
    ref = score_pairs(PARAMS, ta, ma, tb, mb, CFG)

    def pad(t, m, extra):
        return (jnp.pad(t, ((0, 0), (0, extra))),
                jnp.pad(m, ((0, 0), (0, extra))))

    for ea, eb in [(3, 0), (0, 5), (4, 2)]:
        ta2, ma2 = pad(ta, ma, ea)
        tb2, mb2 = pad(tb, mb, eb)
        got = score_pairs(PARAMS, ta2, ma2, tb2, mb2, CFG)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=f"pad a+{ea} b+{eb}")


def test_score_pairs_masked_tokens_are_invisible():
    """Garbage under the mask must not change the score."""
    ta, ma = _tok(jax.random.PRNGKey(5), 2, 6, real_len=3)
    tb, mb = _tok(jax.random.PRNGKey(6), 2, 6, real_len=4)
    ref = score_pairs(PARAMS, ta, ma, tb, mb, CFG)
    junk = jax.random.randint(jax.random.PRNGKey(7), ta.shape, 4,
                              CFG.vocab_size, dtype=jnp.int32)
    ta_junk = jnp.where(ma.astype(bool), ta, junk)
    got = score_pairs(PARAMS, ta_junk, ma, tb, mb, CFG)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_score_shortlist_matches_per_pair():
    """The batched shortlist entry point is exactly K independent
    score_pairs calls."""
    b, k, sq, sc = 2, 3, 5, 4
    qt, qm = _tok(jax.random.PRNGKey(8), b, sq)
    ct = jax.random.randint(jax.random.PRNGKey(9), (b, k, sc), 4,
                            CFG.vocab_size, dtype=jnp.int32)
    cm = jnp.ones((b, k, sc), jnp.float32)
    out = score_shortlist(PARAMS, qt, qm, ct, cm, CFG)
    assert out.shape == (b, k)
    for i in range(b):
        for j in range(k):
            ref = score_pairs(PARAMS, qt[i:i + 1], qm[i:i + 1],
                              ct[i, j][None], cm[i, j][None], CFG)
            np.testing.assert_allclose(float(out[i, j]), float(ref[0]),
                                       rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), k=st.integers(2, 5))
def test_score_shortlist_permutation_equivariant(seed, k):
    """Permuting the candidate axis permutes the scores identically — a
    candidate's score cannot depend on its position in the shortlist."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    qt, qm = _tok(k1, 2, 5)
    ct = jax.random.randint(k2, (2, k, 4), 4, CFG.vocab_size,
                            dtype=jnp.int32)
    cm = jnp.ones((2, k, 4), jnp.float32)
    perm = jax.random.permutation(k3, k)
    ref = score_shortlist(PARAMS, qt, qm, ct, cm, CFG)
    got = score_shortlist(PARAMS, qt, qm, ct[:, perm], cm[:, perm], CFG)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref)[:, perm],
                               rtol=1e-4, atol=1e-5)


def test_reranker_training_separates_duplicates():
    """A short training run must push duplicate pairs above non-duplicates
    on held-out generated pairs — the separation the cascade's second
    stage relies on inside the uncertainty band."""
    from repro.data.questions import QuestionPairGenerator
    from repro.tokenizer import HashWordTokenizer
    from repro.training.reranker_train import train_reranker

    tok = HashWordTokenizer(CFG.vocab_size)
    params = init_reranker(jax.random.PRNGKey(1), CFG)
    params, losses = train_reranker(params, CFG, tok, steps=150, batch=32,
                                    seed=0)
    # per-batch loss is noisy; compare first/last windows
    assert np.mean(losses[-10:]) < np.mean(losses[:10])
    gen = QuestionPairGenerator(seed=123)
    pairs = gen.generate(64, dup_frac=0.5, hard_frac=0.5)
    ta, ma = tok.encode_batch([a.text for a, _, _ in pairs], 24)
    tb, mb = tok.encode_batch([b.text for _, b, _ in pairs], 24)
    logits = np.asarray(score_pairs(params, jnp.asarray(ta), jnp.asarray(ma),
                                    jnp.asarray(tb), jnp.asarray(mb), CFG))
    y = np.asarray([y for _, _, y in pairs], bool)
    assert y.any() and (~y).any()
    assert logits[y].mean() > logits[~y].mean() + 0.5
