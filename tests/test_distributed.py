"""Distributed-cache and sharding tests.

These need >1 device, so they spawn a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
must keep seeing 1 device — smoke tests rely on it).
"""
import json
import os
import subprocess
import sys
import textwrap


ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import cache as cache_lib
    from repro.core.distributed import (make_distributed_insert_batch,
                                        make_distributed_lookup,
                                        shard_cache_state)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = cache_lib.CacheConfig(capacity=64, dim=16, topk=4)
    state = cache_lib.init_cache(cfg)
    key = jax.random.PRNGKey(0)
    for i in range(40):
        e = jax.random.normal(jax.random.fold_in(key, i), (cfg.dim,))
        z = jnp.zeros((cfg.max_query_tokens,), jnp.int32)
        m = jnp.ones((cfg.max_query_tokens,), jnp.float32)
        z2 = jnp.zeros((cfg.max_response_tokens,), jnp.int32)
        m2 = jnp.ones((cfg.max_response_tokens,), jnp.float32)
        state = cache_lib.insert(state, cfg, e, z, m, z2, m2)
    q = jax.random.normal(jax.random.PRNGKey(7), (5, cfg.dim))
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    # single-device reference
    ref_s, ref_i = cache_lib.lookup(state, cfg, q)
    # sharded lookup
    sstate = shard_cache_state(state, mesh)
    lookup = make_distributed_lookup(mesh, cfg)
    ds, di = lookup(sstate, q)
    ok_scores = bool(np.allclose(np.asarray(ds), np.asarray(ref_s), atol=1e-5))
    ok_idx = bool(np.array_equal(np.sort(np.asarray(di)), np.sort(np.asarray(ref_i))))
    # sharded insert_batch vs single-device insert_batch (48 rows, 40 real)
    B = 48
    embs = jax.random.normal(jax.random.PRNGKey(3), (B, cfg.dim))
    qt = jnp.ones((B, cfg.max_query_tokens), jnp.int32)
    qm = jnp.ones((B, cfg.max_query_tokens), jnp.float32)
    rt = jnp.ones((B, cfg.max_response_tokens), jnp.int32)
    rm = jnp.ones((B, cfg.max_response_tokens), jnp.float32)
    ref_state, ref_slots = cache_lib.insert_batch(
        cache_lib.init_cache(cfg), cfg, embs, qt, qm, rt, rm, 40)
    dib = make_distributed_insert_batch(mesh, cfg)
    dstate, dslots = dib(shard_cache_state(cache_lib.init_cache(cfg), mesh),
                         embs, qt, qm, rt, rm, 40)
    ok_ins = all(np.allclose(np.asarray(ref_state[k]), np.asarray(dstate[k]),
                             atol=1e-6) for k in ref_state)
    ok_slots = bool(np.array_equal(np.asarray(ref_slots), np.asarray(dslots)))
    print(json.dumps({"ok_scores": ok_scores, "ok_idx": ok_idx,
                      "ok_ins": ok_ins, "ok_slots": ok_slots,
                      "n_dev": len(jax.devices())}))
""")


def test_distributed_lookup_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_dev"] == 8
    assert res["ok_scores"], res
    assert res["ok_idx"], res
    assert res["ok_ins"], res
    assert res["ok_slots"], res


_IVF_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import cache as cache_lib
    from repro.core import index as index_lib
    from repro.core.distributed import (make_distributed_insert_batch,
                                        make_distributed_ivf_lookup,
                                        shard_ivf_cache_state)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    flat_cfg = cache_lib.CacheConfig(capacity=64, dim=16, topk=4)
    # nprobe == nclusters -> must be score/decision-identical to flat
    cfg = cache_lib.CacheConfig(capacity=64, dim=16, topk=4, index="ivf",
                                nclusters=8, nprobe=8)
    B = 80  # 70 real rows laps capacity 64 -> overwrite/stale churn
    embs = jax.random.normal(jax.random.PRNGKey(0), (B, cfg.dim))
    qt = jnp.zeros((B, cfg.max_query_tokens), jnp.int32)
    qm = jnp.ones((B, cfg.max_query_tokens), jnp.float32)
    rt = jnp.zeros((B, cfg.max_response_tokens), jnp.int32)
    rm = jnp.ones((B, cfg.max_response_tokens), jnp.float32)
    state, _ = cache_lib.insert_batch(cache_lib.init_cache(cfg), cfg,
                                      embs, qt, qm, rt, rm, 70)
    q = embs[40:60] / jnp.linalg.norm(embs[40:60], axis=-1, keepdims=True)
    ref_s, ref_i = cache_lib.lookup(state, flat_cfg, q)
    # rebuilt index, sharded layout, distributed two-stage lookup
    sstate = shard_ivf_cache_state(index_lib.build_index(state, cfg, seed=0),
                                   mesh, cfg)
    dl = make_distributed_ivf_lookup(mesh, cfg)
    ds, di = dl(sstate, q)
    ok_scores = bool(np.allclose(np.asarray(ds), np.asarray(ref_s), atol=1e-5))
    ok_idx = bool(np.array_equal(np.asarray(di), np.asarray(ref_i)))
    # sharded IVF insert path from empty must agree with the flat oracle too
    dib = make_distributed_insert_batch(mesh, cfg)
    s1, slots = dib(shard_ivf_cache_state(cache_lib.init_cache(cfg), mesh, cfg),
                    embs, qt, qm, rt, rm, 70)
    ref_state, ref_slots = cache_lib.insert_batch(
        cache_lib.init_cache(cfg), cfg, embs, qt, qm, rt, rm, 70)
    ds2, di2 = dl(s1, q)
    ok_ins = (bool(np.array_equal(np.asarray(slots), np.asarray(ref_slots)))
              and int(s1["ivf_pending"]) == int(ref_state["ivf_pending"])
              and bool(np.allclose(np.asarray(ds2), np.asarray(ref_s),
                                   atol=1e-5))
              and bool(np.array_equal(np.asarray(di2), np.asarray(ref_i))))
    print(json.dumps({"ok_scores": ok_scores, "ok_idx": ok_idx,
                      "ok_ins": ok_ins, "n_dev": len(jax.devices())}))
""")


def test_distributed_ivf_matches_flat():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", _IVF_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["n_dev"] == 8
    assert res["ok_scores"], res
    assert res["ok_idx"], res
    assert res["ok_ins"], res


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    import jax
    from repro.launch.mesh import make_production_mesh
    m1 = make_production_mesh()
    m2 = make_production_mesh(multi_pod=True)
    print(json.dumps({
        "single": [list(m1.axis_names), [int(m1.shape[a]) for a in m1.axis_names]],
        "multi": [list(m2.axis_names), [int(m2.shape[a]) for a in m2.axis_names]],
    }))
""")


def test_production_mesh_shapes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["single"] == [["data", "model"], [16, 16]]
    assert res["multi"] == [["pod", "data", "model"], [2, 16, 16]]


def test_sharding_specs_divisibility():
    """Every generated spec must divide the production mesh axes."""
    import jax
    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.launch import sharding as shd
    from repro.launch.shapes import abstract_params

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")

    mesh = FakeMesh()
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        params = abstract_params(cfg)
        specs = shd.param_specs(mesh, params)
        from jax.sharding import PartitionSpec
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, PartitionSpec))
        import numpy as np
        for p, s in zip(flat_p, flat_s):
            for dim, ax in zip(p.shape, tuple(s)):
                if ax is None:
                    continue
                names = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([mesh.shape[n] for n in names]))
                assert dim % size == 0, (arch, p.shape, tuple(s))
