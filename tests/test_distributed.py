"""Distributed-cache and sharding tests.

These need a fresh device count, so they spawn a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=N (the main test process
must keep seeing 1 device by default — smoke tests rely on it; the
tier1-multidevice lane additionally runs the in-process suites under 8
forced devices, see tests/test_replicas.py).
"""
import json
import os
import subprocess
import sys
import textwrap


ROOT = os.path.join(os.path.dirname(__file__), "..")

_PREAMBLE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_dev}"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
""")


def run_device_script(body: str, *, n_dev: int = 8, timeout: int = 600):
    """Run ``body`` in a fresh interpreter with ``n_dev`` forced host devices.

    The body inherits the preamble's ``os/json/jax/jnp/np`` imports and
    must ``print(json.dumps({...}))`` as its LAST stdout line; the parsed
    dict is returned.  Failures raise with the subprocess stderr in the
    assertion message (a bare returncode check used to surface as a JSON
    decode error on empty stdout).
    """
    script = _PREAMBLE.format(n_dev=n_dev) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (
        f"device-script subprocess failed (rc={out.returncode}), stderr:\n"
        f"{out.stderr[-4000:]}")
    lines = out.stdout.strip().splitlines()
    assert lines, f"no stdout from device script; stderr:\n{out.stderr[-4000:]}"
    return json.loads(lines[-1])


def test_distributed_lookup_matches_single_device():
    res = run_device_script("""
        from repro.core import cache as cache_lib
        from repro.core.distributed import (make_distributed_insert_batch,
                                            make_distributed_lookup,
                                            shard_cache_state)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = cache_lib.CacheConfig(capacity=64, dim=16, topk=4)
        state = cache_lib.init_cache(cfg)
        key = jax.random.PRNGKey(0)
        for i in range(40):
            e = jax.random.normal(jax.random.fold_in(key, i), (cfg.dim,))
            z = jnp.zeros((cfg.max_query_tokens,), jnp.int32)
            m = jnp.ones((cfg.max_query_tokens,), jnp.float32)
            z2 = jnp.zeros((cfg.max_response_tokens,), jnp.int32)
            m2 = jnp.ones((cfg.max_response_tokens,), jnp.float32)
            state = cache_lib.insert(state, cfg, e, z, m, z2, m2)
        q = jax.random.normal(jax.random.PRNGKey(7), (5, cfg.dim))
        q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
        # single-device reference
        ref_s, ref_i = cache_lib.lookup(state, cfg, q)
        # sharded lookup
        sstate = shard_cache_state(state, mesh)
        lookup = make_distributed_lookup(mesh, cfg)
        ds, di = lookup(sstate, q)
        ok_scores = bool(np.allclose(np.asarray(ds), np.asarray(ref_s),
                                     atol=1e-5))
        ok_idx = bool(np.array_equal(np.sort(np.asarray(di)),
                                     np.sort(np.asarray(ref_i))))
        # sharded insert_batch vs single-device insert_batch (40 real rows)
        B = 48
        embs = jax.random.normal(jax.random.PRNGKey(3), (B, cfg.dim))
        qt = jnp.ones((B, cfg.max_query_tokens), jnp.int32)
        qm = jnp.ones((B, cfg.max_query_tokens), jnp.float32)
        rt = jnp.ones((B, cfg.max_response_tokens), jnp.int32)
        rm = jnp.ones((B, cfg.max_response_tokens), jnp.float32)
        ref_state, ref_slots = cache_lib.insert_batch(
            cache_lib.init_cache(cfg), cfg, embs, qt, qm, rt, rm, 40)
        dib = make_distributed_insert_batch(mesh, cfg)
        dstate, dslots = dib(
            shard_cache_state(cache_lib.init_cache(cfg), mesh),
            embs, qt, qm, rt, rm, 40)
        ok_ins = all(np.allclose(np.asarray(ref_state[k]),
                                 np.asarray(dstate[k]), atol=1e-6)
                     for k in ref_state)
        ok_slots = bool(np.array_equal(np.asarray(ref_slots),
                                       np.asarray(dslots)))
        print(json.dumps({"ok_scores": ok_scores, "ok_idx": ok_idx,
                          "ok_ins": ok_ins, "ok_slots": ok_slots,
                          "n_dev": len(jax.devices())}))
    """)
    assert res["n_dev"] == 8
    assert res["ok_scores"], res
    assert res["ok_idx"], res
    assert res["ok_ins"], res
    assert res["ok_slots"], res


def test_distributed_ivf_matches_flat():
    res = run_device_script("""
        from repro.core import cache as cache_lib
        from repro.core import index as index_lib
        from repro.core.distributed import (make_distributed_insert_batch,
                                            make_distributed_ivf_lookup,
                                            shard_ivf_cache_state)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        flat_cfg = cache_lib.CacheConfig(capacity=64, dim=16, topk=4)
        # nprobe == nclusters -> must be score/decision-identical to flat
        cfg = cache_lib.CacheConfig(capacity=64, dim=16, topk=4, index="ivf",
                                    nclusters=8, nprobe=8)
        B = 80  # 70 real rows laps capacity 64 -> overwrite/stale churn
        embs = jax.random.normal(jax.random.PRNGKey(0), (B, cfg.dim))
        qt = jnp.zeros((B, cfg.max_query_tokens), jnp.int32)
        qm = jnp.ones((B, cfg.max_query_tokens), jnp.float32)
        rt = jnp.zeros((B, cfg.max_response_tokens), jnp.int32)
        rm = jnp.ones((B, cfg.max_response_tokens), jnp.float32)
        state, _ = cache_lib.insert_batch(cache_lib.init_cache(cfg), cfg,
                                          embs, qt, qm, rt, rm, 70)
        q = embs[40:60] / jnp.linalg.norm(embs[40:60], axis=-1, keepdims=True)
        ref_s, ref_i = cache_lib.lookup(state, flat_cfg, q)
        # rebuilt index, sharded layout, distributed two-stage lookup
        sstate = shard_ivf_cache_state(
            index_lib.build_index(state, cfg, seed=0), mesh, cfg)
        dl = make_distributed_ivf_lookup(mesh, cfg)
        ds, di = dl(sstate, q)
        ok_scores = bool(np.allclose(np.asarray(ds), np.asarray(ref_s),
                                     atol=1e-5))
        ok_idx = bool(np.array_equal(np.asarray(di), np.asarray(ref_i)))
        # sharded IVF insert path from empty must agree with the flat oracle
        dib = make_distributed_insert_batch(mesh, cfg)
        s1, slots = dib(
            shard_ivf_cache_state(cache_lib.init_cache(cfg), mesh, cfg),
            embs, qt, qm, rt, rm, 70)
        ref_state, ref_slots = cache_lib.insert_batch(
            cache_lib.init_cache(cfg), cfg, embs, qt, qm, rt, rm, 70)
        ds2, di2 = dl(s1, q)
        ok_ins = (bool(np.array_equal(np.asarray(slots),
                                      np.asarray(ref_slots)))
                  and int(s1["ivf_pending"]) == int(ref_state["ivf_pending"])
                  and bool(np.allclose(np.asarray(ds2), np.asarray(ref_s),
                                       atol=1e-5))
                  and bool(np.array_equal(np.asarray(di2),
                                          np.asarray(ref_i))))
        print(json.dumps({"ok_scores": ok_scores, "ok_idx": ok_idx,
                          "ok_ins": ok_ins, "n_dev": len(jax.devices())}))
    """)
    assert res["n_dev"] == 8
    assert res["ok_scores"], res
    assert res["ok_idx"], res
    assert res["ok_ins"], res


def test_distributed_lookup_and_touch_matches_local():
    """The fused sharded lookup+route+touch (DESIGN.md §12) must reproduce
    cache.lookup_and_touch exactly: scores, decisions, AND the recency
    scatter on the row-sharded arrays — for both flat and IVF banks."""
    res = run_device_script("""
        import functools
        from repro.core import cache as cache_lib
        from repro.core import index as index_lib
        from repro.core import router as router_lib
        from repro.core.distributed import (
            make_distributed_lookup_and_touch, shard_cache_state,
            shard_ivf_cache_state)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rcfg = router_lib.RouterConfig()
        out = {"n_dev": len(jax.devices())}
        for name, cfg in [
            ("flat", cache_lib.CacheConfig(capacity=64, dim=16, topk=4)),
            ("ivf", cache_lib.CacheConfig(capacity=64, dim=16, topk=4,
                                          index="ivf", nclusters=8,
                                          nprobe=8)),
        ]:
            B = 48
            embs = jax.random.normal(jax.random.PRNGKey(0), (B, cfg.dim))
            qt = jnp.zeros((B, cfg.max_query_tokens), jnp.int32)
            qm = jnp.ones((B, cfg.max_query_tokens), jnp.float32)
            rt = jnp.zeros((B, cfg.max_response_tokens), jnp.int32)
            rm = jnp.ones((B, cfg.max_response_tokens), jnp.float32)
            state, _ = cache_lib.insert_batch(cache_lib.init_cache(cfg), cfg,
                                              embs, qt, qm, rt, rm, 40)
            if cfg.index == "ivf":
                state = index_lib.build_index(state, cfg, seed=0)
            # queries straddling the EXACT/TWEAK/MISS bands: 8 cached rows
            # (EXACT), 8 fresh gaussians (mostly MISS/TWEAK)
            q = jnp.concatenate([
                state["emb"][:8],
                jax.random.normal(jax.random.PRNGKey(5), (8, cfg.dim))])
            q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
            lt_local = jax.jit(functools.partial(
                cache_lib.lookup_and_touch, cfg=cfg, router_cfg=rcfg))
            ref_state, ref_s, ref_i, ref_d = lt_local(dict(state), q_embs=q)
            cost = jnp.full((q.shape[0],), rcfg.default_cost, jnp.float32)
            (_, _, _, nref_d, nref_tau, nref_cl, nref_ad) = \\
                cache_lib.lookup_route_touch(dict(state), cfg, rcfg, q, cost)
            sstate = (shard_ivf_cache_state(state, mesh, cfg)
                      if cfg.index == "ivf"
                      else shard_cache_state(state, mesh))
            lt = make_distributed_lookup_and_touch(mesh, cfg, rcfg)
            new, ds, di, dd, dtau, dcl, dad = lt(sstate, q, cost)
            out[name] = {
                "scores": bool(np.allclose(np.asarray(ds),
                                           np.asarray(ref_s), atol=1e-5)),
                "idx": bool(np.array_equal(np.asarray(di)[:, 0],
                                           np.asarray(ref_i)[:, 0])),
                "decisions": bool(np.array_equal(np.asarray(dd),
                                                 np.asarray(ref_d))),
                "last_used": bool(np.array_equal(
                    np.asarray(new["last_used"]),
                    np.asarray(ref_state["last_used"]))),
                "hits": bool(np.array_equal(np.asarray(new["hits"]),
                                            np.asarray(ref_state["hits"]))),
                "clock": int(new["clock"]) == int(ref_state["clock"]),
                # band=0 at the default cost: the new cascade path must
                # reproduce the legacy decisions bit-for-bit, and the
                # sharded cascade outputs must match the local ones
                "new_path_legacy": bool(np.array_equal(np.asarray(nref_d),
                                                       np.asarray(ref_d))),
                "tau": bool(np.allclose(np.asarray(dtau),
                                        np.asarray(nref_tau), atol=1e-6)),
                "cluster": bool(np.array_equal(np.asarray(dcl),
                                               np.asarray(nref_cl))),
                "admit": bool(np.array_equal(np.asarray(dad),
                                             np.asarray(nref_ad))),
            }
        print(json.dumps(out))
    """)
    assert res["n_dev"] == 8
    for name in ("flat", "ivf"):
        assert all(res[name].values()), (name, res[name])


def test_distributed_cascade_matches_local():
    """Sharded stage-1 cascade routing (uncertainty band > 0, varying
    per-request cost) must be decision-identical to the local
    lookup_route_touch — the cascade runs AFTER the all_gather top-k
    merge, so both paths score the same merged shortlist — and the
    replicated admission EMA must evolve identically (DESIGN.md §13)."""
    res = run_device_script("""
        from repro.core import cache as cache_lib
        from repro.core import index as index_lib
        from repro.core import router as router_lib
        from repro.core.distributed import (
            make_distributed_lookup_and_touch, shard_ivf_cache_state)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rcfg = router_lib.RouterConfig(band=0.2, admit_floor=0.4,
                                       admit_min=1)
        cfg = cache_lib.CacheConfig(capacity=64, dim=16, topk=4,
                                    index="ivf", nclusters=8, nprobe=8)
        B = 48
        embs = jax.random.normal(jax.random.PRNGKey(0), (B, cfg.dim))
        qt = jnp.zeros((B, cfg.max_query_tokens), jnp.int32)
        qm = jnp.ones((B, cfg.max_query_tokens), jnp.float32)
        rt = jnp.zeros((B, cfg.max_response_tokens), jnp.int32)
        rm = jnp.ones((B, cfg.max_response_tokens), jnp.float32)
        state, _ = cache_lib.insert_batch(cache_lib.init_cache(cfg), cfg,
                                          embs, qt, qm, rt, rm, 40)
        state = index_lib.build_index(state, cfg, seed=0)
        # exact hits, band-straddling perturbations, and cold misses
        q = jnp.concatenate([
            state["emb"][:8],
            0.9 * state["emb"][8:16]
            + 0.45 * jax.random.normal(jax.random.PRNGKey(5), (8, cfg.dim)),
            jax.random.normal(jax.random.PRNGKey(6), (8, cfg.dim))])
        q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
        cost = jnp.linspace(0.0, 1.0, q.shape[0]).astype(jnp.float32)
        (ref_state, _, _, ref_d, ref_tau, ref_cl, ref_ad) = \\
            cache_lib.lookup_route_touch(dict(state), cfg, rcfg, q, cost)
        lt = make_distributed_lookup_and_touch(mesh, cfg, rcfg)
        new, ds, di, dd, dtau, dcl, dad = lt(
            shard_ivf_cache_state(state, mesh, cfg), q, cost)
        print(json.dumps({
            "n_dev": len(jax.devices()),
            "n_uncertain": int((np.asarray(ref_d)
                                == router_lib.UNCERTAIN).sum()),
            "decisions": bool(np.array_equal(np.asarray(dd),
                                             np.asarray(ref_d))),
            "tau": bool(np.allclose(np.asarray(dtau), np.asarray(ref_tau),
                                    atol=1e-6)),
            "cluster": bool(np.array_equal(np.asarray(dcl),
                                           np.asarray(ref_cl))),
            "admit": bool(np.array_equal(np.asarray(dad),
                                         np.asarray(ref_ad))),
            "adm_ema": bool(np.allclose(np.asarray(new["adm_ema"]),
                                        np.asarray(ref_state["adm_ema"]),
                                        atol=1e-6)),
            "adm_count": bool(np.array_equal(
                np.asarray(new["adm_count"]),
                np.asarray(ref_state["adm_count"]))),
        }))
    """)
    assert res["n_dev"] == 8
    assert res["n_uncertain"] > 0, res       # the band is actually exercised
    for k in ("decisions", "tau", "cluster", "admit", "adm_ema",
              "adm_count"):
        assert res[k], (k, res)


def test_sharded_bank_cross_replica_visibility():
    """Two engines on one SHARDED bank: replica 0's miss-commit must be an
    EXACT hit for replica 1 on its very next lookup (DESIGN.md §12)."""
    res = run_device_script("""
        from repro.core import CacheConfig, ReplicaGroup, RouterConfig
        from repro.core.engine import SharedCacheBank, TweakLLMEngine
        from repro.launch.mesh import make_cache_mesh
        from repro.launch.serve import build_stack

        stack = build_stack(capacity=64, train_embedder_steps=0,
                            threshold=1.1)  # EXACT-or-MISS routing
        cache_cfg = stack.pop("cache_cfg")
        router_cfg = stack.pop("router_cfg")
        mesh = make_cache_mesh(4)
        group = ReplicaGroup.build(2, cache_cfg=cache_cfg,
                                   router_cfg=router_cfg, mesh=mesh, **stack)
        r0, r1 = group.engines
        text = "what is the airspeed of an unladen swallow"
        a = r0.handle_batch([text], max_new_tokens=4)
        b = r1.handle_batch([text], max_new_tokens=4)
        print(json.dumps({
            "n_dev": len(jax.devices()),
            "sharded": group.bank.sharded,
            "same_response": a == b,
            "r0": [r0.stats.miss, r0.stats.exact],
            "r1": [r1.stats.miss, r1.stats.exact],
            "agg": [group.stats.miss, group.stats.exact, group.stats.total],
        }))
    """, timeout=900)
    assert res["n_dev"] == 8
    assert res["sharded"]
    assert res["same_response"], res
    assert res["r0"] == [1, 0], res       # replica 0 took the miss
    assert res["r1"] == [0, 1], res       # replica 1 hit replica 0's write
    assert res["agg"] == [1, 1, 2], res


def test_production_mesh_shapes():
    res = run_device_script("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print(json.dumps({
            "single": [list(m1.axis_names),
                       [int(m1.shape[a]) for a in m1.axis_names]],
            "multi": [list(m2.axis_names),
                      [int(m2.shape[a]) for a in m2.axis_names]],
        }))
    """, n_dev=512)
    assert res["single"] == [["data", "model"], [16, 16]]
    assert res["multi"] == [["pod", "data", "model"], [2, 16, 16]]


def test_sharding_specs_divisibility():
    """Every generated spec must divide the production mesh axes."""
    import jax
    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.launch import sharding as shd
    from repro.launch.shapes import abstract_params

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
        axis_names = ("pod", "data", "model")

    mesh = FakeMesh()
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        params = abstract_params(cfg)
        specs = shd.param_specs(mesh, params)
        from jax.sharding import PartitionSpec
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, PartitionSpec))
        import numpy as np
        for p, s in zip(flat_p, flat_s):
            for dim, ax in zip(p.shape, tuple(s)):
                if ax is None:
                    continue
                names = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([mesh.shape[n] for n in names]))
                assert dim % size == 0, (arch, p.shape, tuple(s))
