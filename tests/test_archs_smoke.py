"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import build_model
from repro.training import AdamWConfig, init_opt_state, make_train_step

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.family in ("audio", "encdec"):
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_frames, cfg.d_model))
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 5 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = model.forward(params, batch)
    want_s = S + (cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (B, want_s, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    opt = init_opt_state(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, caches = model.prefill(params, batch, capacity=S + 8 +
                                   (cfg.num_prefix_tokens or 0))
    assert logits.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    logits2, caches = model.decode_step(params, tok, caches)
    assert logits2.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_exact_config_specs():
    """The full configs match the assigned table exactly."""
    spec = {
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "mamba2-130m": (24, 768, 1, 1, 0, 50280),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
    }
    for arch, (l, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == l, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch
    # MoE extras
    assert get_config("arctic-480b").num_experts == 128
    assert get_config("arctic-480b").experts_per_token == 2
    assert get_config("arctic-480b").moe_dense_residual
    assert get_config("qwen3-moe-235b-a22b").num_experts == 128
    assert get_config("qwen3-moe-235b-a22b").experts_per_token == 8
    assert get_config("mamba2-130m").ssm_state == 128
