"""Differential suite for the paged KV pool (DESIGN.md §11).

The contract under test is BITWISE, not approximate: paged decode
gathers K/V pages back into logical-slot order through the block table
(pure data movement) and runs the identical attend, so

  paged fused decode == dense fused decode == host-stepped oracle

as exact token/length/ended equality, across batch sizes, length
buckets and page sizes — including the degenerate page_size=1 and the
pinned shared-prefix path.  On top of that sit allocator unit tests
(refcounts, exhaustion-raises-not-corrupts) and the ``DecodeSession``
mid-flight join/leave differentials.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from repro.models import ModelConfig, build_model
from repro.serving import GenerateConfig, Generator, SamplerConfig
from repro.serving.continuous import DecodeSession, NoFreeSlots
from repro.serving.paged_kv import (PagePool, PagePoolConfig,
                                    PagePoolExhausted)

VOCAB = 128
EOS = 2
MNT = 6


@pytest.fixture(scope="module")
def model_and_params():
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
                      d_ff=64, vocab_size=VOCAB, max_seq_len=256,
                      dtype="float32", attention_impl="xla_flash",
                      flash_block_q=16, flash_block_k=16)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _gen(model_and_params, *, paged=False, page_size=8, pool_pages=0,
         temp=0.0, mnt=MNT):
    model, params = model_and_params
    gc = GenerateConfig(
        max_new_tokens=mnt, eos_id=EOS,
        sampler=SamplerConfig(temperature=temp, vocab_size=VOCAB),
        paged=paged, page_size=page_size, pool_pages=pool_pages)
    return Generator(model, params, gc)


def _prompts(batch, s, seed=0):
    rng = np.random.default_rng(seed)
    return np.asarray(rng.integers(3, VOCAB, size=(batch, s)), np.int32)


def _triple(gen, toks, **kw):
    t, l, e = gen.generate_with_lengths({"tokens": jnp.asarray(toks)}, **kw)
    return np.asarray(t), np.asarray(l), np.asarray(e)


def _assert_bitwise(a, b):
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------- paged == dense, bitwise
@pytest.mark.parametrize("page_size", [1, 4, 16])
@pytest.mark.parametrize("batch,s", [(1, 3), (3, 7)])
def test_paged_fused_bitwise_equals_dense(model_and_params, page_size,
                                          batch, s):
    dense = _gen(model_and_params)
    paged = _gen(model_and_params, paged=True, page_size=page_size)
    toks = _prompts(batch, s, seed=batch * 100 + s)
    _assert_bitwise(_triple(paged, toks, seed=5), _triple(dense, toks, seed=5))
    assert paged.pool.live_pages == 0          # lease released


def test_paged_host_oracle_bitwise_equals_dense(model_and_params):
    dense = _gen(model_and_params)
    paged = _gen(model_and_params, paged=True, page_size=4)
    toks = _prompts(3, 7, seed=1)
    ref = _triple(dense, toks, seed=9)
    _assert_bitwise(_triple(paged, toks, seed=9), ref)
    _assert_bitwise(_triple(paged, toks, seed=9, fused=False), ref)
    assert paged.pool.live_pages == 0


def test_paged_temperature_sampling_bitwise(model_and_params):
    dense = _gen(model_and_params, temp=0.9)
    paged = _gen(model_and_params, paged=True, page_size=4, temp=0.9)
    toks = _prompts(3, 7, seed=2)
    _assert_bitwise(_triple(paged, toks, seed=7), _triple(dense, toks, seed=7))


def test_paged_prefix_cache_pins_and_matches_dense(model_and_params):
    """Shared-prefix path: full prefix pages pinned ONCE, shared by every
    row, responses bitwise-equal to the dense prefix path."""
    rng = np.random.default_rng(3)
    pre_ids = [int(x) for x in rng.integers(3, VOCAB, size=11)]
    dense = _gen(model_and_params)
    paged = _gen(model_and_params, paged=True, page_size=4, pool_pages=64)
    pc_d = dense.build_prefix_cache(pre_ids, batch=3)
    pc_p = paged.build_prefix_cache(pre_ids, batch=3)
    sfx = _prompts(3, 5, seed=4)
    ref = _triple(dense, sfx, seed=9, prefix_cache=pc_d)
    got = _triple(paged, sfx, seed=9, prefix_cache=pc_p)
    _assert_bitwise(got, ref)
    # 11 tokens at page 4 -> 2 full pages pinned; remainder rides private
    assert paged.pool.pinned_pages == 2
    assert paged.pool.live_pages == 2          # only the pins persist
    # the pin is cached by token ids: a second call allocates no new pins
    got2 = _triple(paged, sfx, seed=9, prefix_cache=pc_p)
    _assert_bitwise(got2, ref)
    assert paged.pool.pinned_pages == 2 and paged.pool.live_pages == 2


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=3),      # batch
       st.integers(min_value=1, max_value=9),      # prompt length
       st.sampled_from([1, 4, 8]),                 # page size
       st.integers(min_value=0, max_value=2 ** 16))  # seed
def test_property_paged_bitwise_any_shape(model_and_params, batch, s,
                                          page_size, seed):
    dense = _gen(model_and_params)
    paged = _gen(model_and_params, paged=True, page_size=page_size,
                 pool_pages=64)
    toks = _prompts(batch, s, seed=seed)
    _assert_bitwise(_triple(paged, toks, seed=seed),
                    _triple(dense, toks, seed=seed))
    assert paged.pool.live_pages == 0


# -------------------------------------------------------- allocator unit
def test_pool_alloc_free_refcount(model_and_params):
    model, _ = model_and_params
    pool = PagePool(model, PagePoolConfig(page_size=4, num_pages=8))
    a = pool.alloc(3)
    assert pool.live_pages == 3 and pool.free_pages == 5
    assert (pool.refcounts()[a] == 1).all()
    pool.incref(a)
    pool.decref(a)
    assert pool.live_pages == 3                # still held by first ref
    pool.decref(a)
    assert pool.live_pages == 0 and pool.free_pages == 8
    with pytest.raises(RuntimeError, match="over-freed"):
        pool.decref(a[:1])


def test_pool_exhaustion_raises_before_mutation(model_and_params):
    model, _ = model_and_params
    pool = PagePool(model, PagePoolConfig(page_size=4, num_pages=4))
    a = pool.alloc(3)
    rc = pool.refcounts()
    with pytest.raises(PagePoolExhausted):
        pool.alloc(2)
    # nothing corrupted: refcounts and free list exactly as before
    assert (pool.refcounts() == rc).all() and pool.free_pages == 1
    b = pool.alloc(1)                          # the survivor still allocates
    pool.decref(a)
    pool.decref(b)
    assert pool.free_pages == 4


def test_block_table_exhaustion_is_all_or_nothing(model_and_params):
    model, _ = model_and_params
    pool = PagePool(model, PagePoolConfig(page_size=4, num_pages=6))
    with pytest.raises(PagePoolExhausted):
        pool.alloc_block_table(batch=4, capacity=8)   # needs 8 > 6
    assert pool.live_pages == 0 and pool.free_pages == 6
    tbl, writable = pool.alloc_block_table(batch=3, capacity=8)
    assert tbl.shape == (3, 2) and writable.all()
    assert pool.live_pages == 6
    pool.free_block_table(tbl, writable)
    assert pool.live_pages == 0


def test_pinned_prefix_sharing_refcounts(model_and_params):
    """Pinned pages are shared (refcount += batch), freed back to exactly
    the pin's own reference, and released by unpin."""
    model, _ = model_and_params
    dense = _gen(model_and_params)
    pool = PagePool(model, PagePoolConfig(page_size=4, num_pages=32))
    pc = dense.build_prefix_cache(list(range(3, 14)), batch=3)  # 11 tokens
    pin = pool.ensure_pinned(pc)
    assert pin is not None and len(pin.ids) == 2 and pin.tokens == 8
    assert (pool.refcounts()[pin.ids] == 1).all()
    tbl, writable = pool.alloc_block_table(batch=3, capacity=16, pin=pin)
    # pinned head shared by every row, read-only; private tail writable
    assert (tbl[:, :2] == pin.ids).all() and not writable[:, :2].any()
    assert writable[:, 2:].all()
    assert (pool.refcounts()[pin.ids] == 4).all()   # 1 pin + 3 rows
    pool.free_block_table(tbl, writable)
    assert (pool.refcounts()[pin.ids] == 1).all()
    assert pool.live_pages == pool.pinned_pages == 2
    pool.unpin(pin.key)
    assert pool.live_pages == 0 and pool.pinned_pages == 0
    # same token ids re-pin from cache state, new call allocates again
    assert pool.ensure_pinned(pc) is not None


def test_pool_exhaustion_in_generate_leaves_pool_clean(model_and_params):
    paged = _gen(model_and_params, paged=True, page_size=4, pool_pages=4)
    small = _prompts(1, 3, seed=5)
    _triple(paged, small, seed=0)              # builds the 4-page pool
    big = _prompts(4, 7, seed=6)               # needs 4 * 4 = 16 pages
    with pytest.raises(PagePoolExhausted):
        _triple(paged, big, seed=0)
    assert paged.pool.live_pages == 0          # nothing leaked
    _triple(paged, small, seed=0)              # pool still serves


# ------------------------------------------------- DecodeSession churn
def test_session_inaugural_cohort_bitwise_equals_dense(model_and_params):
    """A cohort filling every slot at step 0, run to completion, replays
    the dense fused loop bitwise — prefill, key schedule, sampling."""
    dense = _gen(model_and_params)
    toks = _prompts(3, 7, seed=7)
    cap = 7 + MNT + 1                          # the dense capacity rule
    ref_t, ref_l, ref_e = _triple(dense, toks, seed=5)
    sess = DecodeSession(_gen(model_and_params), slots=3, capacity=cap,
                         seed=5)
    sess.admit(toks, tags=["a", "b", "c"])
    fins = sorted(sess.drain(), key=lambda f: f["slot"])
    np.testing.assert_array_equal(np.stack([f["tokens"] for f in fins]),
                                  ref_t)
    assert [f["length"] for f in fins] == ref_l.tolist()
    assert [f["ended"] for f in fins] == ref_e.tolist()
    assert [f["tag"] for f in fins] == ["a", "b", "c"]
    assert sess.pool.live_pages == 0 and sess.free_slots == 3


def _run_churn(model_and_params, *, fused, chunk, slots=4, s=7, seed=11):
    """Random join/leave trace; returns {tag: (tokens, length, ended)}."""
    sess = DecodeSession(_gen(model_and_params), slots=slots,
                         capacity=s + MNT + 1, seed=seed)
    r = np.random.default_rng(42)
    pending = [_prompts(k, s, seed=100 + i)
               for i, k in enumerate((2, 1, 2, 1, 3))]
    results, tag = {}, 0
    for _ in range(60):
        while pending and pending[0].shape[0] <= sess.free_slots:
            cohort = pending.pop(0)
            k = cohort.shape[0]
            sess.admit(cohort, tags=list(range(tag, tag + k)))
            tag += k
        sess.run_chunk(chunk, fused=fused)
        for f in sess.harvest():
            results[f["tag"]] = (f["tokens"], f["length"], f["ended"])
        if not pending and sess.free_slots == sess.slots:
            break
    assert not pending and sess.free_slots == sess.slots
    assert sess.pool.live_pages == 0           # zero leaked pages
    return results


def test_session_churn_fused_bitwise_equals_host_oracle(model_and_params):
    """ANY join/leave trace: the fused chunks replay the host-stepped
    oracle bitwise (the PR 4 fused-loop argument, now with mid-flight
    splice/evict in the carry)."""
    rf = _run_churn(model_and_params, fused=True, chunk=2)
    rh = _run_churn(model_and_params, fused=False, chunk=2)
    assert set(rf) == set(rh) and len(rf) == 9
    for t in rf:
        np.testing.assert_array_equal(rf[t][0], rh[t][0])
        assert rf[t][1:] == rh[t][1:]


def test_session_chunk_size_invariance(model_and_params):
    """Chunk boundaries are invisible: key splits and decode steps are
    sequential regardless of where the while_loop is cut."""
    r2 = _run_churn(model_and_params, fused=True, chunk=2)
    r3 = _run_churn(model_and_params, fused=True, chunk=3)
    rm = _run_churn(model_and_params, fused=True, chunk=MNT)
    for t in r2:
        np.testing.assert_array_equal(r2[t][0], r3[t][0])
        np.testing.assert_array_equal(r2[t][0], rm[t][0])


def test_session_slot_pinned_row_invariance(model_and_params):
    """Greedy decode: a row's trajectory depends only on its own prompt
    and slot, not on co-resident rows joining or leaving around it."""
    cap = 7 + MNT + 1
    p0 = _prompts(1, 7, seed=8)
    other = _prompts(2, 7, seed=9)
    solo = DecodeSession(_gen(model_and_params), slots=3, capacity=cap,
                         seed=3)
    solo.admit(p0, slots=[1])
    t_solo = solo.drain()[0]["tokens"]
    busy = DecodeSession(_gen(model_and_params), slots=3, capacity=cap,
                         seed=3)
    busy.admit(other, slots=[0, 2])
    busy.run_chunk(2)                          # co-residents mid-flight
    busy.admit(p0, slots=[1], tags=["pin"])
    fins = busy.drain()
    t_co = next(f["tokens"] for f in fins if f["tag"] == "pin")
    np.testing.assert_array_equal(t_solo, t_co)
    assert busy.pool.live_pages == 0


def test_session_admission_guards(model_and_params):
    sess = DecodeSession(_gen(model_and_params), slots=2, capacity=14)
    with pytest.raises(ValueError, match="exceeds session capacity"):
        sess.admit(_prompts(1, 14))
    sess.admit(_prompts(2, 7, seed=1))
    with pytest.raises(NoFreeSlots):
        sess.admit(_prompts(1, 7, seed=2))
    with pytest.raises(NoFreeSlots):
        sess.admit(_prompts(1, 7, seed=2), slots=[0])   # occupied slot
    sess.drain()
    assert sess.free_slots == 2 and sess.pool.live_pages == 0
