"""Layer-2 analyzer self-tests: the contract checks pass on the real hot
paths, and each checker demonstrably CATCHES a seeded violation — a
dropped donation, an f64 promotion, a host callback, a shape leak past
the bucket set, and an unrolled decode loop (DESIGN.md §10).
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro.analysis import contracts
from repro.analysis.contracts import (
    callback_eqns, check_recompiles, check_traced, has_donation, run_all,
    wide_dtype_vars, while_count,
)
from repro.core import cache as cache_lib


# ------------------------------------------------- the real paths pass --

def test_all_contracts_clean_on_head():
    failures = run_all()
    assert failures == [], "\n".join(failures)


def test_contract_names_cover_the_registered_hot_paths():
    names = [n for n, _ in contracts.CONTRACTS]
    assert names == ["lookup_and_touch", "insert_batch", "ivf_lookup",
                     "fused_decode", "prefix_suffix_prefill"]


# -------------------------------------------- seeded violations caught --

def _insert_args(cfg, b=2):
    return (cache_lib.init_cache(cfg), contracts._unit_rows(b),
            jnp.zeros((b, cfg.max_query_tokens), jnp.int32),
            jnp.ones((b, cfg.max_query_tokens), jnp.float32),
            jnp.zeros((b, cfg.max_response_tokens), jnp.int32),
            jnp.ones((b, cfg.max_response_tokens), jnp.float32),
            jnp.asarray(2, jnp.int32))


def test_dropped_donation_is_caught():
    cfg = contracts._cache_cfg()
    no_donate = cache_lib.make_insert_batch(cfg, donate=False)
    tr = no_donate.trace(*_insert_args(cfg))
    assert not has_donation(tr.lower().as_text())
    failures = check_traced("insert_batch", tr, expect_donation=True)
    assert len(failures) == 1 and "donation was dropped" in failures[0]
    # ... and the donating build keeps the aliasing the registry declares
    donating = cache_lib.make_insert_batch(cfg)
    assert has_donation(donating.trace(*_insert_args(cfg)).lower().as_text())


def test_unexpected_donation_is_caught():
    # a read-only path that suddenly aliases its input is just as wrong
    jitted = jax.jit(lambda s: s * 2.0, donate_argnums=(0,))
    tr = jitted.trace(jnp.ones((4, 4)))
    failures = check_traced("ro_path", tr, expect_donation=False)
    assert len(failures) == 1 and "unexpected" in failures[0]


def test_f64_promotion_is_caught():
    with enable_x64():  # lowering must also happen inside the x64 scope
        tr = jax.jit(lambda x: x.astype(jnp.float64) * 2.0).trace(
            jnp.ones((4,), jnp.float32))
        failures = check_traced("widened", tr)
    assert any("float64" in w for w in wide_dtype_vars(tr.jaxpr))
    assert len(failures) == 1 and "64-bit" in failures[0]


def test_host_callback_is_caught():
    def host_fn(x):
        return np.asarray(x)

    def f(x):
        return jax.pure_callback(
            host_fn, jax.ShapeDtypeStruct((4,), jnp.float32), x)

    tr = jax.jit(f).trace(jnp.ones((4,), jnp.float32))
    assert callback_eqns(tr.jaxpr) == ["pure_callback"]
    failures = check_traced("cb_path", tr)
    assert len(failures) == 1 and "callback" in failures[0]


def test_callback_found_inside_scan_body():
    # iter_eqns must recurse into sub-jaxprs: a callback hidden in a
    # lax.scan body is still a per-iteration host round-trip
    def f(x):
        def body(c, _):
            y = jax.pure_callback(
                lambda v: np.asarray(v), jax.ShapeDtypeStruct((), jnp.float32),
                c)
            return y, y
        out, _ = jax.lax.scan(body, x, None, length=3)
        return out

    tr = jax.jit(f).trace(jnp.float32(1.0))
    assert "pure_callback" in callback_eqns(tr.jaxpr)


def test_shape_leak_fails_the_recompile_gate():
    jitted = jax.jit(lambda x: x * 2.0)
    for b in (1, 2, 4):          # pretend the bucket set is (1, 2) ...
        jax.block_until_ready(jitted(jnp.ones((b, 4))))
    failures = check_recompiles("leaky", jitted, calls=2)
    assert len(failures) == 1 and "shape/dtype leak" in failures[0]


def test_under_exercised_bucket_set_also_fails():
    jitted = jax.jit(lambda x: x + 1.0)
    jax.block_until_ready(jitted(jnp.ones((2, 4))))
    failures = check_recompiles("partial", jitted, calls=3)
    assert len(failures) == 1 and "under-exercised" in failures[0]


def test_unrolled_decode_loop_is_caught():
    # no while primitive in the jaxpr -> the fused-decode contract fails
    tr = jax.jit(lambda x: x * 2.0).trace(jnp.ones((4,)))
    assert while_count(tr.jaxpr) == 0
    failures = check_traced("decode", tr, expect_while=True)
    assert len(failures) == 1 and "while_loop" in failures[0]
    with_loop = jax.jit(lambda x: jax.lax.while_loop(
        lambda c: c[0] < 3, lambda c: (c[0] + 1, c[1] * 2.0), (0, x)))
    tr2 = with_loop.trace(jnp.ones((4,)))
    assert while_count(tr2.jaxpr) == 1
    assert check_traced("decode", tr2, expect_while=True) == []


def test_cli_reports_clean(capsys):
    assert contracts.main([]) == 0
    assert "hot paths clean" in capsys.readouterr().out
