"""Dry-run analysis machinery: HLO collective parser + roofline formulas.

These run WITHOUT the 512-device env (pure text/arithmetic)."""
import pytest

from repro.launch.dryrun import collective_bytes, _shape_bytes
from repro.configs import get_config
from benchmarks.roofline import (analytic_fwd_flops, analytic_step_flops,
                                 model_flops)

HLO = """
HloModule jit_step

%region_0.1 (arg: (f32[8,128], s32[])) -> (f32[8,128], s32[]) {
  %ag.1 = bf16[64,128]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[8,128]{1,0} all-reduce(%p1), to_apply=%add
  ROOT %t = tuple(...)
}

ENTRY %main (p: f32[8,128]) -> f32[8,128] {
  %w = (f32[8,128], s32[]) while(%init), condition=%region_1.2, body=%region_0.1
  %ag.2 = f32[4,4]{1,0} all-gather(%x)
  %a2a = bf16[2,8]{1,0} all-to-all(%y)
  ROOT %r = f32[8,128] get-tuple-element(%w), index=0
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert _shape_bytes("bf16[64,128]") == 64 * 128 * 2
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert _shape_bytes("pred[10]") == 10


def test_collective_parser_trip_count_scaling():
    once = collective_bytes(HLO, loop_trip_count=1)
    scaled = collective_bytes(HLO, loop_trip_count=10)
    ag_body = 64 * 128 * 2
    ar_body = 8 * 128 * 4
    ag_main = 4 * 4 * 4
    a2a = 2 * 8 * 2
    assert once["all-gather"] == ag_body + ag_main
    assert once["all-reduce"] == ar_body
    assert once["all-to-all"] == a2a
    # only the while-BODY collectives scale with the trip count
    assert scaled["all-gather"] == 10 * ag_body + ag_main
    assert scaled["all-reduce"] == 10 * ar_body
    assert scaled["all-to-all"] == a2a


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "mamba2-130m",
                                  "qwen3-moe-235b-a22b", "whisper-tiny",
                                  "recurrentgemma-9b"])
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_analytic_flops_sane(arch, shape):
    cfg = get_config(arch)
    fwd = analytic_fwd_flops(cfg, shape)
    step = analytic_step_flops(cfg, shape)
    mf = model_flops(cfg, shape)
    assert fwd > 0 and step >= fwd
    # 6*N*D should be within ~2 orders of the analytic number: catches
    # dimension mix-ups in either formula.
    assert 0.01 < mf / step < 3.0, (arch, shape, mf, step)


def test_decode_flops_much_smaller_than_prefill():
    cfg = get_config("qwen2.5-3b")
    assert (analytic_fwd_flops(cfg, "decode_32k")
            < analytic_fwd_flops(cfg, "prefill_32k") / 50)
