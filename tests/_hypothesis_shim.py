"""Optional-hypothesis shim: property tests skip cleanly when absent.

``from _hypothesis_shim import given, settings, st`` — with hypothesis
installed this re-exports the real decorators; without it, ``@given``
marks the test skipped (and ``st.*`` strategy builders become inert
placeholders so decoration-time calls still work).
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False
    _skip = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_args, **_kwargs):
        return lambda fn: _skip(fn)

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _InertStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _InertStrategies()
