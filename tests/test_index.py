"""Clustered (IVF) semantic-cache index invariants — DESIGN.md §7.

The load-bearing property: at ``nprobe == nclusters`` the IVF lookup is
score- and decision-identical to the flat scan, through arbitrary
insert/overwrite churn and across k-means rebuilds.  At the default
``nprobe`` it must keep recall@1 >= 0.95 on clustered synthetic data.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import cache as cache_lib
from repro.core import index as index_lib
from repro.core import router as router_lib


def _cfgs(capacity=32, dim=16, nclusters=4, nprobe=None, policy="fifo",
          topk=4, **kw):
    base = dict(capacity=capacity, dim=dim, max_query_tokens=4,
                max_response_tokens=4, topk=topk, policy=policy, **kw)
    flat = cache_lib.CacheConfig(**base)
    ivf = cache_lib.CacheConfig(
        index="ivf", nclusters=nclusters,
        nprobe=nclusters if nprobe is None else nprobe, **base)
    return flat, ivf


def _entry(key, cfg):
    e = jax.random.normal(key, (cfg.dim,))
    qt = jnp.zeros((cfg.max_query_tokens,), jnp.int32)
    qm = jnp.ones((cfg.max_query_tokens,), jnp.float32)
    rt = jnp.zeros((cfg.max_response_tokens,), jnp.int32)
    rm = jnp.ones((cfg.max_response_tokens,), jnp.float32)
    return e, qt, qm, rt, rm


def _clustered(key, n, dim, ntrue=16, noise=0.5):
    """Unit rows drawn from a mixture of ``ntrue`` directions.

    ``noise`` is the total perturbation NORM (scaled by 1/sqrt(dim) per
    coordinate), so intra-cluster cosine ~ 1/sqrt(1 + noise^2) no matter
    the dimension.
    """
    k1, k2, k3 = jax.random.split(key, 3)
    centers = jax.random.normal(k1, (ntrue, dim))
    centers /= jnp.linalg.norm(centers, axis=-1, keepdims=True)
    which = jax.random.randint(k2, (n,), 0, ntrue)
    pts = centers[which] + (noise / dim ** 0.5) * \
        jax.random.normal(k3, (n, dim))
    return pts / jnp.linalg.norm(pts, axis=-1, keepdims=True)


def _assert_matches_flat(state, flat, ivf, q, rcfg=None):
    rcfg = rcfg or router_lib.RouterConfig()
    fs, fi = cache_lib.lookup(state, flat, q)
    ivs, ivi = cache_lib.lookup(state, ivf, q)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(ivs),
                               rtol=1e-5, atol=1e-5)
    fd = np.asarray(router_lib.route(fs[:, 0], rcfg))
    ivd = np.asarray(router_lib.route(ivs[:, 0], rcfg))
    np.testing.assert_array_equal(fd, ivd)
    # indices must agree wherever the score is real (flat reports
    # arbitrary indices for -inf rows, ivf reports -1)
    finite = np.isfinite(np.asarray(fs))
    np.testing.assert_array_equal(np.asarray(fi)[finite],
                                  np.asarray(ivi)[finite])


@pytest.mark.parametrize("policy", ["fifo", "lru", "lfu"])
def test_full_probe_matches_flat_through_churn(policy):
    """nprobe == nclusters == flat scan, with ring-lapping overwrites."""
    flat, ivf = _cfgs(policy=policy)
    st_ = cache_lib.init_cache(ivf)
    embs = jax.random.normal(jax.random.PRNGKey(0), (48, flat.dim))
    for i in range(44):  # laps capacity 32 -> overwrites stale the table
        e, *rest = _entry(jax.random.fold_in(jax.random.PRNGKey(1), i), ivf)
        st_ = cache_lib.insert(st_, ivf, e, *rest)
    q = embs[:16] / jnp.linalg.norm(embs[:16], axis=-1, keepdims=True)
    _assert_matches_flat(st_, flat, ivf, q)
    # a k-means rebuild must preserve the equivalence exactly
    st_ = index_lib.build_index(st_, ivf, seed=0)
    _assert_matches_flat(st_, flat, ivf, q)
    # ... and so must further inserts on the rebuilt table
    for i in range(6):
        e, *rest = _entry(jax.random.fold_in(jax.random.PRNGKey(2), i), ivf)
        st_ = cache_lib.insert(st_, ivf, e, *rest)
    _assert_matches_flat(st_, flat, ivf, q)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 2 ** 16),
       policy=st.sampled_from(["fifo", "lru", "lfu"]),
       nclusters=st.sampled_from([1, 3, 8]))
def test_full_probe_equivalence_property(n, seed, policy, nclusters):
    """Property: IVF@nprobe=nclusters is decision- and score-identical to
    the flat scan after any insert_batch history."""
    flat, ivf = _cfgs(capacity=16, dim=8, nclusters=nclusters, policy=policy)
    b = 8
    sf, si = cache_lib.init_cache(flat), cache_lib.init_cache(ivf)
    key = jax.random.PRNGKey(seed)
    for start in range(0, n, b):
        key, k1 = jax.random.split(key)
        cnt = min(b, n - start)
        embs = jax.random.normal(k1, (b, flat.dim))
        qt = jnp.zeros((b, flat.max_query_tokens), jnp.int32)
        qm = jnp.ones((b, flat.max_query_tokens), jnp.float32)
        rt = jnp.zeros((b, flat.max_response_tokens), jnp.int32)
        rm = jnp.ones((b, flat.max_response_tokens), jnp.float32)
        sf, slf = cache_lib.insert_batch(sf, flat, embs, qt, qm, rt, rm, cnt)
        si, sli = cache_lib.insert_batch(si, ivf, embs, qt, qm, rt, rm, cnt)
        np.testing.assert_array_equal(np.asarray(slf), np.asarray(sli))
        # the engine's maintenance hook: a rebuild restores the table when
        # append-only churn overflows it (the equivalence contract holds
        # MODULO maintenance, exactly as served traffic experiences it)
        si, _ = index_lib.maybe_reindex(si, ivf, seed=start)
    key, kq = jax.random.split(key)
    q = jax.random.normal(kq, (6, flat.dim))
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    # the non-ivf keys must be bit-identical state (ivf rides alongside)
    for k in sf:
        np.testing.assert_array_equal(np.asarray(sf[k]), np.asarray(si[k]),
                                      err_msg=k)
    _assert_matches_flat(si, flat, ivf, q)


def test_default_nprobe_recall_on_clustered_data():
    """recall@1 >= 0.95 and band agreement >= 0.98 at the default nprobe."""
    cap, dim = 2048, 32
    flat, ivf = _cfgs(capacity=cap, dim=dim, nclusters=0, nprobe=0)
    assert index_lib.resolve(ivf).nprobe == 8  # the default
    st_ = cache_lib.init_cache(ivf)
    st_["emb"] = _clustered(jax.random.PRNGKey(0), cap, dim)
    st_["valid"] = jnp.ones((cap,), bool)
    st_ = index_lib.build_index(st_, ivf, seed=0)
    qi = jax.random.randint(jax.random.PRNGKey(1), (256,), 0, cap)
    q = st_["emb"][qi] + (0.15 / dim ** 0.5) * \
        jax.random.normal(jax.random.PRNGKey(2), (256, dim))
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    fs, fi = cache_lib.lookup(st_, flat, q)
    ivs, ivi = cache_lib.lookup(st_, ivf, q)
    recall = float(np.mean(np.asarray(fi[:, 0]) == np.asarray(ivi[:, 0])))
    agree = float(np.mean(
        np.asarray(router_lib.band_of(fs[:, 0]))
        == np.asarray(router_lib.band_of(ivs[:, 0]))))
    assert recall >= 0.95, recall
    assert agree >= 0.98, agree


def test_maybe_reindex_triggers_and_resets():
    flat, ivf = _cfgs(capacity=16, dim=8, nclusters=2, reindex_every=8)
    st_ = cache_lib.init_cache(ivf)
    for i in range(6):
        e, *rest = _entry(jax.random.PRNGKey(i), ivf)
        st_ = cache_lib.insert(st_, ivf, e, *rest)
    st_, did = index_lib.maybe_reindex(st_, ivf)
    assert not did and int(st_["ivf_pending"]) == 6
    for i in range(6, 10):
        e, *rest = _entry(jax.random.PRNGKey(i), ivf)
        st_ = cache_lib.insert(st_, ivf, e, *rest)
    st_, did = index_lib.maybe_reindex(st_, ivf)
    assert did and int(st_["ivf_pending"]) == 0
    # rebuilt table is compact: counts equal live membership, no overflow
    assert int(jnp.sum(st_["ivf_count"])) == int(jnp.sum(st_["valid"]))
    assert not bool(st_["ivf_overflow"])
    # flat path is untouched by maybe_reindex
    st2, did = index_lib.maybe_reindex(cache_lib.init_cache(flat), flat)
    assert not did


def test_overflow_forces_rebuild():
    """Slack-1 table + overwrite churn must raise the overflow flag, and
    the rebuild must restore the flat-scan equivalence."""
    flat, ivf = _cfgs(capacity=8, dim=8, nclusters=2, ivf_bucket=4,
                      reindex_every=10 ** 6)
    st_ = cache_lib.init_cache(ivf)
    embs = []
    for i in range(24):  # 24 appends into 8 table slots
        e, *rest = _entry(jax.random.PRNGKey(i), ivf)
        embs.append(e / jnp.linalg.norm(e))
        st_ = cache_lib.insert(st_, ivf, e, *rest)
    assert bool(st_["ivf_overflow"])
    st_, did = index_lib.maybe_reindex(st_, ivf)
    assert did
    q = jnp.stack(embs[-8:])
    _assert_matches_flat(st_, flat, ivf, q)


def test_resolve_auto_params():
    cfg = cache_lib.CacheConfig(capacity=65536, index="ivf")
    p = index_lib.resolve(cfg)
    assert p.nclusters == 512        # capacity / 128
    assert p.bucket == 256           # 2x slack over capacity/nclusters
    assert p.nprobe == 8
    # bucket floor: the table must be able to hold every slot
    tiny = cache_lib.CacheConfig(capacity=64, index="ivf", nclusters=4,
                                 ivf_bucket=2)
    assert index_lib.resolve(tiny).bucket == 16


def test_ivf_engine_matches_flat_engine():
    """Full-probe IVF engine serves byte-identical responses + stats."""
    from repro.launch.serve import build_engine
    flat_eng = build_engine(train_embedder_steps=0, capacity=64,
                            threshold=0.7)
    ivf_eng = build_engine(train_embedder_steps=0, capacity=64,
                           threshold=0.7, index="ivf", nclusters=4,
                           nprobe=4)
    batches = [
        ["how do i sort a list in python", "what is the capital of france"],
        ["how do i sort a list in python", "explain http caching briefly"],
        ["what is the capital of france", "how do i sort a python list"],
    ]
    for qs in batches:
        r1 = flat_eng.handle_batch(qs, max_new_tokens=4)
        r2 = ivf_eng.handle_batch(qs, max_new_tokens=4)
        assert r1 == r2
    assert flat_eng.stats == ivf_eng.stats
