"""Sanitizer harness (DESIGN.md §10, Layer 3): runtime enforcement of the
hot-path invariants the static layers can't prove.

Run with ``pytest --sanitize`` (or ``make test-sanitize``).  The conftest
hook additionally flips ``jax_numpy_rank_promotion`` to "raise" for the
whole session, so every test in the sanitize run also proves the absence
of silent rank-promoting broadcasts.

* ``jax.transfer_guard("disallow")`` around the serve path: after the
  compile buckets are warm, serving a batch must perform ZERO implicit
  host<->device transfers — the explicit ``jax.device_get`` sync points
  (waived in the lint) are the only crossings, and the guard allows only
  explicit ones.
* ``jax.checking_leaks()``: no tracer leaks out of the jitted closures.
* ``jax_debug_nans``: the engine e2e smoke produces finite numerics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CacheConfig, RouterConfig, TweakLLMEngine
from repro.core import cache as cache_lib
from repro.core import router as router_lib
from repro.models import ModelConfig, build_model
from repro.models.embedder import init_embedder, tiny_embedder_config
from repro.serving import GenerateConfig, Generator, SamplerConfig
from repro.tokenizer import HashWordTokenizer

pytestmark = pytest.mark.sanitize

VOCAB = 4096


@pytest.fixture(scope="module")
def engine():
    tok = HashWordTokenizer(VOCAB)
    ecfg = tiny_embedder_config(VOCAB)
    eparams = init_embedder(jax.random.PRNGKey(0), ecfg)
    lm = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                     d_ff=128, vocab_size=VOCAB, max_seq_len=512,
                     dtype="float32")
    gc = GenerateConfig(max_new_tokens=4,
                        sampler=SamplerConfig(vocab_size=VOCAB))
    big_m = build_model(lm)
    small_m = build_model(lm.replace(num_layers=1))
    big = Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gc)
    small = Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gc)
    return TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=64, dim=ecfg.d_model, topk=4),
        router_cfg=RouterConfig())


def test_serve_path_under_transfer_guard(engine):
    """After warmup, serving performs only EXPLICIT host<->device copies.

    The first calls compile every bucket this test touches and populate
    the cache; the guarded replay then serves a MISS batch and an EXACT
    batch end to end.  Any implicit transfer — a stray ``int()`` on a
    device scalar, an np.asarray coercion inside jit dispatch — raises
    under the guard, pinning the O(1)-explicit-syncs-per-batch design.
    """
    warm = ["how do i configure a vpn on linux",
            "what is the capital city of france"]
    engine.handle_batch(warm, max_new_tokens=4)          # compile + insert
    engine.handle_batch(warm, max_new_tokens=4)          # EXACT replay
    fresh = ["why does concrete cure slowly in winter",
             "best way to water a cactus indoors"]
    engine.handle_batch(fresh, max_new_tokens=4)         # warm MISS buckets
    with jax.transfer_guard("disallow"):
        miss = engine.handle_batch(
            ["how long should sourdough starter ferment",
             "what makes titanium alloys corrosion resistant"],
            max_new_tokens=4)
        exact = engine.handle_batch(warm, max_new_tokens=4)
    assert all(isinstance(r, str) and r for r in miss + exact)
    assert engine.stats.exact >= 2


def test_lookup_touch_under_transfer_guard():
    """The fused lookup+touch device call itself moves no implicit data."""
    cfg = CacheConfig(capacity=32, dim=16, topk=4)
    rcfg = RouterConfig()
    jitted = jax.jit(
        lambda s, q: cache_lib.lookup_and_touch(s, cfg, rcfg, q),
        donate_argnums=(0,))
    q = jnp.asarray(np.eye(2, 16, dtype=np.float32))
    out = jitted(cache_lib.init_cache(cfg), q)           # compile outside
    jax.block_until_ready(out)
    # state allocation transfers fill constants — that's setup, not the
    # hot call, so it stays outside the guard
    state = cache_lib.init_cache(cfg)
    jax.block_until_ready(state)
    with jax.transfer_guard("disallow"):
        state, scores, idx, dec = jitted(state, q)
        jax.block_until_ready((scores, idx, dec))
    assert dec.shape == (2,)
    assert int(jax.device_get(dec)[0]) == router_lib.MISS


def test_engine_e2e_checking_leaks_and_nans(engine):
    """Smoke e2e under tracer-leak checking + debug_nans."""
    with jax.checking_leaks(), jax.debug_nans(True):
        rs, meta = engine.handle_batch(
            ["how do tides follow the moon", "how do tides follow the moon"],
            max_new_tokens=4, collect_meta=True)
    assert all(isinstance(r, str) and r for r in rs)
    assert all(np.isfinite(m["sim"]) for m in meta)


def test_rank_promotion_guard_is_active():
    """--sanitize must flip rank promotion to 'raise' process-wide."""
    assert jax.config.jax_numpy_rank_promotion == "raise"
    with pytest.raises(ValueError, match="rank_promotion"):
        _ = jnp.ones((3,)) + jnp.ones((2, 1, 3))
