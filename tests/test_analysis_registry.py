"""Registry <-> tree parity: every ``jax.jit`` reference in src/repro is
an analyzable site, every site has exactly one registry entry, and every
registry entry points at a real file (DESIGN.md §10).
"""
import ast
import os

from repro.analysis import lint, registry
from repro.analysis.lint import JitUse, lint_source


def _walk_sources():
    root = lint.find_root()
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path, encoding="utf-8") as f:
                    yield rel, f.read()


def _raw_jit_references(source: str) -> int:
    """Count every ``jax.jit`` attribute access in the AST — the
    grep-equivalent upper bound on jit sites, immune to comments and
    docstrings mentioning jax.jit."""
    count = 0
    for node in ast.walk(ast.parse(source)):
        if isinstance(node, ast.Attribute) and node.attr == "jit" and \
                isinstance(node.value, ast.Name) and node.value.id == "jax":
            count += 1
    return count


def test_every_jit_reference_is_an_analyzed_site_and_registered():
    uses, raw = [], 0
    for rel, source in _walk_sources():
        lint_source(source, rel, collect_jit=uses)
        raw += _raw_jit_references(source)
    # grep-count parity: the AST collector must account for EVERY textual
    # jax.jit in the tree (a stored/aliased jit would make raw > uses and
    # separately fail the lint as JR401)
    assert raw == len(uses), (raw, len(uses))
    assert len(uses) == len(registry.JIT_REGISTRY), (
        f"{len(uses)} jax.jit sites in src/repro vs "
        f"{len(registry.JIT_REGISTRY)} registry entries")


def test_registry_files_exist_and_are_sorted_unique():
    root = lint.find_root()
    for rel in registry.registered_files():
        assert os.path.exists(os.path.join(root, rel)), rel


def test_registry_notes_are_mandatory():
    # the note is the point of the registry: policy + prose rationale
    for site in registry.JIT_REGISTRY:
        assert site.note, f"{site.file}:{site.qualname} has no note"


def test_hot_modules_point_at_real_paths():
    root = lint.find_root()
    for m in registry.HOT_MODULES:
        path = os.path.join(root, m.rstrip("/"))
        assert os.path.exists(path), m


def test_unregistered_jit_fails_registry_check():
    table = (registry.JitSite("core/engine.py", "TweakLLMEngine.__init__"),)
    uses = [JitUse("core/engine.py", "TweakLLMEngine.__init__", 5, {}),
            JitUse("core/engine.py", "new_fn", 10, {})]
    vs = lint.check_registry(uses, table=table)
    assert [v.rule for v in vs] == ["JR401"]
    assert "new_fn" in vs[0].msg


def test_moved_file_caught_via_files_scanned():
    # a registry entry naming a file the lint never scanned is stale even
    # if no use conflicts with it
    vs = lint.check_registry(
        [], table=(registry.JitSite("core/renamed.py", "f"),),
        files_scanned=["core/engine.py"])
    assert [v.rule for v in vs] == ["JR403"]
    assert "never" in vs[0].msg
