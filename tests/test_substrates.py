"""Tokenizer, data generators, training loop, checkpoint round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.checkpoint import load_checkpoint, latest_checkpoint, save_checkpoint
from repro.data import (QuestionPairGenerator, WorkloadGenerator,
                        token_stream_batches)
from repro.models import ModelConfig, build_model
from repro.tokenizer import HashWordTokenizer
from repro.training import AdamWConfig, init_opt_state, make_train_step


# ------------------------------------------------------------- tokenizer

def test_tokenizer_deterministic():
    tok = HashWordTokenizer(4096)
    a = tok.encode("How do I learn Python?")
    b = tok.encode("how do i learn python ?")
    assert a == b  # case/punct-spacing insensitive


@settings(max_examples=25, deadline=None)
@given(st.text(alphabet=st.characters(codec="ascii"), max_size=64),
       st.integers(256, 8192))
def test_tokenizer_ids_in_range(text, vocab):
    tok = HashWordTokenizer(vocab)
    ids = tok.encode(text)
    assert all(0 <= i < vocab for i in ids)


def test_encode_batch_shapes_and_mask():
    tok = HashWordTokenizer(4096)
    toks, mask = tok.encode_batch(["one two three", "one"], 8)
    assert toks.shape == (2, 8) and mask.shape == (2, 8)
    assert mask[0].sum() == 4  # bos + 3 words
    assert mask[1].sum() == 2
    assert np.all(toks[mask == 0] == tok.pad)


# ------------------------------------------------------------------ data

def test_question_pairs_labels():
    gen = QuestionPairGenerator(seed=0)
    pairs = gen.generate(100, dup_frac=0.5, hard_frac=0.25)
    dups = [p for p in pairs if p[2] == 1]
    negs = [p for p in pairs if p[2] == 0]
    assert len(dups) > 20 and len(negs) > 20
    for a, b, _l in dups:
        assert a.topic == b.topic and a.intent == b.intent
    for a, b, _l in negs:
        assert (a.topic, a.intent) != (b.topic, b.intent)


def test_polarity_hard_negatives_share_topic():
    gen = QuestionPairGenerator(seed=1)
    found = False
    for _ in range(50):
        a, b = gen.hard_negative_pair()
        if a.topic == b.topic:
            assert {a.intent, b.intent} == {"why_good", "why_bad"}
            found = True
    assert found


def test_workload_profiles_differ():
    lm = WorkloadGenerator("lmsys", seed=0).sample(400)
    wc = WorkloadGenerator("wildchat", seed=0).sample(400)
    lm_repeat = 1 - len({q.text for q in lm}) / len(lm)
    wc_repeat = 1 - len({q.text for q in wc}) / len(wc)
    assert lm_repeat > wc_repeat  # lmsys-like repeats harder


def test_pretrain_stream_shapes():
    tok = HashWordTokenizer(4096)
    it = token_stream_batches(tok, batch=2, seq_len=16)
    b = next(it)
    assert b["tokens"].shape == (2, 16)
    assert np.array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


# -------------------------------------------------------------- training

def test_loss_decreases_tiny_lm():
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=512, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = HashWordTokenizer(512)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3),
                                   total_steps=30))
    opt = init_opt_state(params)
    losses = []
    stream = token_stream_batches(tok, 4, 32)
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_microbatched_matches_plain_grads():
    cfg = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=128, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 128)
    batch = {"tokens": toks, "targets": toks,
             "mask": jnp.ones((4, 16), jnp.float32)}
    s1 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-2)))
    s4 = jax.jit(make_train_step(model, AdamWConfig(lr=1e-2), microbatches=4))
    p1, _, m1 = s1(params, init_opt_state(params), batch)
    p4, _, m4 = s4(params, init_opt_state(params), batch)
    # same global batch -> same update (up to fp accumulation order)
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-3, d


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    cfg = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                      d_ff=64, vocab_size=128, dtype="bfloat16")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, params, {"arch": "test"})
    assert latest_checkpoint(d) == 7
    restored, meta = load_checkpoint(d, 7, params)
    assert meta["metadata"]["arch"] == "test"
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-2)
