"""Multi-replica serving semantics (DESIGN.md §12), under SimClock.

The acceptance properties:
  (a) a trace replayed through 2 and 4 replicas yields responses AND
      aggregated EngineStats byte-identical to the single-engine serial
      replay (exact-or-miss routing, same visibility ordering),
  (b) a shared-bank write from one replica is an EXACT hit on another
      replica's very next lookup; private banks deliberately are not,
  (c) zero leaked KV pages per replica once every request is harvested,
  (d) replica-level scheduling: least-loaded dispatch, global dedup
      (one generation per unique in-flight text, fleet-wide), and work
      stealing rebalances drifted queues.

Everything runs in-process on however many devices exist; the sharded-bank
test needs >= 4 and is exercised by ``make test-multidevice``
(XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
import jax
import pytest

from repro.core import CacheConfig, ReplicaGroup, RouterConfig
from repro.core.engine import SharedCacheBank, TweakLLMEngine
from repro.models import ModelConfig, build_model
from repro.models.embedder import init_embedder, tiny_embedder_config
from repro.serving import (GenerateConfig, Generator, ReplicaScheduler,
                           SamplerConfig, Scheduler, SchedulerConfig,
                           SimClock, leaked_pages, replay_trace)
from repro.tokenizer import HashWordTokenizer

VOCAB = 4096
EXACT_OR_MISS = {"tweak_threshold": 0.9999}


@pytest.fixture(scope="module")
def stack():
    tok = HashWordTokenizer(VOCAB)
    ecfg = tiny_embedder_config(VOCAB)
    eparams = init_embedder(jax.random.PRNGKey(0), ecfg)
    lm = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                     d_ff=64, vocab_size=VOCAB, max_seq_len=512,
                     dtype="float32")
    gc = GenerateConfig(max_new_tokens=4,
                        sampler=SamplerConfig(vocab_size=VOCAB))
    big_m = build_model(lm)
    small_m = build_model(lm)
    big = Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gc)
    small = Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gc)
    return tok, ecfg, eparams, big, small


def _cache_cfg(ecfg):
    return CacheConfig(capacity=128, dim=ecfg.d_model, topk=4)


def _group(stack, n, *, shared=True, mesh=None, router_kw=None):
    tok, ecfg, eparams, big, small = stack
    return ReplicaGroup.build(
        n, tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small, cache_cfg=_cache_cfg(ecfg),
        router_cfg=RouterConfig(**(router_kw or EXACT_OR_MISS)),
        shared=shared, mesh=mesh)


def _serial(stack, texts, router_kw=None):
    """Reference: ONE engine, one handle_batch call per request in order."""
    tok, ecfg, eparams, big, small = stack
    eng = TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small, cache_cfg=_cache_cfg(ecfg),
        router_cfg=RouterConfig(**(router_kw or EXACT_OR_MISS)))
    return [eng.handle_batch([t], max_new_tokens=4)[0] for t in texts], eng


# ---------------------------------------------- (b) cross-replica cache
def test_shared_bank_write_visible_across_replicas(stack):
    group = _group(stack, 2)
    r0, r1 = group.engines
    text = "a question first answered by replica zero"
    a = r0.handle_batch([text], max_new_tokens=4)
    b = r1.handle_batch([text], max_new_tokens=4)
    assert a == b
    assert (r0.stats.miss, r0.stats.exact) == (1, 0)
    assert (r1.stats.miss, r1.stats.exact) == (0, 1)   # hit A's write
    agg = group.stats
    assert (agg.total, agg.miss, agg.exact) == (2, 1, 1)
    assert group.shared and group.bank is r0.bank


def test_private_banks_do_not_share(stack):
    group = _group(stack, 2, shared=False)
    r0, r1 = group.engines
    text = "a question each private replica answers alone"
    a = r0.handle_batch([text], max_new_tokens=4)
    b = r1.handle_batch([text], max_new_tokens=4)
    assert a == b                        # same weights -> same generation
    assert r0.stats.miss == 1 and r1.stats.miss == 1   # both missed
    assert not group.shared
    with pytest.raises(ValueError, match="private banks"):
        _ = group.bank


def test_engine_rejects_mismatched_bank_config(stack):
    tok, ecfg, eparams, big, small = stack
    bank = SharedCacheBank(_cache_cfg(ecfg))
    with pytest.raises(ValueError, match="disagrees"):
        TweakLLMEngine(
            tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
            big=big, small=small, bank=bank,
            cache_cfg=CacheConfig(capacity=64, dim=ecfg.d_model))


# ------------------------------------------- (a) serial byte-identity
def _replica_trace():
    """8 distinct texts, then spaced repeats of the first 4: every repeat
    arrives after its original's dispatch completed, so cache visibility
    ordering matches the serial replay exactly."""
    texts = [f"replica trace question {i} about topic {i}" for i in range(8)]
    trace = [(0.01 * i, t) for i, t in enumerate(texts)]
    trace += [(1.0 + 0.3 * j, texts[j]) for j in range(4)]
    return trace


@pytest.mark.parametrize("n", [2, 4])
def test_replica_churn_byte_identical_to_serial(stack, n):
    """The satellite contract: responses AND aggregated EngineStats from an
    n-replica shared-bank replay are byte-identical to the single-engine
    serial replay under exact-or-miss routing."""
    trace = _replica_trace()
    group = _group(stack, n)
    sched = ReplicaScheduler(group.engines,
                             SchedulerConfig(max_wait=0.05, max_batch=4,
                                             max_new_tokens=4),
                             clock=SimClock())
    done = sorted(replay_trace(sched, trace), key=lambda r: r.rid)
    seq, ref = _serial(stack, [t for _, t in trace])
    assert [r.response for r in done] == seq           # byte-identical
    assert group.stats == ref.stats                    # byte-identical
    assert group.stats.miss == 8 and group.stats.exact == 4
    assert sched.stats.completed == len(trace) and sched.stats.rejected == 0
    # the fleet actually fanned out: more than one lane served traffic
    assert sum(lane.dispatched > 0 for lane in sched.lanes) > 1


def test_single_replica_matches_single_lane_scheduler(stack):
    """ReplicaScheduler with one engine degenerates to Scheduler exactly."""
    trace = _replica_trace()
    cfg = SchedulerConfig(max_wait=0.05, max_batch=4, max_new_tokens=4)
    group = _group(stack, 1)
    rs = ReplicaScheduler(group.engines, cfg, clock=SimClock())
    done_r = sorted(replay_trace(rs, trace), key=lambda r: r.rid)
    eng = _group(stack, 1).engines[0]
    ss = Scheduler(eng, cfg, clock=SimClock())
    done_s = sorted(replay_trace(ss, trace), key=lambda r: r.rid)
    assert [r.response for r in done_r] == [r.response for r in done_s]
    assert group.stats == eng.stats
    assert rs.stats.batches == ss.stats.batches
    assert [r.finish for r in done_r] == [r.finish for r in done_s]


# ------------------------------------------------- (c) page accounting
@pytest.fixture(scope="module")
def paged_parts():
    """Model + params for building PER-REPLICA paged generators: each
    replica owns its own KV page pool (the per-replica accounting the
    leak test isolates), over identical weights."""
    lm = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
                     d_ff=64, vocab_size=VOCAB, max_seq_len=512,
                     dtype="float32", attention_impl="xla_flash",
                     flash_block_q=16, flash_block_k=16)
    gc = GenerateConfig(max_new_tokens=4,
                        sampler=SamplerConfig(vocab_size=VOCAB),
                        paged=True, page_size=8, pool_pages=256)
    big_m = build_model(lm)
    small_m = build_model(lm)
    return (big_m, big_m.init(jax.random.PRNGKey(1)),
            small_m, small_m.init(jax.random.PRNGKey(2)), gc)


def test_zero_leaked_kv_pages_per_replica(stack, paged_parts):
    tok, ecfg, eparams, _, _ = stack
    big_m, big_p, small_m, small_p, gc = paged_parts
    group = ReplicaGroup.build(
        2, tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=lambda rid: Generator(big_m, big_p, gc),
        small=lambda rid: Generator(small_m, small_p, gc),
        cache_cfg=_cache_cfg(ecfg), router_cfg=RouterConfig(**EXACT_OR_MISS))
    bigs = {id(e.big) for e in group.engines}
    assert len(bigs) == 2                # truly per-replica pools
    sched = ReplicaScheduler(group.engines,
                             SchedulerConfig(max_wait=0.02, max_batch=4,
                                             max_new_tokens=4),
                             clock=SimClock())
    trace = [(0.01 * i, f"paged replica query {i} item {i}")
             for i in range(10)]
    done = replay_trace(sched, trace)
    assert len(done) == 10
    assert group.leaked_kv_pages() == [0, 0]
    assert leaked_pages(*(e.big for e in group.engines),
                        *(e.small for e in group.engines)) == 0


# --------------------------------------- (d) replica-level scheduling
def test_least_loaded_submit_balances_lanes(stack):
    group = _group(stack, 2)
    sched = ReplicaScheduler(group.engines,
                             SchedulerConfig(max_wait=10.0, max_batch=8,
                                             max_new_tokens=4),
                             clock=SimClock())
    for i in range(6):
        sched.submit(f"balanced submit {i} subject {i}")
    assert [len(lane.groups) for lane in sched.lanes] == [3, 3]


def test_global_dedup_one_generation_fleet_wide(stack):
    """K copies of one text across a 2-replica fleet: ONE group on ONE
    lane, one engine dispatch, K-1 joins."""
    group = _group(stack, 2)
    sched = ReplicaScheduler(group.engines,
                             SchedulerConfig(max_wait=1.0, max_batch=8,
                                             max_new_tokens=4),
                             clock=SimClock())
    K = 5
    reqs = [sched.submit("fleet duplicate question about tides")
            for _ in range(K)]
    assert sum(len(lane.groups) for lane in sched.lanes) == 1
    sched.clock.advance(1.0)
    done = sched.poll()
    assert len(done) == K and all(r.done for r in reqs)
    assert group.stats.total == 1 and group.stats.miss == 1
    assert sched.stats.joined == K - 1 and sched.stats.dispatched == 1
    assert len({r.response for r in reqs}) == 1


def _drive(sched):
    """Replay-to-empty: advance the SimClock wakeup-to-wakeup."""
    done = []
    while True:
        w = sched.next_wakeup()
        if w is None:
            break
        sched.clock.advance_to(w)
        done.extend(sched.poll())
    return done


def _imbalanced_sched(stack, *, steal):
    """4 groups piled on lane 0, lane 1 idle-empty — the drifted-queue
    state stealing exists for (least-loaded admission prevents it
    arising from admission alone; a replica restart or stall does not)."""
    group = _group(stack, 2)
    sched = ReplicaScheduler(group.engines,
                             SchedulerConfig(max_wait=0.0, max_batch=1,
                                             max_new_tokens=4, steal=steal),
                             clock=SimClock(),
                             service_model=lambda b: 1.0)
    reqs = [sched.submit(f"steal target {i} area {i}") for i in range(4)]
    l0, l1 = sched.lanes
    l0.groups += l1.groups               # adversarial drift, by hand
    l1.groups.clear()
    return sched, reqs


def test_work_stealing_rebalances_drifted_queues(stack):
    sched, reqs = _imbalanced_sched(stack, steal=True)
    done = _drive(sched)
    assert len(done) == 4 and all(r.done for r in reqs)
    assert sched.stats.stolen == 2       # ceil(surplus/2) of 3 surplus
    l0, l1 = sched.lanes
    assert l1.stolen_in == 2 and l1.dispatched == 2 and l0.dispatched == 2
    # stealing halves the drain time vs the no-steal serial drain
    assert max(r.finish for r in reqs) == pytest.approx(2.0)


def test_steal_disabled_serializes_on_the_donor(stack):
    sched, reqs = _imbalanced_sched(stack, steal=False)
    done = _drive(sched)
    assert len(done) == 4 and all(r.done for r in reqs)
    assert sched.stats.stolen == 0
    assert sched.lanes[1].dispatched == 0
    assert max(r.finish for r in reqs) == pytest.approx(4.0)


def test_continuous_mode_per_replica_slot_accounting(stack):
    """Each lane owns its own slot horizons (the PR 7 accounting, per
    replica): 2 replicas x 2 slots serve 4 concurrent requests at once."""
    group = _group(stack, 2)
    sched = ReplicaScheduler(group.engines,
                             SchedulerConfig(continuous=True, slots=2,
                                             max_new_tokens=4),
                             clock=SimClock(),
                             service_model=lambda b: 2.0 * b)
    reqs = [sched.submit(f"continuous replica query {i} item {i}")
            for i in range(6)]
    sched.poll()                         # 2 lanes x 2 slots -> 4 in flight
    per = 2.0 * 2 / 2                    # service_model(slots)/slots
    assert [r.done for r in reqs] == [True] * 4 + [False] * 2
    assert all(r.finish == pytest.approx(per) for r in reqs[:4])
    sched.clock.advance_to(per)
    sched.poll()
    assert all(r.finish == pytest.approx(2 * per) for r in reqs[4:])


# ------------------------------------------- sharded bank (multidevice)
def test_sharded_bank_replicas_match_local(stack):
    """Row-sharded shared bank == local shared bank, end to end: same
    trace, same responses, same aggregated stats.  Needs >= 4 devices —
    runs under ``make test-multidevice`` (8 forced host devices)."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8; "
                    "run via `make test-multidevice`)")
    from repro.launch.mesh import make_cache_mesh
    trace = _replica_trace()
    cfg = SchedulerConfig(max_wait=0.05, max_batch=4, max_new_tokens=4)
    local = _group(stack, 2)
    done_l = sorted(replay_trace(
        ReplicaScheduler(local.engines, cfg, clock=SimClock()), trace),
        key=lambda r: r.rid)
    sharded = _group(stack, 2, mesh=make_cache_mesh(4))
    assert sharded.bank.sharded
    done_s = sorted(replay_trace(
        ReplicaScheduler(sharded.engines, cfg, clock=SimClock()), trace),
        key=lambda r: r.rid)
    assert [r.response for r in done_s] == [r.response for r in done_l]
    assert sharded.stats == local.stats
    assert sharded.stats.exact == 4      # repeats hit across replicas
