"""Judge + multi-agent debate protocol tests."""
import jax
import numpy as np

from repro.eval import (PERSONAS, debate_batch, make_loglik_scorer,
                        run_debate, verdict_shares)
from repro.models import ModelConfig, build_model
from repro.tokenizer import HashWordTokenizer


def test_three_personas_match_paper_table2():
    names = [p.name for p in PERSONAS]
    assert names == ["factual_accuracy", "user_experience",
                     "relevance_completeness"]


def test_debate_blinding_symmetry():
    """Swapping A and B must swap the verdict (protocol is order-fair)."""
    rng1 = np.random.default_rng(0)
    rng2 = np.random.default_rng(0)
    q = "how do i learn piano practice"
    good = "here is a detailed answer about piano practice: first learn scales"
    bad = "it depends"
    r1 = run_debate(q, good, bad, -1.0, -3.0, rng=rng1)
    r2 = run_debate(q, bad, good, -3.0, -1.0, rng=rng2)
    flip = {"A": "B", "B": "A", "AB": "AB"}
    assert r1.verdict == flip[r2.verdict]


def test_debate_prefers_clearly_better():
    rng = np.random.default_rng(1)
    q = "how do i learn piano practice"
    good = ("here is a detailed answer about piano practice: first understand "
            "the fundamentals then practice consistently track progress")
    bad = "no idea"
    wins = 0
    for _ in range(10):
        r = run_debate(q, good, bad, -0.5, -4.0, rng=rng)
        wins += r.verdict == "A"
    assert wins >= 8


def test_verdict_shares_sum_to_one():
    rs = debate_batch(["q"] * 10, ["resp a"] * 10, ["resp b"] * 10,
                      [-1.0] * 10, [-1.0] * 10)
    shares = verdict_shares(rs)
    assert abs(sum(shares.values()) - 1.0) < 1e-9


def test_loglik_scorer_ranks_real_text_higher():
    vocab = 512
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=vocab, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = HashWordTokenizer(vocab)
    score = make_loglik_scorer(model, params, tok, max_len=48)
    out = score(["what is keto"], ["keto is a diet plan"])
    assert out.shape == (1,)
    assert np.isfinite(out[0])
