"""End-to-end behaviour tests for the paper's system.

Prefill+decode == full forward (the KV-cache/state invariant) for every
architecture family, plus tweak-prompt construction protocol checks.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tweak
from repro.models import (LOCAL_ATTN, MAMBA2, MOE, RGLRU, ModelConfig,
                          build_model)

B, S, V = 2, 12, 256


def _consistency(cfg, extra=None, atol=5e-3):
    toks = jax.random.randint(jax.random.PRNGKey(0), (B, S + 3), 0, V)
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(1))
    bf = {"tokens": toks}
    bp = {"tokens": toks[:, :S]}
    if extra:
        bf.update(extra)
        bp.update(extra)
    lf, _ = m.forward(p, bf)
    off = cfg.num_prefix_tokens if cfg.frontend == "vision_stub" else 0
    lp, caches = m.prefill(p, bp, capacity=S + 8 + off)
    errs = [float(np.max(np.abs(lp - lf[:, off + S - 1])))]
    for i in range(3):
        lp, caches = m.decode_step(p, toks[:, S + i], caches)
        if i < 2:
            errs.append(float(np.max(np.abs(lp - lf[:, off + S + i]))))
    assert max(errs) < atol, (cfg.name, errs)


def test_decode_matches_forward_dense():
    _consistency(ModelConfig(num_layers=3, d_model=64, num_heads=4,
                             num_kv_heads=2, d_ff=128, vocab_size=V,
                             dtype="float32", qkv_bias=True))


def test_decode_matches_forward_swa():
    _consistency(ModelConfig(num_layers=3, d_model=64, num_heads=4,
                             num_kv_heads=2, d_ff=128, vocab_size=V,
                             sliding_window=6, dtype="float32"))


def test_decode_matches_forward_moe():
    _consistency(ModelConfig(num_layers=2, d_model=64, num_heads=4,
                             num_kv_heads=2, d_ff=96, vocab_size=V,
                             block_pattern=(MOE,), num_experts=4,
                             experts_per_token=2, moe_d_ff=96,
                             capacity_factor=2.0, dtype="float32"))


def test_decode_matches_forward_mamba2():
    _consistency(ModelConfig(num_layers=2, d_model=64, num_heads=1,
                             num_kv_heads=1, d_ff=0, vocab_size=V,
                             block_pattern=(MAMBA2,), ssm_state=16,
                             ssm_head_dim=16, ssm_chunk=4, dtype="float32"))


def test_decode_matches_forward_hybrid():
    _consistency(ModelConfig(num_layers=5, d_model=64, num_heads=4,
                             num_kv_heads=1, d_ff=128, vocab_size=V,
                             block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
                             sliding_window=6, dtype="float32"))


def test_decode_matches_forward_encdec():
    cfg = ModelConfig(family="encdec", num_layers=2, enc_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=V,
                      mlp_type="gelu", norm_type="layernorm", enc_frames=8,
                      max_seq_len=64, tie_embeddings=True, dtype="float32")
    frames = jax.random.normal(jax.random.PRNGKey(2), (B, 8, 64))
    _consistency(cfg, extra={"frames": frames})


def test_decode_matches_forward_vlm():
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=V, frontend="vision_stub",
                      num_prefix_tokens=4, frontend_dim=32, dtype="float32")
    pe = jax.random.normal(jax.random.PRNGKey(3), (B, 4, 32))
    _consistency(cfg, extra={"prefix_embeds": pe})


# ------------------------------------------------------------ tweak prompt

def test_tweak_prompt_contains_all_parts():
    t = tweak.build_tweak_text("new q", "old q", "old resp")
    assert "new q" in t and "old q" in t and "old resp" in t
    assert t.index("old q") < t.index("old resp")
    # the static instruction prefix opens the prompt — the shared-KV split
    assert t.startswith(tweak.tweak_prefix_text())


def test_query_suffix_applied():
    assert tweak.preprocess_query("hi  ").endswith("answer briefly")


def test_tweak_batch_tokens_fixed_shape():
    from repro.tokenizer import HashWordTokenizer
    tok = HashWordTokenizer(4096)
    statics = tweak.encode_static_segments(tok)
    n_static = sum(len(s) for s in statics)
    nq = jnp.ones((2, 4), jnp.int32)
    nm = jnp.ones((2, 4), jnp.float32)
    cq = jnp.ones((2, 3), jnp.int32)
    cm = jnp.ones((2, 3), jnp.float32)
    cr = jnp.ones((2, 6), jnp.int32)
    crm = jnp.ones((2, 6), jnp.float32)
    toks, mask = tweak.build_tweak_batch_tokens(statics, nq, nm, cq, cm,
                                                cr, crm)
    assert toks.shape == (2, n_static + 3 + 6 + 4)
    assert mask.shape == toks.shape


def test_tweak_token_paths_match_text_oracle():
    """Both token assemblies derive from TWEAK_SEGMENTS: unpadded field
    tokens must reproduce exactly the encoding of the text oracle, and the
    prefix + suffix split must concatenate back to the full row."""
    from repro.tokenizer import HashWordTokenizer
    tok = HashWordTokenizer(4096)
    q, cq, cr = "what is rust", "what is go", "a compiled language"
    oracle = tok.encode(tweak.build_tweak_text(q, cq, cr))
    row = tweak.encode_tweak_row(tok, q, cq, cr, 256)
    assert row == oracle
    pre = tweak.tweak_prefix_ids(tok)
    suf = tweak.encode_tweak_row(tok, q, cq, cr, 256, drop_prefix=True)
    assert list(pre) + suf == oracle
    # jittable fixed-shape assembly agrees too (no padding case)
    statics = tweak.encode_static_segments(tok)
    enc = lambda t: np.asarray(tok.encode(t, add_bos=False), np.int32)[None]
    ones = lambda a: np.ones(a.shape, np.float32)
    nq_t, cq_t, cr_t = enc(q), enc(cq), enc(cr)
    toks, mask = tweak.build_tweak_batch_tokens(
        statics, nq_t, ones(nq_t), cq_t, ones(cq_t), cr_t, ones(cr_t))
    assert np.asarray(toks)[0].tolist() == oracle
    assert np.asarray(mask).all()
