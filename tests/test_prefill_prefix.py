"""Shared-prefix KV reuse in prefill (DESIGN.md §9).

The contract under test: ``prefill_with_prefix(suffix, prefix_cache)``
must be BYTE-identical to ``prefill([prefix | suffix])`` — logits, every
cache leaf, and (through the fused decode loop) the full generated
output — across batch and suffix-length buckets.  Plus the serving-layer
pieces that ride on it: cue-preserving truncation of over-long tweak
prompts, prompt-token accounting, explicit fallback for architectures
that can't guarantee the bitwise contract, and stale-prefix-cache
rebuild when the small generator is swapped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from repro.configs import get_config
from repro.core import CacheConfig, RouterConfig, TweakLLMEngine
from repro.core import tweak as tweak_lib
from repro.models import ModelConfig, build_model
from repro.serving import GenerateConfig, Generator, SamplerConfig
from repro.tokenizer import HashWordTokenizer

VOCAB = 512
EOS = 2


def _flash_cfg(**kw):
    base = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                d_ff=128, vocab_size=VOCAB, max_seq_len=1024,
                dtype="float32", attention_impl="xla_flash",
                flash_block_q=32, flash_block_k=32)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture(scope="module")
def lm():
    cfg = _flash_cfg()
    m = build_model(cfg)
    return m, m.init(jax.random.PRNGKey(0))


def _generator(model, params, *, mnt=8, temperature=0.0, vocab=VOCAB):
    gc = GenerateConfig(max_new_tokens=mnt, eos_id=EOS,
                        sampler=SamplerConfig(temperature=temperature,
                                              vocab_size=vocab))
    return Generator(model, params, gc)


def _prefix_suffix(b, p, s, seed=1, vocab=VOCAB):
    pre = jax.random.randint(jax.random.PRNGKey(seed), (1, p), 5, vocab)
    suf = jax.random.randint(jax.random.PRNGKey(seed + 1), (b, s), 5, vocab)
    return jnp.broadcast_to(pre, (b, p)), suf


def _assert_trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


# ------------------------------------------- prefill-level differential
@pytest.mark.parametrize("b,p,s", [(1, 45, 16), (2, 45, 32), (4, 45, 16),
                                   (4, 7, 128), (8, 45, 64)])
def test_prefix_prefill_bitwise_matches_full(lm, b, p, s):
    m, params = lm
    pre, suf = _prefix_suffix(b, p, s)
    cap = p + s + 9
    lf, cf = m.prefill(params, {"tokens": jnp.concatenate([pre, suf], 1)},
                       cap)
    prefix = m.prefill_prefix(params, pre)
    lp, cp = m.prefill_with_prefix(params, {"tokens": suf}, cap, prefix)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lp))
    _assert_trees_equal(cf, cp)


# ------------------------------------------- full-generation differential
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_prefix_generate_bitwise_matches_full(lm, temperature):
    """prefix-reuse prefill -> fused decode == full prefill -> fused decode:
    same tokens, lengths, ended flags, under greedy AND temperature
    sampling with fixed seeds."""
    m, params = lm
    gen = _generator(m, params, mnt=8, temperature=temperature)
    b, p, s = 4, 45, 32
    pre, suf = _prefix_suffix(b, p, s, seed=3)
    pc = gen.build_prefix_cache([int(t) for t in np.asarray(pre[0])], b)
    ft = gen.generate_with_lengths(
        {"tokens": jnp.concatenate([pre, suf], 1)}, max_new_tokens=8, seed=5)
    pt = gen.generate_with_lengths({"tokens": suf}, max_new_tokens=8, seed=5,
                                   prefix_cache=pc)
    for a, c in zip(ft, pt):
        np.testing.assert_array_equal(a, c)


def test_prefix_generate_matches_host_loop_oracle(lm):
    """Transitivity with the PR-4 oracle: prefix-reuse fused decode ==
    host-driven per-step decode of the concatenated prompt."""
    m, params = lm
    gen = _generator(m, params, mnt=6)
    b, p, s = 2, 45, 16
    pre, suf = _prefix_suffix(b, p, s, seed=7)
    pc = gen.build_prefix_cache([int(t) for t in np.asarray(pre[0])], b)
    pt = gen.generate_with_lengths({"tokens": suf}, max_new_tokens=6, seed=2,
                                   prefix_cache=pc)
    ht = gen.generate_with_lengths(
        {"tokens": jnp.concatenate([pre, suf], 1)}, max_new_tokens=6, seed=2,
        fused=False)
    for a, c in zip(pt, ht):
        np.testing.assert_array_equal(a, c)


def test_prefix_cache_batch_mismatch_raises(lm):
    m, params = lm
    gen = _generator(m, params)
    pre, suf = _prefix_suffix(2, 45, 16)
    pc = gen.build_prefix_cache([int(t) for t in np.asarray(pre[0])], 2)
    with pytest.raises(ValueError, match="batch"):
        gen.generate_with_lengths({"tokens": suf[:1]}, max_new_tokens=4,
                                  prefix_cache=pc)


# ------------------------------------------- hypothesis property
@given(st.data())
@settings(max_examples=10, deadline=None)
def test_prefix_prefill_equivalence_property(lm, data):
    """Bitwise prefix-reuse == full across sampled (batch, suffix bucket,
    prefix length, seed).  Shapes come from a small fixed grid so jit
    compiles stay bounded."""
    m, params = lm
    b = data.draw(st.sampled_from([1, 2, 4]), label="batch")
    s = data.draw(st.sampled_from([16, 32, 64]), label="suffix")
    p = data.draw(st.sampled_from([7, 45]), label="prefix")
    seed = data.draw(st.integers(min_value=0, max_value=2 ** 16), label="seed")
    pre, suf = _prefix_suffix(b, p, s, seed=seed % 97 + 1)
    gen = _generator(m, params, mnt=4)
    pc = gen.build_prefix_cache([int(t) for t in np.asarray(pre[0])], b)
    ft = gen.generate_with_lengths(
        {"tokens": jnp.concatenate([pre, suf], 1)}, max_new_tokens=4,
        seed=seed)
    pt = gen.generate_with_lengths({"tokens": suf}, max_new_tokens=4,
                                   seed=seed, prefix_cache=pc)
    for a, c in zip(ft, pt):
        np.testing.assert_array_equal(a, c)


# ------------------------------------------- explicit arch fallback
def test_unsupported_archs_report_and_raise():
    """Recurrent / windowed / naive-softmax models must say NO (and raise
    rather than silently degrade) — callers fall back to full prefill."""
    cases = [
        get_config("mamba2-130m", smoke=True),                  # SSM
        _flash_cfg(attention_impl="naive"),                     # reassociates
        _flash_cfg(attention_impl="auto"),                      # -> naive
        _flash_cfg(sliding_window=8),                           # windowed
    ]
    for cfg in cases:
        m = build_model(cfg)
        assert not m.supports_prefix_prefill, cfg.name
        with pytest.raises(NotImplementedError):
            m.prefill_prefix(None, jnp.zeros((1, 4), jnp.int32))
        with pytest.raises(NotImplementedError):
            m.prefill_with_prefix(None, {"tokens": jnp.zeros((1, 4),
                                                             jnp.int32)},
                                  16, None)


def test_supported_arch_reports_yes(lm):
    m, _ = lm
    assert m.supports_prefix_prefill
    gen = _generator(m, None)
    assert gen.supports_prefix_prefill


# ------------------------------------------- engine integration
VOCAB_E = 4096


def _engine_stack(small_cfg=None, **router_kw):
    from repro.models.embedder import init_embedder, tiny_embedder_config
    tok = HashWordTokenizer(VOCAB_E)
    ecfg = tiny_embedder_config(VOCAB_E)
    ep = init_embedder(jax.random.PRNGKey(0), ecfg)
    lm_cfg = _flash_cfg(vocab_size=VOCAB_E, max_seq_len=512)
    gc = GenerateConfig(max_new_tokens=6,
                        sampler=SamplerConfig(vocab_size=VOCAB_E))
    big_m = build_model(lm_cfg)
    small_m = build_model(small_cfg or lm_cfg.replace(num_layers=1))
    big = Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gc)
    small = Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gc)
    eng = TweakLLMEngine(
        tokenizer=tok, embedder_params=ep, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=64, dim=ecfg.d_model, topk=4),
        router_cfg=RouterConfig(**router_kw))
    return eng


def _seed_tweak_traffic(eng, n=3):
    eng.populate([f"seeded question number {i} about topic {i}"
                  for i in range(n)],
                 [f"cached answer {i} " + "filler word " * (3 * i)
                  for i in range(n)])
    return eng.handle_batch_result(
        ["a fresh question about something else",
         "yet another question on a new theme",
         "a third distinct question arrives"], max_new_tokens=4)


def test_engine_tweak_uses_prefix_cache_and_buckets():
    eng = _engine_stack(tweak_threshold=-1.0)   # everything routes TWEAK
    assert eng._prefix_path_available()
    res = _seed_tweak_traffic(eng)
    assert eng.stats.tweak == 3
    assert eng._prefix_caches                    # prefix KV was built
    pc = next(iter(eng._prefix_caches.values()))
    assert pc.token_ids == eng._tweak_prefix_ids()
    assert all(isinstance(r, str) and r for r in res.responses)
    # prompt accounting: every tweak row billed prefix + real suffix
    p = len(eng._tweak_prefix_ids())
    assert res.small_prompt_tokens >= 3 * (p + 1)
    assert eng.stats.small_prompt_tokens == res.small_prompt_tokens


def test_engine_prefix_toggle_serves_both_paths():
    """use_prefix_cache=False forces the full-prompt fallback; both paths
    must serve the same traffic and bill identical PROMPT token totals
    (same real prompt content, different prefill strategy)."""
    a = _engine_stack(tweak_threshold=-1.0)
    b = _engine_stack(tweak_threshold=-1.0)
    b.use_prefix_cache = False
    ra = _seed_tweak_traffic(a)
    rb = _seed_tweak_traffic(b)
    assert a.stats.tweak == b.stats.tweak == 3
    assert a._prefix_caches and not b._prefix_caches
    assert ra.small_prompt_tokens == rb.small_prompt_tokens
    assert [len(r) > 0 for r in ra.responses] == \
        [len(r) > 0 for r in rb.responses]


def test_engine_fallback_arch_serves_tweak_without_prefix():
    """A mamba2 small model can't do prefix prefill: the engine must fall
    back explicitly (no prefix caches) and still serve the TWEAK path."""
    cfg = get_config("mamba2-130m", smoke=True)
    eng = _engine_stack(small_cfg=cfg.replace(vocab_size=VOCAB_E,
                                              max_seq_len=512),
                        tweak_threshold=-1.0)
    assert not eng._prefix_path_available()
    res = _seed_tweak_traffic(eng)
    assert eng.stats.tweak == 3
    assert not eng._prefix_caches
    assert all(isinstance(r, str) and r for r in res.responses)
    assert eng.stats.small_prompt_tokens > 0


def test_stale_prefix_cache_rebuilt_on_generator_swap():
    """Swapping the small generator (new model/sampler config) must
    invalidate the cached prefix KV — a stale prefix would corrupt every
    subsequent tweak response silently."""
    eng = _engine_stack(tweak_threshold=-1.0)
    _seed_tweak_traffic(eng)
    old = dict(eng._prefix_caches)
    old_sig = eng._prefix_sig
    assert old
    # same arch, different params + different sampler config
    lm_cfg = _flash_cfg(vocab_size=VOCAB_E, max_seq_len=512, num_layers=1,
                        rope_theta=20_000.0)
    m2 = build_model(lm_cfg)
    eng.small = Generator(m2, m2.init(jax.random.PRNGKey(9)),
                          GenerateConfig(max_new_tokens=6,
                                         sampler=SamplerConfig(
                                             temperature=0.5,
                                             vocab_size=VOCAB_E)))
    eng.handle_batch(["a question that routes to tweak again"],
                     max_new_tokens=4)
    assert eng._prefix_sig != old_sig
    for bucket, pc in old.items():
        assert eng._prefix_caches.get(bucket) is not pc


# ------------------------------------------- truncation bugfix
def test_overlong_cached_response_keeps_adapted_cue():
    """Regression: encode_batch tail-truncation used to cut the trailing
    'adapted response :' cue off over-long tweak prompts.  Truncation must
    come out of the cached-response field instead."""
    tok = HashWordTokenizer(VOCAB_E)
    long_resp = " ".join(f"filler{i}" for i in range(500))
    toks, mask = tweak_lib.build_tweak_batch(
        tok, ["the new question"], ["the old question"], [long_resp], 128)
    row = toks[0][mask[0] > 0].tolist()
    assert len(row) == 128                       # budget filled exactly
    cue = tok.encode(". adapted response :", add_bos=False)
    assert row[-len(cue):] == cue                # cue survives at the end
    nq = tok.encode("the new question", add_bos=False)
    as_str = ",".join(map(str, row))
    assert ",".join(map(str, nq)) in as_str      # new query survives whole
    # suffix variant preserves the cue too
    stoks, smask = tweak_lib.build_tweak_suffix_batch(
        tok, ["the new question"], ["the old question"], [long_resp], 64)
    srow = stoks[0][smask[0] > 0].tolist()
    assert srow[-len(cue):] == cue


def test_truncation_never_drops_statics_raises_when_impossible():
    tok = HashWordTokenizer(VOCAB_E)
    with pytest.raises(ValueError, match="static"):
        tweak_lib.build_tweak_batch(tok, ["q"], ["cq"], ["cr"], 8)


def test_static_overflow_rejected_before_any_state_mutation():
    """A budget that passes the bucket math but can't fit the static
    segments must fail the up-front handle_batch validation — NOT raise
    out of truncation mid-serve, after lookup touched recency and stats
    were partially billed."""
    eng = _engine_stack(tweak_threshold=-1.0)
    eng.populate(["a seeded question about pottery"], ["a cached answer"])
    msl = eng.small.model.cfg.max_seq_len          # 512 in this stack
    statics = eng._tweak_static_tokens()
    assert statics > 16
    # budget 16 fits the bucket check (16 + 495 + 1 <= 512) but not the
    # static segments
    before = (eng.stats.total, eng.stats.exact,
              eng.stats.baseline_prompt_tokens)
    with pytest.raises(ValueError, match="static"):
        eng.handle_batch(["anything routes to tweak"],
                         max_new_tokens=msl - 17)
    assert (eng.stats.total, eng.stats.exact,
            eng.stats.baseline_prompt_tokens) == before


def test_stale_prefix_cache_rebuilt_on_checkpoint_swap_same_config():
    """Swapping the small generator for one with IDENTICAL configs but
    different weights (checkpoint reload) must still invalidate the
    prefix KV — config equality alone cannot see the new params."""
    eng = _engine_stack(tweak_threshold=-1.0)
    _seed_tweak_traffic(eng)
    old_sig = eng._prefix_sig
    old = dict(eng._prefix_caches)
    assert old
    m2 = build_model(eng.small.model.cfg)          # same config
    eng.small = Generator(m2, m2.init(jax.random.PRNGKey(33)),
                          eng.small.cfg)           # same generate config
    eng.handle_batch(["a further question that routes to tweak"],
                     max_new_tokens=4)
    assert eng._prefix_sig != old_sig
    for bucket, pc in old.items():
        assert eng._prefix_caches.get(bucket) is not pc


# ------------------------------------------- prompt-token accounting
def test_prompt_token_accounting_miss_and_exact():
    eng = _engine_stack()          # default router: fresh queries MISS
    res = eng.handle_batch_result(["a totally novel question about chess"],
                                  max_new_tokens=4)
    s = eng.stats
    assert s.big_prompt_tokens > 0                   # real, unpadded
    assert s.big_prompt_tokens <= eng.max_query_len
    assert res.big_prompt_tokens == s.big_prompt_tokens
    assert s.baseline_prompt_tokens == s.big_prompt_tokens
    base = s.baseline_prompt_tokens
    # EXACT repeat: no LLM prompt billed, but the all-Big baseline would
    # still have ingested the query
    eng.handle_batch(["a totally novel question about chess"],
                     max_new_tokens=4)
    assert s.big_prompt_tokens == res.big_prompt_tokens
    assert s.baseline_prompt_tokens > base
    assert s.cost < s.baseline_cost
