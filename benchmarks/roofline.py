"""§Roofline: three-term analysis per (arch x shape x mesh) from the dry-run.

  compute term    = FLOPs        / (chips x 197 TFLOP/s bf16)
  memory term     = HBM bytes    / (chips x 819 GB/s)
  collective term = coll. bytes  / (chips x 50 GB/s/link)

FLOPs: XLA's cost_analysis() counts while-loop bodies ONCE (verified
empirically: flops are ~constant in num_layers under scan), so compute/
memory terms use ANALYTIC per-config formulas (below), cross-checked
against the HLO numbers for the unscanned program parts.  Collective bytes
come from the dry-run HLO with loop-body trip-count scaling (dryrun.py).

MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params —
the ratio MODEL_FLOPS / analytic-HLO-FLOPs exposes remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict

from repro.configs import get_config
from repro.launch.shapes import INPUT_SHAPES

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link
DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


# ------------------------------------------------------- analytic flops

def _attn_flops_per_layer(cfg, seq, batch, kind, window=0):
    """Projections + score/PV flops for one attention layer (fwd)."""
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    tokens = batch * (1 if kind == "decode" else seq)
    proj = 2 * tokens * d * (h + 2 * hk) * dh + 2 * tokens * h * dh * d
    if kind == "decode":
        ctx = min(seq, window) if window else seq
        sc = 2 * batch * h * dh * ctx * 2          # qk + pv, one token
    else:
        eff = min(seq, window) if window else seq
        avg_ctx = eff / 2 if not window else min(window, seq / 2)
        sc = 2 * batch * seq * h * dh * avg_ctx * 2
    return proj + sc


def _mlp_flops_per_layer(cfg, seq, batch, kind):
    tokens = batch * (1 if kind == "decode" else seq)
    mult = 3 if cfg.mlp_type == "swiglu" else 2
    return 2 * tokens * cfg.d_model * cfg.d_ff * mult


def _moe_flops_per_layer(cfg, seq, batch, kind):
    tokens = batch * (1 if kind == "decode" else seq)
    d, e, k, f = cfg.d_model, cfg.num_experts, cfg.experts_per_token, cfg.moe_d_ff
    router = 2 * tokens * d * e
    experts = 2 * tokens * k * 3 * d * f
    # GShard dispatch+combine einsum cost: tokens x E x C x d each way.
    s_g = 1 if kind == "decode" else seq
    cap = max(8, int(cfg.capacity_factor * k * s_g / e + 7) // 8 * 8)
    dispatch = 2 * tokens * e * cap * d * 2
    dense = _mlp_flops_per_layer(cfg, seq, batch, kind) if cfg.moe_dense_residual else 0
    return router + experts + dispatch + dense


def _ssm_flops_per_layer(cfg, seq, batch, kind):
    tokens = batch * (1 if kind == "decode" else seq)
    d, di = cfg.d_model, cfg.ssm_d_inner
    n, h, p = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    g = cfg.ssm_groups
    proj = 2 * tokens * d * (2 * di + 2 * g * n + h) + 2 * tokens * di * d
    conv = 2 * tokens * (di + 2 * g * n) * cfg.ssm_conv_width
    if kind == "decode":
        ssd = 2 * tokens * h * p * n * 2
    else:
        L = min(cfg.ssm_chunk, seq)
        ssd = tokens * (2 * L * g * n + 2 * L * h * p + 8 * h * p * n)
    return proj + conv + ssd


def _rglru_flops_per_layer(cfg, seq, batch, kind):
    tokens = batch * (1 if kind == "decode" else seq)
    d, w = cfg.d_model, cfg.resolved_rnn_width
    return (2 * tokens * d * w * 2 + 2 * tokens * w * d
            + 2 * tokens * w * w * 2 + 10 * tokens * w)


def analytic_fwd_flops(cfg, shape_name: str) -> float:
    seq, batch, kind = INPUT_SHAPES[shape_name]
    total = 0.0
    for i in range(cfg.num_layers):
        k_ = cfg.block_pattern[i % len(cfg.block_pattern)]
        if k_ in ("attn", "local_attn"):
            win = cfg.sliding_window if (k_ == "local_attn" or cfg.sliding_window) else 0
            total += _attn_flops_per_layer(cfg, seq, batch, kind, win)
            total += _mlp_flops_per_layer(cfg, seq, batch, kind)
        elif k_ == "moe":
            win = cfg.sliding_window
            total += _attn_flops_per_layer(cfg, seq, batch, kind, win)
            total += _moe_flops_per_layer(cfg, seq, batch, kind)
        elif k_ == "mamba2":
            total += _ssm_flops_per_layer(cfg, seq, batch, kind)
        elif k_ == "rglru":
            total += _rglru_flops_per_layer(cfg, seq, batch, kind)
            total += _mlp_flops_per_layer(cfg, seq, batch, kind)
    if cfg.enc_layers:  # whisper encoder + cross attention
        f = cfg.enc_frames
        # decode does NOT re-run the encoder (cross K/V cached at prefill)
        enc = 0 if kind == "decode" else cfg.enc_layers * (
            _attn_flops_per_layer(cfg, f, batch, "prefill")
            + _mlp_flops_per_layer(cfg, f, batch, "prefill"))
        tokens = batch * (1 if kind == "decode" else seq)
        cross = cfg.num_layers * (2 * tokens * cfg.d_model
                                  * (cfg.num_heads + 2 * cfg.num_kv_heads)
                                  * cfg.resolved_head_dim
                                  + 2 * tokens * cfg.num_heads
                                  * cfg.resolved_head_dim * f * 2)
        total += enc + cross
    tokens = batch * (1 if kind == "decode" else seq)
    total += 2 * tokens * cfg.d_model * cfg.padded_vocab      # logits
    return total


def analytic_step_flops(cfg, shape_name: str) -> float:
    """Train: fwd + 2x bwd + 1x remat recompute; inference: fwd."""
    seq, batch, kind = INPUT_SHAPES[shape_name]
    f = analytic_fwd_flops(cfg, shape_name)
    if kind == "train":
        return f * (4.0 if cfg.remat else 3.0)
    return f


def model_flops(cfg, shape_name: str) -> float:
    seq, batch, kind = INPUT_SHAPES[shape_name]
    n = cfg.param_count(active_only=True)
    tokens = batch * (1 if kind == "decode" else seq)
    return (6.0 if kind == "train" else 2.0) * n * tokens


# ------------------------------------------------------------- reporting

def load_records(mesh: str = "16x16", dry_dir: str = None):
    d = dry_dir or os.environ.get("DRYRUN_DIR") or (
        DRYRUN_DIR + "_optimized"
        if glob.glob(os.path.join(DRYRUN_DIR + "_optimized", "*.json"))
        else DRYRUN_DIR)
    recs = []
    for f in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def roofline_row(rec: Dict) -> Dict:
    arch, shape = rec["arch"], rec["shape"]
    if rec["status"] != "ok":
        return {"arch": arch, "shape": shape, "status": rec["status"],
                "reason": rec.get("reason", "")}
    cfg = get_config(arch)
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops_total = analytic_step_flops(cfg, shape)
    t_compute = flops_total / (chips * PEAK_FLOPS)
    # memory term: per-device HBM traffic ~ cost_analysis bytes (per device,
    # loop bodies once) is an undercount; floor it with resident bytes/dev.
    mem = rec.get("memory", {})
    resident = sum(mem.get(k, 0) for k in ("argument_size_in_bytes",
                                           "temp_size_in_bytes",
                                           "output_size_in_bytes"))
    hbm_bytes = max(rec.get("cost", {}).get("bytes accessed", 0.0), resident)
    t_memory = hbm_bytes / HBM_BW
    coll = rec.get("collectives", {})
    coll_bytes = sum(v for k, v in coll.items() if isinstance(v, (int, float)))
    t_coll = coll_bytes / ICI_BW
    mf = model_flops(cfg, shape)
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh"], "status": "ok",
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "analytic_flops": flops_total,
        "useful_ratio": mf / max(flops_total, 1.0),
        "mem_per_dev_gib": resident / 2 ** 30,
        "hlo_flops_per_dev": rec.get("cost", {}).get("flops", 0.0),
    }


def main():
    print("# roofline: arch,shape,mesh,t_compute,t_memory,t_collective,"
          "dominant,useful_ratio,mem_gib")
    for mesh in ("16x16",):
        for rec in load_records(mesh):
            r = roofline_row(rec)
            if r.get("status") != "ok":
                print(f"roofline_{r['arch']}_{r['shape']},0.0,"
                      f"SKIPPED:{r.get('reason','')}")
                continue
            print(f"roofline_{r['arch']}_{r['shape']},0.0,"
                  f"tc={r['t_compute_s']:.2e};tm={r['t_memory_s']:.2e};"
                  f"tcoll={r['t_collective_s']:.2e};dom={r['dominant']};"
                  f"useful={r['useful_ratio']:.2f};mem={r['mem_per_dev_gib']:.1f}GiB")


if __name__ == "__main__":
    main()
