"""Speculative decode: cached-response drafts vs plain fused decode (§14).

The TWEAK path's output is, by construction, a light edit of a cached
response whose token ids the bank already holds — so the engine feeds
them to ``Generator`` as a free draft and the fused loop verifies
``spec_k`` positions per forward pass, accepting the longest greedy-
matching prefix (lossless; DESIGN.md §14).  Two parts:

* ``bench_spec_generate`` — spec-vs-plain fused decode swept over draft
  overlap fraction {1.0, 0.9, 0.5, 0.0} x batch x spec_k.  The draft is
  the plain run's own output with its tail rewritten to a provably
  never-matching pattern, so the overlap fraction — and therefore the
  measured ``acceptance_rate`` — is exact and machine-independent.
  ``spec_speedup`` (plain us / spec us, interleaved A/B medians) is the
  gated perf ratio: the acceptance floor is >= 1.5x at full overlap and
  >= 0.95x (no regression) at zero overlap, where every verify block
  is rejected and speculation degenerates to per-row fallback decode.
* ``bench_tweak_acceptance`` — measured acceptance on a REAL
  dup/confusable TWEAK stream: a trained tiny LM serves as both big and
  small model of a ``TweakLLMEngine``, anchor queries seed the bank,
  their paired duplicates / hard negatives route through the router,
  and the engine drafts each cached response into the tweak decode.
  The training matters: an UNDERtrained LM's greedy continuation is so
  prompt-sensitive that the tweak output diverges from the cached
  response at token 0 and speculation never arms — 600 steps collapses
  it enough that cached and tweaked responses genuinely agree (the
  paper's premise).  The resulting ``EngineStats.acceptance_rate`` is
  deterministic (greedy decode, seeded traffic) and gated as a quality
  metric.
"""
from __future__ import annotations

import dataclasses
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CacheConfig, RouterConfig, TweakLLMEngine
from repro.data import QuestionPairGenerator, token_stream_batches
from repro.models import ModelConfig, build_model
from repro.serving import GenerateConfig, Generator, SamplerConfig
from .common import VOCAB, csv_row, get_tokenizer, get_trained_embedder

GEN_VOCAB = 4096
PROMPT_LEN = 16
MNT = 64
_cache: dict = {}


def _generator(mnt: int, k: int) -> Generator:
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=GEN_VOCAB, max_seq_len=1024,
                      dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return Generator(m, params, GenerateConfig(
        max_new_tokens=mnt, sampler=SamplerConfig(vocab_size=GEN_VOCAB),
        spec_k=k))


def _overlap_drafts(ref, overlap: float, mnt: int):
    """Drafts agreeing with ``ref`` on exactly the first overlap*mnt
    positions; the tail is shifted into a disjoint token (never 0-2, never
    the reference id), so greedy verify rejects every tail position."""
    n = int(round(overlap * mnt))
    wrong = (ref + 1 - 3) % (GEN_VOCAB - 3) + 3
    pos = np.arange(mnt)[None, :]
    did = np.where(pos < n, ref, wrong).astype(np.int32)
    return did, np.full((ref.shape[0],), mnt, np.int32)


def _time_spec(gen, batch, drafts, mnt, reps):
    """Median seconds per call for (spec, plain-fused), interleaved A/B
    pairs like bench_generate so runner stalls hit both arms alike."""
    gen.generate_with_lengths(batch, max_new_tokens=mnt, seed=0,
                              drafts=drafts)                  # compile spec
    acc_rate = (gen.last_spec_stats["accepted"]
                / max(gen.last_spec_stats["proposed"], 1))
    gen.generate_with_lengths(batch, max_new_tokens=mnt, seed=0)  # plain
    ts_spec, ts_plain = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        gen.generate_with_lengths(batch, max_new_tokens=mnt, seed=0,
                                  drafts=drafts)
        ts_spec.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        gen.generate_with_lengths(batch, max_new_tokens=mnt, seed=0)
        ts_plain.append(time.perf_counter() - t0)
    return statistics.median(ts_spec), statistics.median(ts_plain), acc_rate


def bench_spec_generate(batches=(1, 8), ks=(4, 8),
                        overlaps=(1.0, 0.9, 0.5, 0.0), reps=5):
    """Spec-vs-plain decode throughput per (batch, k, overlap) bucket.

    Greedy output is draft-independent (lossless contract), so the plain
    run's tokens ARE the model's true continuation — rewriting their tail
    dials in the overlap exactly."""
    for k in ks:
        gen = _generator(MNT, k)
        for b in batches:
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (b, PROMPT_LEN), 5, GEN_VOCAB)}
            ref, lengths, _ = gen.generate_with_lengths(
                batch, max_new_tokens=MNT, seed=0)
            toks = int(lengths.sum())
            for ov in overlaps:
                drafts = _overlap_drafts(np.asarray(ref), ov, MNT)
                s_spec, s_plain, acc = _time_spec(gen, batch, drafts,
                                                  MNT, reps)
                csv_row(f"spec_b{b}_k{k}_ov{int(ov * 100)}", s_spec * 1e6,
                        f"plain_us={s_plain * 1e6:.0f};"
                        f"tok_s_spec={toks / s_spec:.0f};"
                        f"tok_s_plain={toks / s_plain:.0f};tokens={toks}",
                        spec_speedup=round(s_plain / max(s_spec, 1e-9), 2),
                        acceptance_rate=round(acc, 3))


def _trained_speclm(steps: int = 600):
    """Tiny LM trained far enough that its greedy continuations of a
    query and of the tweak prompt built from that query's cached
    response actually overlap (see module docstring)."""
    if "lm" not in _cache:
        cfg = ModelConfig(name="speclm", num_layers=2, d_model=96,
                          num_heads=4, num_kv_heads=2, d_ff=192,
                          vocab_size=VOCAB, max_seq_len=512,
                          dtype="float32")
        from repro.training import (AdamWConfig, init_opt_state,
                                    make_train_step)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(7))
        step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3),
                                       total_steps=steps))
        opt = init_opt_state(params)
        stream = token_stream_batches(get_tokenizer(), 8, 64, seed=3)
        for _ in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
            params, opt, _ = step(params, opt, batch)
        _cache["lm"] = (model, params)
    return _cache["lm"]


def bench_tweak_acceptance(n_pairs: int = 48, spec_k: int = 4,
                           mnt: int = 16, smoke: bool = False):
    """Acceptance rate the engine actually achieves on mixed
    dup / hard-negative / random traffic.

    Big and small share one trained LM, so the cached response is the
    same model's greedy continuation of the original prompt — the
    closest CPU-trainable stand-in for the paper's premise that cached
    and tweaked responses largely agree.  ``n_pairs`` is NOT scaled down
    for smoke: the rate is a ratio of small per-row counts, so shrinking
    the stream makes the gated value noisy, and serving is cheap next to
    the one-time LM training anyway."""
    del smoke
    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    model, params = _trained_speclm()
    gcfg = GenerateConfig(max_new_tokens=mnt,
                          sampler=SamplerConfig(vocab_size=VOCAB))
    big = Generator(model, params, gcfg)
    small = Generator(model, params, dataclasses.replace(gcfg, spec_k=spec_k))
    assert small.speculation_ready
    eng = TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=512, dim=ecfg.d_model, topk=4),
        router_cfg=RouterConfig(tweak_threshold=0.3))
    pairs = QuestionPairGenerator(seed=5).generate(n_pairs, dup_frac=0.75,
                                                   hard_frac=0.25)
    eng.handle_batch([a.text for a, _, _ in pairs], max_new_tokens=mnt)
    t0 = time.perf_counter()
    eng.handle_batch([b.text for _, b, _ in pairs], max_new_tokens=mnt)
    us = (time.perf_counter() - t0) / n_pairs * 1e6
    s = eng.stats
    assert s.tweak > 0, "dup stream must route some TWEAK traffic"
    assert s.proposed > 0, "TWEAK rows must carry cached-response drafts"
    csv_row("spec_tweak_stream", us,
            f"tweak={s.tweak};proposed={s.proposed};accepted={s.accepted};"
            f"spec_steps={s.spec_steps}",
            acceptance_rate=round(s.acceptance_rate, 3))


def main(smoke: bool = False):
    if smoke:
        # CI perf-gate subset: the b=1 dispatch-bound cell (the regime a
        # CPU runner can meaningfully measure — at b=8 the tiny model's
        # k-wide lm_head matmul is compute-bound and the verify block
        # buys nothing) at ALL overlap points, because the
        # 1.5x-at-full-overlap and 0.95x-at-zero-overlap acceptance
        # numbers are both gated, so both ends of the sweep must run
        bench_spec_generate(batches=(1,), ks=(4,), reps=7)
        bench_tweak_acceptance(smoke=True)
        return
    bench_spec_generate()
    bench_tweak_acceptance()


if __name__ == "__main__":
    main()
