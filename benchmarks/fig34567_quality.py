"""Figs 3-7: response-quality protocols per cosine-similarity band.

Runs the REAL pipeline — embedder similarity, band assignment, tweak-prompt
machinery, loglik judge, 3-persona x 2-round debate — over paired queries.
Response texts follow the synthetic-response protocol (big-quality template
for Big-LLM-direct and for the cached response the tweaker adapts;
small-quality template for Small-LLM-direct), see benchmarks/common.py.

  Fig 3/4 (user study)  -> simulated raters = per-persona satisfaction votes
  Fig 5   (QP dataset)  -> debate: Big direct vs Small TWEAKED
  Fig 6   (control)     -> debate: Big direct vs Small DIRECT (no tweak)
  Fig 7   (LMSYS-like)  -> Fig 5 protocol on the workload stream

Expected trends (the reproduction targets): tweaked quality rises with the
similarity band and approaches parity; small-direct loses clearly.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.router import band_of
from repro.data import QuestionPairGenerator, synthesize_response
from repro.eval import debate_batch, make_loglik_scorer, PERSONAS, persona_score
from repro.eval.debate import verdict_shares
from repro.models.embedder import encode as embed_encode
from .common import csv_row, get_judge_lm, get_tokenizer, get_trained_embedder


def _tweaked_response(new_q, cached_q, cached_resp, sim: float,
                      same_cell: bool, new_topic_resp: str,
                      rng: np.random.Generator):
    """Protocol model of the Small LLM's tweak: the cached (big-quality)
    response adapted toward the new query.

    * same intent+topic (true duplicate): query swap suffices — quality is
      the Big LLM's, modulo small surface edits.
    * near-miss hit (cache returned a related-but-different question, the
      regime the paper says needs 'more substantial, potentially
      lower-quality modifications'): the tweaker recovers partially — the
      response mixes corrected content with stale fragments, more stale the
      lower the similarity."""
    adapted = cached_resp.replace(f"(answering: {cached_q})",
                                  f"(answering: {new_q})")
    if same_cell:
        # surface degradation from rewriting, rarer the closer the match
        if rng.random() < max(0.05, min(0.6, (0.96 - sim) * 1.5)):
            adapted = adapted.replace("consult expert resources.", "")
        return adapted
    # near-miss: blend recovered answer with stale cached fragments
    stale = max(0.0, min(0.9, (0.92 - sim) * 3.0))
    parts_new = new_topic_resp.split(". ")
    parts_old = adapted.split(". ")
    out = []
    for i in range(max(len(parts_new), len(parts_old))):
        if rng.random() < stale and i < len(parts_old):
            out.append(parts_old[i])
        elif i < len(parts_new):
            out.append(parts_new[i])
    return ". ".join(out)


def _band_table(bands, verdicts):
    out = {}
    for b in range(3):
        rs = [v for bb, v in zip(bands, verdicts) if bb == b]
        if rs:
            out[b] = verdict_shares(rs)
    return out


def run(n_pairs: int = 240, seed: int = 0):
    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    judge_model, judge_params = get_judge_lm()
    score = make_loglik_scorer(judge_model, judge_params, tok, max_len=128)
    gen = QuestionPairGenerator(seed=seed)
    rng = np.random.default_rng(seed + 99)
    # Cache-hit population = true duplicates AND near-miss hits (hard
    # negatives that still clear the similarity threshold) — the realistic
    # hit mix the paper's §5.2 bands contain.
    pairs = ([gen.duplicate_pair() + (True,) for _ in range(n_pairs)]
             + [gen.hard_negative_pair() + (False,) for _ in range(n_pairs)])

    embed = jax.jit(lambda t, m: embed_encode(eparams, t, m, ecfg))
    t1, m1 = tok.encode_batch([a.text for a, b, s in pairs], 32)
    t2, m2 = tok.encode_batch([b.text for a, b, s in pairs], 32)
    e1 = np.asarray(embed(jnp.asarray(t1), jnp.asarray(m1)))
    e2 = np.asarray(embed(jnp.asarray(t2), jnp.asarray(m2)))
    sims = np.sum(e1 * e2, axis=1)
    bands = np.asarray(band_of(jnp.asarray(sims)))

    keep = bands >= 0  # only tweak-path queries (sim >= 0.7), per paper
    idx = np.nonzero(keep)[0]
    queries, big_direct, tweaked, small_direct = [], [], [], []
    for i in idx:
        a, b, same_cell = pairs[i]
        queries.append(b.text)
        big = synthesize_response(b.text, b.topic, b.intent, quality="big")
        cached = synthesize_response(a.text, a.topic, a.intent, quality="big")
        big_direct.append(big)
        tweaked.append(_tweaked_response(b.text, a.text, cached,
                                         float(sims[i]), same_cell, big, rng))
        small_direct.append(synthesize_response(b.text, b.topic, b.intent,
                                                quality="small"))
    bands_k = bands[idx]

    ll_big = score(queries, big_direct)
    ll_twk = score(queries, tweaked)
    ll_sml = score(queries, small_direct)

    # Fig 3: satisfaction (binary votes by persona scorers).  Thresholds
    # are calibrated per persona so Big-direct satisfaction sits in the
    # paper's ~80% regime; tweaked satisfaction then varies freely.
    ps_big = np.array([[persona_score(p, float(ll_big[i]), q, big_direct[i])
                        for p in PERSONAS] for i, q in enumerate(queries)])
    ps_twk = np.array([[persona_score(p, float(ll_twk[i]), q, tweaked[i])
                        for p in PERSONAS] for i, q in enumerate(queries)])
    thr = np.quantile(ps_big, 0.2, axis=0)        # (n_personas,)
    sat = {b: {"big": [], "twk": []} for b in range(3)}
    for i in range(len(queries)):
        for j in range(len(PERSONAS)):
            sat[bands_k[i]]["big"].append(ps_big[i, j] > thr[j])
            sat[bands_k[i]]["twk"].append(ps_twk[i, j] > thr[j])

    # Figs 4/5/7: side-by-side debates big-direct (A) vs tweaked (B)
    d_twk = debate_batch(queries, big_direct, tweaked,
                         [float(x) for x in ll_big], [float(x) for x in ll_twk],
                         seed=seed)
    # Fig 6 control: big direct vs small DIRECT
    d_sml = debate_batch(queries, big_direct, small_direct,
                         [float(x) for x in ll_big], [float(x) for x in ll_sml],
                         seed=seed + 1)
    return bands_k, sat, d_twk, d_sml


def main():
    bands, sat, d_twk, d_sml = run()
    names = ["0.7-0.8", "0.8-0.9", "0.9-1.0"]
    print("# fig3: satisfaction rating by band (big vs tweaked)")
    for b in range(3):
        if sat[b]["big"]:
            sb = np.mean(sat[b]["big"]) * 100
            st = np.mean(sat[b]["twk"]) * 100
            print(f"fig3_band_{names[b]},0.0,big={sb:.1f}%;tweaked={st:.1f}%")
    print("# fig5/7: debate verdicts by band (A=big direct, B=small tweaked)")
    tw = _band_table(bands, d_twk)
    for b, sh in tw.items():
        par = (sh["B"] + sh["AB"]) * 100
        print(f"fig5_band_{names[b]},0.0,"
              f"A={sh['A']:.2f};B={sh['B']:.2f};AB={sh['AB']:.2f};"
              f"tweaked_better_or_par={par:.1f}%")
    print("# fig6 control: big direct vs small direct")
    sm = _band_table(bands, d_sml)
    for b, sh in sm.items():
        print(f"fig6_band_{names[b]},0.0,"
              f"A={sh['A']:.2f};B={sh['B']:.2f};AB={sh['AB']:.2f}")
    # trend summary: tweaked parity should rise with band; small-direct loses
    par = [100 * (tw[b]["B"] + tw[b]["AB"]) for b in sorted(tw)]
    ctl = [100 * sm[b]["A"] for b in sorted(sm)]
    csv_row("fig567_summary", 0.0,
            f"tweaked_par_by_band={'/'.join(f'{p:.0f}%' for p in par)};"
            f"smalldirect_bigwins={'/'.join(f'{p:.0f}%' for p in ctl)}")


if __name__ == "__main__":
    main()
