"""Fused on-device decode loop vs the host-driven loop (DESIGN.md §8).

Serving decode on the TWEAK and MISS paths used to pay one device dispatch
plus one host sync PER TOKEN; the fused ``lax.while_loop`` decode returns
the whole (B, max_new_tokens) block from a single dispatch.  This bench
measures end-to-end ``generate`` (prefill + decode) for both loops across
(batch x max_new_tokens) buckets and reports per-token throughput; the
``speedup`` ratio (host us / fused us) is machine-independent and gated by
``benchmarks/check_regression.py`` in the ``bench-smoke`` CI job.
"""
from __future__ import annotations

import statistics
import time

import jax

from repro.models import ModelConfig, build_model
from repro.serving import GenerateConfig, Generator, SamplerConfig
from .common import csv_row

VOCAB = 4096
PROMPT_LEN = 16


def _generator(mnt: int) -> Generator:
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=VOCAB, max_seq_len=1024,
                      dtype="float32")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return Generator(m, params, GenerateConfig(
        max_new_tokens=mnt, sampler=SamplerConfig(vocab_size=VOCAB)))


def _time_generate(gen, batch, mnt, reps):
    """Median seconds per call for (fused, host) plus real tokens per call.

    Fused/host calls are interleaved (A/B pairs) and reduced by the median
    so CPU-quota stalls on shared runners hit both loops alike instead of
    whichever loop happened to run during the spike — the speedup RATIO is
    the CI-gated quantity, so its stability is what matters.
    """
    _, lengths, _ = gen.generate_with_lengths(
        batch, max_new_tokens=mnt, seed=0, fused=True)       # compile fused
    gen.generate_with_lengths(batch, max_new_tokens=mnt, seed=0,
                              fused=False)                   # compile host
    toks = int(lengths.sum())
    ts_fused, ts_host = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        gen.generate_with_lengths(batch, max_new_tokens=mnt, seed=0,
                                  fused=True)
        ts_fused.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        gen.generate_with_lengths(batch, max_new_tokens=mnt, seed=0,
                                  fused=False)
        ts_host.append(time.perf_counter() - t0)
    return statistics.median(ts_fused), statistics.median(ts_host), toks


def bench_generate(batches=(1, 4, 8), mnts=(16, 64), reps=5):
    """Fused vs host decode throughput per (batch, max_new_tokens) bucket.

    Batches <= 8 on CPU are the dispatch-bound regime the fused loop
    targets (§5.2.3 of the paper: the paths routing is supposed to make
    cheap); per-token speedup there is the gated acceptance metric.
    """
    for mnt in mnts:
        gen = _generator(mnt)
        for b in batches:
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (b, PROMPT_LEN), 5, VOCAB)}
            s_fused, s_host, toks = _time_generate(gen, batch, mnt, reps)
            tok_s_fused = toks / s_fused
            tok_s_host = toks / s_host
            csv_row(f"generate_fused_b{b}_t{mnt}", s_fused * 1e6,
                    f"host_us={s_host * 1e6:.0f};tok_s_fused={tok_s_fused:.0f};"
                    f"tok_s_host={tok_s_host:.0f};tokens={toks}",
                    speedup=round(s_host / max(s_fused, 1e-9), 2))


def main(smoke: bool = False):
    if smoke:
        # CI perf-gate subset: the t=32 bucket amortises timer noise better
        # than t=16 on throttled shared runners while staying fast
        bench_generate(batches=(1, 8), mnts=(32,), reps=7)
        return
    bench_generate()


if __name__ == "__main__":
    main()
