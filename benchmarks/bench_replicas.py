"""Replica bench: aggregate throughput scaling + shared-bank hit convergence.

Two parts (DESIGN.md §12):

* ``bench_scaling`` — pure queueing simulation under a ``SimClock``: N
  modeled replicas behind a :class:`ReplicaScheduler`, all-distinct
  queries on a Poisson trace offered at 2x the fleet's saturation rate
  (the knee), with the same fixed affine service model the scheduler
  bench gates on.  Aggregate delivered tokens/s must rise monotonically
  with replica count 1 -> 2 -> 4, and the 4-replica scaling efficiency
  ``tok_s(4) / (4 * tok_s(1))`` is a deterministic, machine-independent
  ratio the CI gate holds a floor on.  p50/p99 at the knee are reported
  per replica count.
* ``bench_hit_convergence`` — REAL engines on a Zipf-repeating trace
  (arrivals drawn Zipfian over a pool of lmsys-profile query texts, so
  the repetition is EXACT-text, paper §6.1's fast path): the same trace
  is served by a single engine, by 2 replicas over ONE shared bank, and
  by 2 replicas with private banks.
  With the shared bank, a commit from either replica serves both, so the
  fleet hit rate converges to the single-cache reference
  (``hit_ratio ~ 1``); private banks split the query stream and lose the
  cross-replica hits (the degraded baseline).  Both ratios are
  deterministic (SimClock trace, exact-or-miss routing) and gated.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import ReplicaGroup, TweakLLMEngine
from repro.data import WorkloadGenerator
from repro.serving import (ReplicaScheduler, Scheduler, SchedulerConfig,
                           SimClock, poisson_trace, replay_trace)
from repro.launch.serve import build_stack

from .bench_scheduler import _ModeledEngine
from .common import csv_row

MAX_NEW_TOKENS = 4


def _distinct_queries(n: int, tag: str) -> List[str]:
    return [f"{tag} question number {i} about subject {i}" for i in range(n)]


def bench_scaling(n: int = 1000, replica_counts=(1, 2, 4),
                  max_batch: int = 16, max_wait: float = 0.02,
                  smoke: bool = False):
    """Criterion: aggregate tokens/s rises monotonically 1 -> 2 -> 4."""
    def service_model(b: int) -> float:
        return 0.010 + 0.002 * b   # dispatch overhead + per-row cost

    if smoke:
        n = 320
    cap_single = max_batch / service_model(max_batch)  # one lane, saturated
    tok_s: Dict[int, float] = {}
    for r in replica_counts:
        # all-distinct queries (no dedup joins) at 2x the FLEET capacity:
        # every lane saturates, so delivered tokens/s measures scaling,
        # not routing luck or coalescing
        trace = poisson_trace(_distinct_queries(n, f"scale{r}"),
                              2.0 * r * cap_single, seed=1)
        sched = ReplicaScheduler(
            [_ModeledEngine() for _ in range(r)],
            SchedulerConfig(max_wait=max_wait, max_batch=max_batch,
                            queue_capacity=n + 1,
                            max_new_tokens=MAX_NEW_TOKENS),
            clock=SimClock(), service_model=service_model)
        done = replay_trace(sched, trace)
        assert len(done) == n and sched.stats.rejected == 0
        lats = np.array([q.latency for q in done])
        span = max(q.finish for q in done) - trace[0][0]
        p50, p99 = np.percentile(lats, (50, 99))
        tok_s[r] = n * MAX_NEW_TOKENS / span
        csv_row(f"replicas_scaling_r{r}", float(lats.mean()) * 1e6,
                f"tok_s={tok_s[r]:.0f};p50={p50*1e3:.2f}ms;"
                f"p99={p99*1e3:.2f}ms;stolen={sched.stats.stolen};"
                f"mean_batch={sched.stats.mean_batch:.1f}")
    rs = sorted(tok_s)
    assert all(tok_s[a] < tok_s[b] for a, b in zip(rs, rs[1:])), \
        f"aggregate tokens/s not monotonic in replica count: {tok_s}"
    hi = max(rs)
    eff = tok_s[hi] / (hi * tok_s[min(rs)])
    csv_row("replicas_scaling_eff", 0.0,
            ";".join(f"r{r}={tok_s[r]:.0f}" for r in rs),
            scaling_eff=round(eff, 3))


def _hit_rate(stats) -> float:
    return (stats.exact + stats.tweak) / max(stats.total, 1)


def _zipf_trace(n: int, rate: float, *, pool: int, alpha: float = 1.1,
                seed: int = 1):
    """Poisson arrivals, texts drawn Zipfian over a fixed query pool.

    The WorkloadGenerator's own repetition is paraphrase-level (its
    exact-repeat probability is tiny), which the exact-or-miss router
    deliberately cannot hit; drawing arrivals over a pool makes the
    repeats byte-identical, so the hit-rate ratios measure the SHARED
    BANK, not embedder luck."""
    wl = WorkloadGenerator(profile="lmsys", seed=0)
    texts: List[str] = []
    for q in wl.sample(4 * pool):
        if q.text not in texts:
            texts.append(q.text)
        if len(texts) == pool:
            break
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, len(texts) + 1) ** alpha
    p /= p.sum()
    return poisson_trace([texts[i] for i in rng.choice(len(texts), n, p=p)],
                         rate, seed=seed)


def bench_hit_convergence(n: int = 400, rate: float = 200.0,
                          smoke: bool = False):
    """Criterion: shared-bank fleet hit rate == single-cache reference;
    private banks measurably below both."""
    if smoke:
        n = 200
    # threshold > 1 disables the TWEAK band: hits are byte-identical
    # repeats (EXACT), so all three runs route deterministically and the
    # ratios are machine-independent
    stack = build_stack(train_embedder_steps=0, capacity=4096, threshold=1.1)
    trace = _zipf_trace(n, rate, pool=max(n // 5, 24))
    cfg = SchedulerConfig(max_wait=0.02, max_batch=8,
                          max_new_tokens=MAX_NEW_TOKENS)

    single = TweakLLMEngine(**stack)
    done = replay_trace(Scheduler(single, cfg, clock=SimClock()), trace)
    assert len(done) == n

    rates: Dict[str, float] = {"single": _hit_rate(single.stats)}
    for mode in ("shared", "private"):
        group = ReplicaGroup.build(2, shared=(mode == "shared"), **stack)
        done = replay_trace(
            ReplicaScheduler(group.engines, cfg, clock=SimClock()), trace)
        assert len(done) == n
        rates[mode] = _hit_rate(group.stats)
        csv_row(f"replicas_hit_{mode}", 0.0,
                f"hit_rate={rates[mode]:.3f};single={rates['single']:.3f};"
                f"n={n}")

    # the two gated ratios: shared bank converges to the single-cache
    # reference; private banks demonstrably do not
    csv_row("replicas_hit_convergence", 0.0,
            f"shared={rates['shared']:.3f};single={rates['single']:.3f}",
            hit_ratio=round(rates["shared"] / max(rates["single"], 1e-9), 3))
    csv_row("replicas_shared_vs_private", 0.0,
            f"shared={rates['shared']:.3f};private={rates['private']:.3f}",
            hit_ratio=round(rates["shared"] / max(rates["private"], 1e-9), 3))


def main(smoke: bool = False):
    bench_scaling(smoke=smoke)
    bench_hit_convergence(smoke=smoke)


if __name__ == "__main__":
    main()
