"""Fig 2: precision/recall of GPTCache-style caching + the cost-quality
frontier of the calibrated router cascade.

Two protocols share this module:

* ``run`` — the paper's §4.2.1 P/R sweep: for each labeled pair, put(q1)
  then get(q2) with re-rank, growing the cache; sweep the ANN cosine
  threshold; P/R from the human duplicate labels.  Paper finds ~0.90
  precision @ 0.70 and recall collapsing to ~0.2 by ~0.97 precision.
* ``run_frontier`` — the decision layer's operating sweep (DESIGN.md
  §13): serve the same labeled stream through the REAL routing kernels
  (``threshold_for`` / ``route_cascade`` / ``stage2_combine`` over a
  trained ``score_shortlist`` reranker) at several ``cost_threshold``
  operating points, once single-stage (band = 0) and once as the full
  cascade.  Each point reports hit rate, judge-scored response quality
  (loglik under the trained judge LM, normalized small-direct = 0 /
  big-direct = 1) and $-cost vs all-Big; the scalar gate is the area
  under the cost-threshold → quality-weighted-savings curve.  Retrieval scores are shared
  across points — only the decision boundary moves — so the cache
  touch/insert machinery (byte-identity-tested elsewhere) stays out of
  the protocol.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.router import (MISS, TWEAK, EXACT, UNCERTAIN, RouterConfig,
                               route_cascade, stage2_combine, threshold_for)
from repro.data import QuestionPairGenerator
from repro.data.questions import synthesize_response
from repro.models.embedder import encode as embed_encode
from repro.models.reranker import score_shortlist
from .common import (csv_row, get_judge_lm, get_tokenizer,
                     get_trained_embedder, get_trained_reranker)

THRESHOLDS = np.arange(0.70, 1.00, 0.02)

# frontier operating points and the per-request $-cost model (relative to
# one Big generation; TWEAK pays the Small model, EXACT only retrieval)
COST_POINTS = (0.0, 0.25, 0.5, 0.75, 1.0)
BIG_COST, TWEAK_COST, EXACT_COST = 1.0, 0.3, 0.02
SHORTLIST_K = 4


def run(n_pairs: int = 400, seed: int = 0):
    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    gen = QuestionPairGenerator(seed=seed)
    pairs = gen.generate(n_pairs, dup_frac=0.5, hard_frac=0.25)

    q1 = [a.text for a, b, l in pairs]
    q2 = [b.text for a, b, l in pairs]
    labels = np.asarray([l for a, b, l in pairs], bool)

    embed = jax.jit(lambda t, m: embed_encode(eparams, t, m, ecfg))
    t1, m1 = tok.encode_batch(q1, 32)
    t2, m2 = tok.encode_batch(q2, 32)
    t0 = time.perf_counter()
    e1 = np.asarray(embed(jnp.asarray(t1), jnp.asarray(m1)))
    e2 = np.asarray(embed(jnp.asarray(t2), jnp.asarray(m2)))
    embed_us = (time.perf_counter() - t0) / (2 * n_pairs) * 1e6

    # GPTCache protocol: put(q1_i), get(q2_i), then put(q2_i) — the cache
    # grows as the stream proceeds (§4.2.1).  A hit is CORRECT iff the
    # retrieved entry has the same (topic, intent) cell as the query —
    # returning its cached response would actually answer the question.
    cell1 = [(a.topic, a.intent) for a, b, l in pairs]
    cell2 = [(b.topic, b.intent) for a, b, l in pairs]
    bank_e, bank_c = [], []
    scores = np.zeros(n_pairs)
    hit_correct = np.zeros(n_pairs, bool)
    for i in range(n_pairs):
        bank_e.append(e1[i])
        bank_c.append(cell1[i])
        sims = np.asarray(bank_e) @ e2[i]
        j = int(np.argmax(sims))
        scores[i] = sims[j]
        hit_correct[i] = bank_c[j] == cell2[i]
        bank_e.append(e2[i])
        bank_c.append(cell2[i])
    curve = []
    for t in THRESHOLDS:
        hits = scores >= t
        tp = float(np.sum(hits & hit_correct))
        fp = float(np.sum(hits & ~hit_correct))
        fn = float(np.sum(~hits & labels))
        p = tp / max(tp + fp, 1e-9)
        r = tp / max(tp + fn, 1e-9)
        curve.append((t, p, r))
    return curve, embed_us


def run_frontier(n_pairs: int = 240, seed: int = 0,
                 reranker_steps: int = 300, band: float = 0.12):
    """Sweep the router's operating points; returns the frontier report.

    ``n_pairs`` is the total stream size (half true duplicates, half hard
    negatives); the bank holds every stream query's partner, so retrieval
    is against a realistic mixed population.
    """
    from repro.eval.judge import make_loglik_scorer
    from .fig34567_quality import _tweaked_response

    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    rr_params, rr_cfg = get_trained_reranker(steps=reranker_steps)
    judge_model, judge_params = get_judge_lm()
    judge = make_loglik_scorer(judge_model, judge_params, tok, max_len=128)

    gen = QuestionPairGenerator(seed=seed)
    rng = np.random.default_rng(seed + 17)
    n_dup = n_conf = n_pairs // 3
    bank_q, new_q = [], []
    for _ in range(n_dup):
        a, b = gen.duplicate_pair()
        bank_q.append(a)
        new_q.append(b)
    # confusable triples: the bank holds BOTH the true partner and a
    # lexically-close wrong-cell distractor — the misroute population the
    # reranker's shortlist re-selection is measured on
    for _ in range(n_conf):
        a, b, neg = gen.triple()
        bank_q += [a, neg]
        new_q.append(b)
    for _ in range(n_pairs - n_dup - n_conf):
        a, b = gen.hard_negative_pair()
        bank_q.append(a)
        new_q.append(b)
    B = len(new_q)

    embed = jax.jit(lambda t, m: embed_encode(eparams, t, m, ecfg))
    tb_, mb_ = tok.encode_batch([q.text for q in bank_q], 32)
    tq_, mq_ = tok.encode_batch([q.text for q in new_q], 32)
    e_bank = np.asarray(embed(jnp.asarray(tb_), jnp.asarray(mb_)))
    e_new = np.asarray(embed(jnp.asarray(tq_), jnp.asarray(mq_)))

    # retrieval is shared by every operating point: scores/idx never move,
    # only the decision boundary tau does
    sims = e_new @ e_bank.T
    idx = np.argsort(-sims, axis=1)[:, :SHORTLIST_K]
    scores = np.take_along_axis(sims, idx, axis=1).astype(np.float32)
    top1 = scores[:, 0]

    # one reranker pass over the same shortlist = the stage-2 evidence
    ct, cm = tok.encode_batch([q.text for q in bank_q], 24)
    qt, qm = tok.encode_batch([q.text for q in new_q], 24)
    rr = np.asarray(score_shortlist(
        rr_params, jnp.asarray(qt), jnp.asarray(qm),
        jnp.asarray(np.asarray(ct)[idx]), jnp.asarray(np.asarray(cm)[idx]),
        rr_cfg))

    # stage-2 candidate re-selection at the default operating point (the
    # blended-evidence argmax from router.stage2_combine); the re-selected
    # serving text is judged once and reused across points — the blend's
    # cosine term moves only mildly with tau
    live = jnp.ones((B, SHORTLIST_K), bool)
    tau0 = threshold_for(jnp.full((B,), RouterConfig().default_cost,
                                  jnp.float32), RouterConfig())
    _, best0, _ = stage2_combine(jnp.asarray(scores), jnp.asarray(rr),
                                 live, tau0, RouterConfig(band=band))
    rr_pick = np.asarray(best0)

    # response protocol + judge: per query at most three served texts —
    # Big regeneration (MISS), tweak from the cosine top-1, tweak from the
    # reranker-chosen candidate — judged ONCE, reused across all points
    cell_b = [(q.topic, q.intent) for q in bank_q]
    cell_n = [(q.topic, q.intent) for q in new_q]
    cached = [synthesize_response(q.text, q.topic, q.intent, quality="big")
              for q in bank_q]
    big_direct = [synthesize_response(q.text, q.topic, q.intent,
                                      quality="big") for q in new_q]
    small_direct = [synthesize_response(q.text, q.topic, q.intent,
                                        quality="small") for q in new_q]

    def tweak_from(i, pos):
        j = int(idx[i, pos])
        return _tweaked_response(new_q[i].text, bank_q[j].text, cached[j],
                                 float(sims[i, j]), cell_b[j] == cell_n[i],
                                 big_direct[i], rng)

    queries = [q.text for q in new_q]
    served_top1 = [tweak_from(i, 0) for i in range(B)]
    served_rr = [tweak_from(i, int(rr_pick[i])) for i in range(B)]
    ll_big = judge(queries, big_direct)
    ll_small = judge(queries, small_direct)
    span = np.maximum(ll_big - ll_small, 1e-6)

    def norm(ll):  # quality in [0,1]: small-direct = 0, big-direct = 1
        return np.clip((ll - ll_small) / span, 0.0, 1.0)

    q_big = norm(ll_big)
    q_top1 = norm(judge(queries, served_top1))
    q_rr = norm(judge(queries, served_rr))

    # misroute recovery inside the paper's 0.7-0.9 uncertainty band: the
    # cosine top-1 answers a different (topic, intent) cell, a same-cell
    # candidate IS in the shortlist, and stage 2's blended re-selection
    # picks it
    cand_ok = np.asarray([[cell_b[int(j)] == cell_n[i] for j in idx[i]]
                          for i in range(B)])
    picked_ok = cand_ok[np.arange(B), rr_pick]
    elig_any = ~cand_ok[:, 0] & cand_ok.any(axis=1)
    in_band = (top1 >= 0.7) & (top1 < 0.9)
    eligible = elig_any & in_band
    recovered = eligible & picked_ok
    # the other side of re-selection: in-band rows whose top-1 was already
    # correct but stage 2 moved off it (should stay ~0)
    broken = in_band & cand_ok[:, 0] & ~picked_ok

    variants = {"single": RouterConfig(),
                "cascade": RouterConfig(band=band, commit_at=0.45)}
    curves = {}
    t0 = time.perf_counter()
    for vname, rcfg in variants.items():
        pts = []
        for c in COST_POINTS:
            tau = threshold_for(jnp.full((B,), c, jnp.float32), rcfg)
            d = np.asarray(route_cascade(jnp.asarray(top1), tau, rcfg))
            use_rr = np.zeros(B, bool)
            n_unc = int(np.sum(d == UNCERTAIN))
            if n_unc:
                commit, _best, _conf = stage2_combine(
                    jnp.asarray(scores), jnp.asarray(rr), live, tau, rcfg)
                commit = np.asarray(commit)
                unc = d == UNCERTAIN
                use_rr = unc & commit    # stage 2 re-selects the candidate
                d = np.where(unc, np.where(commit, TWEAK, MISS), d)
            quality = np.where(d == MISS, q_big,
                               np.where(use_rr, q_rr, q_top1))
            dollars = np.where(d == MISS, BIG_COST,
                               np.where(d == EXACT, EXACT_COST, TWEAK_COST))
            pts.append(dict(cost=c, tau=float(np.mean(np.asarray(tau))),
                            uncertain=n_unc,
                            hit_rate=float(np.mean(d != MISS)),
                            quality=float(np.mean(quality)),
                            cost_ratio=float(np.mean(dollars) / BIG_COST)))
        curves[vname] = pts
    sweep_us = (time.perf_counter() - t0) / (2 * len(COST_POINTS)) * 1e6

    def auc(pts):
        # area under cost_threshold -> quality-weighted $-savings: the
        # expected judged-quality-discounted fraction of the all-Big bill
        # saved across the whole operating range.  (Integrating quality
        # over savings instead is degenerate here — tweak quality stays
        # near Big-direct, so that area ignores the hit-rate advantage.)
        ys = [p["quality"] * (1.0 - p["cost_ratio"]) for p in pts]
        return float(np.trapz(ys, [p["cost"] for p in pts]))

    dominates = sum(
        1 for s, ca in zip(curves["single"], curves["cascade"])
        if ca["hit_rate"] > s["hit_rate"] + 1e-9
        and ca["quality"] >= s["quality"] - 0.015)
    return dict(curves=curves, sweep_us=sweep_us,
                auc={v: auc(pts) for v, pts in curves.items()},
                dominates=dominates,
                recovery=dict(eligible=int(eligible.sum()),
                              recovered=int(recovered.sum()),
                              eligible_any=int(elig_any.sum()),
                              recovered_any=int((elig_any & picked_ok).sum()),
                              broken=int(broken.sum())))


def frontier_main(smoke: bool = False):
    rep = run_frontier(n_pairs=96 if smoke else 240,
                       reranker_steps=150 if smoke else 300)
    print("# frontier: variant,cost,tau,hit_rate,quality,cost_ratio")
    for vname, pts in rep["curves"].items():
        for p in pts:
            csv_row(f"frontier_{vname}@c{p['cost']:.2f}", rep["sweep_us"],
                    f"tau={p['tau']:.3f};uncertain={p['uncertain']}",
                    hit_rate=round(p["hit_rate"], 4),
                    quality=round(p["quality"], 4),
                    cost_ratio=round(p["cost_ratio"], 4))
    default = [p for p in rep["curves"]["cascade"]
               if abs(p["cost"] - RouterConfig().default_cost) < 1e-9][0]
    csv_row("frontier_single", rep["sweep_us"], "",
            frontier_auc=round(rep["auc"]["single"], 4))
    csv_row("frontier_cascade", rep["sweep_us"],
            f"dominates={rep['dominates']}/{len(COST_POINTS)}",
            frontier_auc=round(rep["auc"]["cascade"], 4))
    csv_row("frontier_default_op", rep["sweep_us"], "cascade@default_cost",
            hit_ratio=round(default["hit_rate"], 4),
            quality=round(default["quality"], 4))
    r = rep["recovery"]
    csv_row("frontier_band_recovery", rep["sweep_us"],
            f"stage-2 re-selection, top1 in [0.7,0.9); any-sim "
            f"{r['recovered_any']}/{r['eligible_any']}",
            recovered=r["recovered"], eligible=r["eligible"],
            broken=r["broken"])


def main():
    curve, embed_us = run()
    print("# fig2: threshold,precision,recall")
    for t, p, r in curve:
        print(f"fig2_pr@{t:.2f},{embed_us:.1f},precision={p:.3f};recall={r:.3f}")
    p070 = [c for c in curve if abs(c[0] - 0.70) < 1e-6][0]
    hi = max(curve, key=lambda c: c[1])
    csv_row("fig2_summary", embed_us,
            f"P@0.70={p070[1]:.3f};R@0.70={p070[2]:.3f};"
            f"maxP={hi[1]:.3f}@t={hi[0]:.2f}(R={hi[2]:.3f})")


if __name__ == "__main__":
    main()
    frontier_main()
