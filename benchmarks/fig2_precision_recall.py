"""Fig 2: precision/recall of GPTCache-style verbatim caching vs threshold.

Paper protocol (§4.2.1): for each labeled pair, put(q1) then get(q2) with
re-rank, growing the cache; sweep the ANN cosine threshold; P/R from the
human duplicate labels.  Paper finds ~0.90 precision @ 0.70 and recall
collapsing to ~0.2 by the time precision hits ~0.97.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import QuestionPairGenerator
from repro.models.embedder import encode as embed_encode
from .common import csv_row, get_tokenizer, get_trained_embedder

THRESHOLDS = np.arange(0.70, 1.00, 0.02)


def run(n_pairs: int = 400, seed: int = 0):
    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    gen = QuestionPairGenerator(seed=seed)
    pairs = gen.generate(n_pairs, dup_frac=0.5, hard_frac=0.25)

    q1 = [a.text for a, b, l in pairs]
    q2 = [b.text for a, b, l in pairs]
    labels = np.asarray([l for a, b, l in pairs], bool)

    embed = jax.jit(lambda t, m: embed_encode(eparams, t, m, ecfg))
    t1, m1 = tok.encode_batch(q1, 32)
    t2, m2 = tok.encode_batch(q2, 32)
    t0 = time.perf_counter()
    e1 = np.asarray(embed(jnp.asarray(t1), jnp.asarray(m1)))
    e2 = np.asarray(embed(jnp.asarray(t2), jnp.asarray(m2)))
    embed_us = (time.perf_counter() - t0) / (2 * n_pairs) * 1e6

    # GPTCache protocol: put(q1_i), get(q2_i), then put(q2_i) — the cache
    # grows as the stream proceeds (§4.2.1).  A hit is CORRECT iff the
    # retrieved entry has the same (topic, intent) cell as the query —
    # returning its cached response would actually answer the question.
    cell1 = [(a.topic, a.intent) for a, b, l in pairs]
    cell2 = [(b.topic, b.intent) for a, b, l in pairs]
    bank_e, bank_c = [], []
    scores = np.zeros(n_pairs)
    hit_correct = np.zeros(n_pairs, bool)
    for i in range(n_pairs):
        bank_e.append(e1[i])
        bank_c.append(cell1[i])
        sims = np.asarray(bank_e) @ e2[i]
        j = int(np.argmax(sims))
        scores[i] = sims[j]
        hit_correct[i] = bank_c[j] == cell2[i]
        bank_e.append(e2[i])
        bank_c.append(cell2[i])
    curve = []
    for t in THRESHOLDS:
        hits = scores >= t
        tp = float(np.sum(hits & hit_correct))
        fp = float(np.sum(hits & ~hit_correct))
        fn = float(np.sum(~hits & labels))
        p = tp / max(tp + fp, 1e-9)
        r = tp / max(tp + fn, 1e-9)
        curve.append((t, p, r))
    return curve, embed_us


def main():
    curve, embed_us = run()
    print("# fig2: threshold,precision,recall")
    for t, p, r in curve:
        print(f"fig2_pr@{t:.2f},{embed_us:.1f},precision={p:.3f};recall={r:.3f}")
    p070 = [c for c in curve if abs(c[0] - 0.70) < 1e-6][0]
    hi = max(curve, key=lambda c: c[1])
    csv_row("fig2_summary", embed_us,
            f"P@0.70={p070[1]:.3f};R@0.70={p070[2]:.3f};"
            f"maxP={hi[1]:.3f}@t={hi[0]:.2f}(R={hi[2]:.3f})")


if __name__ == "__main__":
    main()
