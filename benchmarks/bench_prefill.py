"""Shared-prefix KV reuse + suffix bucketing vs full-bucket prefill.

Before DESIGN.md §9, every TWEAK request re-prefilled the byte-identical
Appendix-A instruction prefix from scratch AND padded its prompt to the
worst-case ``_tweak_encode_len`` bucket — a short cached response paid
attention FLOPs for the whole budget.  This bench measures the tweak hot
path's prefill both ways on the same model:

* **full**   — prefill ``[prefix | suffix]`` padded to the worst-case
  tweak bucket (the old engine behaviour),
* **prefix** — prefill only the suffix padded to ITS length bucket,
  attending over the prefix KV cache (built once, reused).

Reported tokens/s uses the REAL useful prompt tokens (prefix + actual
suffix) for both, so the ``speedup`` ratio is the end-to-end per-hit
prefill win and machine-independent; it is gated by
``benchmarks/check_regression.py`` in the ``bench-smoke`` CI job.  A
``speedup_samelen`` ratio isolates pure prefix reuse (both sides padded
to the same suffix bucket) from the bucketing win.  Full (non-smoke)
runs also report end-to-end per-hit generate latency (prefill + fused
decode).
"""
from __future__ import annotations

import statistics
import time

import jax
import jax.numpy as jnp

from repro.core import tweak as tweak_lib
from repro.models import ModelConfig, build_model
from repro.serving import GenerateConfig, Generator, SamplerConfig
from repro.serving.batcher import bucket_len, floor_len_bucket
from repro.tokenizer import HashWordTokenizer
from .common import csv_row

VOCAB = 4096
MNT = 16


def _generator() -> Generator:
    # The tweak-path small-LLM shape of the serving benches, with the
    # length-invariant fixed-block flash attention the byte-identical
    # prefix contract requires (DESIGN.md §9).
    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=VOCAB, max_seq_len=1024,
                      dtype="float32", attention_impl="xla_flash",
                      flash_block_q=32, flash_block_k=32)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return Generator(m, params, GenerateConfig(
        max_new_tokens=MNT, sampler=SamplerConfig(vocab_size=VOCAB)))


def _tokens(b, s, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 5, VOCAB)


def _time_pair(fn_a, fn_b, reps):
    """Median seconds per call for two fns, interleaved A/B (bench_generate's
    discipline: CPU-quota stalls on shared runners hit both alike, keeping
    the gated RATIO stable)."""
    fn_a(), fn_b()                                     # compile both
    ts_a, ts_b = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ts_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        ts_b.append(time.perf_counter() - t0)
    return statistics.median(ts_a), statistics.median(ts_b)


def bench_prefill(batches=(1, 8), suffixes=(32, 96), reps=5, e2e=True):
    """Prefix-reuse + bucketed suffix vs worst-case-bucket full prefill."""
    gen = _generator()
    tok = HashWordTokenizer(VOCAB)
    prefix_ids = tweak_lib.tweak_prefix_ids(tok)
    p = len(prefix_ids)
    msl = gen.model.cfg.max_seq_len
    # The engine's worst-case tweak bucket at this config: every request
    # used to pay prefill over this whole length.
    full_bucket = floor_len_bucket(msl - MNT - 1)
    for b in batches:
        pc = gen.build_prefix_cache(prefix_ids, b)
        pre = jnp.broadcast_to(jnp.asarray(prefix_ids, jnp.int32)[None, :],
                               (b, p))
        for s_real in suffixes:
            s_bucket = bucket_len(s_real)
            suf = _tokens(b, s_real)
            pad = jnp.zeros((b, s_bucket - s_real), jnp.int32)
            suf_b = jnp.concatenate([suf, pad], axis=1)
            full = jnp.concatenate(
                [pre, suf, jnp.zeros((b, full_bucket - p - s_real),
                                     jnp.int32)], axis=1)
            # same content, both padded to the SAME suffix bucket: isolates
            # the pure prefix-KV-reuse win from the bucketing win
            samelen = jnp.concatenate([pre, suf_b], axis=1)
            cap_full = full_bucket + MNT + 1
            cap_pfx = p + s_bucket + MNT + 1

            t_pfx, t_full = _time_pair(
                lambda: gen._prefill_with_prefix(
                    gen.params, {"tokens": suf_b}, cap_pfx, pc.caches),
                lambda: gen._prefill(gen.params, {"tokens": full}, cap_full),
                reps)
            t_same = _time_pair(
                lambda: gen._prefill(gen.params, {"tokens": samelen},
                                     p + s_bucket + MNT + 1),
                lambda: (), reps)[0]
            useful = b * (p + s_real)
            derived = (f"full_us={t_full * 1e6:.0f};"
                       f"tok_s_prefix={useful / t_pfx:.0f};"
                       f"tok_s_full={useful / t_full:.0f};"
                       f"prefix={p};bucket={s_bucket}/{full_bucket}")
            extra = {}
            if e2e:
                g_pfx, g_full = _time_pair(
                    lambda: gen.generate_with_lengths(
                        {"tokens": suf_b}, max_new_tokens=MNT, seed=0,
                        prefix_cache=pc)[0],
                    lambda: gen.generate_with_lengths(
                        {"tokens": full}, max_new_tokens=MNT, seed=0)[0],
                    reps)
                derived += (f";hit_ms_prefix={g_pfx * 1e3:.1f};"
                            f"hit_ms_full={g_full * 1e3:.1f}")
                extra["speedup_e2e"] = round(g_full / max(g_pfx, 1e-9), 2)
            csv_row(f"prefill_b{b}_s{s_real}", t_pfx * 1e6, derived,
                    speedup=round(t_full / max(t_pfx, 1e-9), 2),
                    speedup_samelen=round(t_same / max(t_pfx, 1e-9), 2),
                    **extra)


def main(smoke: bool = False):
    if smoke:
        # CI perf-gate subset: one batch x one suffix bucket, no e2e
        # decode timing (the decode loop has its own gated bench)
        bench_prefill(batches=(8,), suffixes=(32,), reps=7, e2e=False)
        return
    bench_prefill()


if __name__ == "__main__":
    main()
