"""Shared benchmark fixtures: trained tiny embedder/judge, timing helper.

Model-quality figures run the paper's *protocols* end-to-end on the real
router/cache/judge machinery; response TEXTS come from the synthetic
response generator (big-quality vs small-quality templates), because a
CPU-trainable 2-layer LM's sampled tokens carry no judgeable signal.  The
serving examples (examples/serve_e2e.py) exercise true token generation.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

from repro.models.embedder import init_embedder, tiny_embedder_config
from repro.models import ModelConfig, build_model
from repro.tokenizer import HashWordTokenizer
from repro.training.embedder_train import train_embedder

VOCAB = 8192
_cache = {}


def get_tokenizer() -> HashWordTokenizer:
    if "tok" not in _cache:
        _cache["tok"] = HashWordTokenizer(VOCAB)
    return _cache["tok"]


def get_trained_embedder(steps: int = 150):
    if "emb" not in _cache:
        cfg = tiny_embedder_config(VOCAB)
        params = init_embedder(jax.random.PRNGKey(0), cfg)
        params, losses = train_embedder(params, cfg, get_tokenizer(),
                                        steps=steps, batch=16)
        _cache["emb"] = (params, cfg, losses)
    return _cache["emb"]


def get_trained_reranker(steps: int = 300):
    """Cross-encoder reranker trained on generated pairs (cascade stage 2,
    DESIGN.md §13).  The frontier bench shares one training run across
    operating points; first caller's ``steps`` wins."""
    if "reranker" not in _cache:
        from repro.models.reranker import init_reranker, tiny_reranker_config
        from repro.training.reranker_train import train_reranker
        cfg = tiny_reranker_config(VOCAB)
        params = init_reranker(jax.random.PRNGKey(11), cfg)
        params, _ = train_reranker(params, cfg, get_tokenizer(),
                                   steps=steps, batch=32, seed=0)
        _cache["reranker"] = (params, cfg)
    return _cache["reranker"]


def get_judge_lm(steps: int = 120):
    """Tiny reference LM trained on the synthetic corpus (judge model)."""
    if "judge" not in _cache:
        from repro.data import token_stream_batches
        from repro.training import AdamWConfig, init_opt_state, make_train_step
        import jax.numpy as jnp
        cfg = ModelConfig(name="judge", num_layers=2, d_model=96, num_heads=4,
                          num_kv_heads=2, d_ff=192, vocab_size=VOCAB,
                          max_seq_len=512, dtype="float32")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(7))
        step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3),
                                       total_steps=steps))
        opt = init_opt_state(params)
        stream = token_stream_batches(get_tokenizer(), 8, 64, seed=3)
        for _ in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
            params, opt, _ = step(params, opt, batch)
        _cache["judge"] = (model, params)
    return _cache["judge"]


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Mean microseconds per call."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or \
            isinstance(r, (tuple, list)) else None
    t0 = time.perf_counter()
    for _ in range(iters):
        r = fn(*args)
        try:
            jax.block_until_ready(r)
        except Exception:
            pass
    return (time.perf_counter() - t0) / iters * 1e6


# registry of every metric emitted this process: run.py --json dumps it
# in the repo-standard BENCH_*.json format and check_regression.py gates
# CI on it.  Extra keyword metrics (speedup=..., recall=...) are the
# machine-independent values the CI perf gate compares.
RESULTS: dict = {}


def csv_row(name: str, us: float, derived: str = "", **metrics):
    RESULTS[name] = {"us_per_call": round(us, 2), "derived": derived}
    RESULTS[name].update(metrics)
    if metrics:
        extra = ";".join(f"{k}={v}" for k, v in metrics.items())
        derived = f"{derived};{extra}" if derived else extra
    print(f"{name},{us:.1f},{derived}")
