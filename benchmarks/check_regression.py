"""CI perf gate: compare a fresh BENCH json against a checked-in baseline.

  PYTHONPATH=src python -m benchmarks.check_regression \
      BENCH_ci.json BENCH_baseline.json [--tol 0.25] [--strict-latency]

Policy (why two classes of metric):

* **Gated** — quality fields (``recall``, ``band_agree``,
  ``decision_agree``, plus the deterministic replica ratios
  ``scaling_eff`` and ``hit_ratio``) transfer exactly across machines
  and FAIL the job when they drop more than ``--tol`` (default 25%)
  below baseline;
  ``speedup`` ratios transfer approximately (cache-hierarchy differences
  leak into gather-vs-GEMM ratios) and fail at double the tolerance —
  wide enough to absorb runner heterogeneity, tight enough to catch a
  real collapse.  A baseline metric missing from the fresh run also
  fails — the bench silently not running is itself a regression.
* **Latency** (``us_per_call``) — absolute wall time does NOT transfer
  across machines (a cold CI runner is easily 3x a dev box), so raw
  latencies only WARN by default; ``--strict-latency`` upgrades them to
  failures for same-machine A/B comparisons.

New metrics in the fresh run (not in the baseline) are reported and
ignored, so adding a bench doesn't require touching the gate.
"""
from __future__ import annotations

import argparse
import json
import sys

# NOTE: deliberately no absolute-throughput keys (qps) — like raw
# latency, absolute throughput does not transfer across runners.
# Quality keys (recall/agreement) transfer exactly and get the base
# tolerance; speedup RATIOS transfer approximately (numerator and
# denominator scale with the machine, but cache-hierarchy differences
# leak in), so they get double the tolerance to keep the gate from
# flaking on runner heterogeneity while still catching real collapses.
QUALITY_KEYS = ("recall", "band_agree", "decision_agree",
                "scaling_eff", "hit_ratio", "frontier_auc",
                "acceptance_rate")
RATIO_KEYS = ("speedup", "spec_speedup")
LATENCY_KEYS = ("us_per_call",)


def _numeric(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def compare(new: dict, base: dict, tol: float, strict_latency: bool):
    """Returns (failures, warnings, notes) as lists of report lines."""
    failures, warnings, notes = [], [], []
    new_m = new.get("metrics", {})
    base_m = base.get("metrics", {})
    for name, bvals in sorted(base_m.items()):
        nvals = new_m.get(name)
        if nvals is None:
            failures.append(f"{name}: metric missing from fresh run")
            continue
        for key, bv in bvals.items():
            if not _numeric(bv):
                continue
            nv = nvals.get(key)
            if not _numeric(nv):
                failures.append(f"{name}.{key}: missing from fresh run")
                continue
            if key in QUALITY_KEYS or key in RATIO_KEYS:
                ktol = tol if key in QUALITY_KEYS else min(2 * tol, 0.9)
                floor = bv * (1 - ktol)
                line = (f"{name}.{key}: {nv:g} vs baseline {bv:g} "
                        f"(floor {floor:g})")
                if nv < floor:
                    failures.append("REGRESSION " + line)
                else:
                    notes.append("ok " + line)
            elif key in LATENCY_KEYS:
                ceil = bv * (1 + tol)
                line = (f"{name}.{key}: {nv:g}us vs baseline {bv:g}us "
                        f"(ceil {ceil:g}us)")
                if nv > ceil:
                    (failures if strict_latency else warnings).append(
                        "SLOWER " + line)
                else:
                    notes.append("ok " + line)
    for name in sorted(set(new_m) - set(base_m)):
        notes.append(f"new metric (not gated): {name}")
    return failures, warnings, notes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="fresh BENCH json (e.g. BENCH_ci.json)")
    ap.add_argument("baseline", help="checked-in BENCH_baseline.json")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed fractional regression (default 0.25)")
    ap.add_argument("--strict-latency", action="store_true",
                    help="gate raw us_per_call too (same-machine A/B only)")
    args = ap.parse_args()
    with open(args.new) as f:
        new = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    failures, warnings, notes = compare(new, base, args.tol,
                                        args.strict_latency)
    for line in notes:
        print("  " + line)
    for line in warnings:
        print("WARN  " + line)
    for line in failures:
        print("FAIL  " + line)
    print(f"# {len(failures)} failures, {len(warnings)} warnings, "
          f"{len(notes)} ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
