"""Table-1-level component microbenchmarks: lookup / embed / route / insert.

us_per_call on this CPU host; the derived column reports the TPU-relevant
quantity (bytes scanned per lookup, entries, dims).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import cache as cache_lib
from repro.core.router import RouterConfig, route
from repro.kernels.cosine_topk.ops import cosine_topk
from repro.models.embedder import encode as embed_encode
from .common import csv_row, get_tokenizer, get_trained_embedder


def bench_lookup(capacity=16384, dim=384, batch=8, k=4):
    db = jax.random.normal(jax.random.PRNGKey(0), (capacity, dim))
    db = db / jnp.linalg.norm(db, axis=-1, keepdims=True)
    q = jax.random.normal(jax.random.PRNGKey(1), (batch, dim))
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    f = jax.jit(lambda q, db: cosine_topk(q, db, None, k=k, impl="xla"))
    jax.block_until_ready(f(q, db))
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(f(q, db))
    us = (time.perf_counter() - t0) / 10 * 1e6
    mb = capacity * dim * 4 / 2 ** 20
    csv_row(f"lookup_xla_{capacity // 1024}k", us,
            f"scan={mb:.0f}MiB;batch={batch};k={k}")


def bench_lookup_pallas_interpret(capacity=2048, dim=384, batch=4, k=4):
    db = jax.random.normal(jax.random.PRNGKey(0), (capacity, dim))
    q = jax.random.normal(jax.random.PRNGKey(1), (batch, dim))
    f = jax.jit(lambda q, db: cosine_topk(q, db, None, k=k, impl="pallas",
                                          block_n=512))
    jax.block_until_ready(f(q, db))
    t0 = time.perf_counter()
    jax.block_until_ready(f(q, db))
    us = (time.perf_counter() - t0) * 1e6
    csv_row("lookup_pallas_interpret_2k", us,
            "interpret-mode-on-CPU;TPU-target-kernel")


def bench_embed(batch=8, seq=32):
    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    texts = ["how do i learn python setup"] * batch
    t, m = tok.encode_batch(texts, seq)
    f = jax.jit(lambda t, m: embed_encode(eparams, t, m, ecfg))
    jax.block_until_ready(f(jnp.asarray(t), jnp.asarray(m)))
    t0 = time.perf_counter()
    for _ in range(10):
        jax.block_until_ready(f(jnp.asarray(t), jnp.asarray(m)))
    us = (time.perf_counter() - t0) / 10 * 1e6
    csv_row("embed_batch8", us, f"dim={ecfg.d_model};layers={ecfg.num_layers}")


def bench_route(batch=1024):
    s = jax.random.uniform(jax.random.PRNGKey(0), (batch,))
    f = jax.jit(lambda s: route(s, RouterConfig()))
    jax.block_until_ready(f(s))
    t0 = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(f(s))
    us = (time.perf_counter() - t0) / 20 * 1e6
    csv_row("route_1024", us, "threshold_compare")


def bench_insert(capacity=4096, dim=384):
    cfg = cache_lib.CacheConfig(capacity=capacity, dim=dim)
    st = cache_lib.init_cache(cfg)
    e = jax.random.normal(jax.random.PRNGKey(0), (dim,))
    z = jnp.zeros((cfg.max_query_tokens,), jnp.int32)
    m = jnp.ones((cfg.max_query_tokens,), jnp.float32)
    z2 = jnp.zeros((cfg.max_response_tokens,), jnp.int32)
    m2 = jnp.ones((cfg.max_response_tokens,), jnp.float32)
    f = jax.jit(lambda st, e: cache_lib.insert(st, cfg, e, z, m, z2, m2))
    st = f(st, e)
    jax.block_until_ready(st["emb"])
    t0 = time.perf_counter()
    for _ in range(10):
        st = f(st, e)
    jax.block_until_ready(st["emb"])
    us = (time.perf_counter() - t0) / 10 * 1e6
    csv_row("cache_insert", us, f"capacity={capacity};ring_fifo")


def bench_insert_batch(capacities=(4096, 16384, 65536), batch=64, dim=384,
                       policy="fifo", reps=5):
    """Sequential per-entry inserts vs one fused insert_batch call.

    Sequential pays one dispatch + host sync per entry (the seed engine's
    write path); insert_batch commits the whole batch in a single jitted
    step.  Reports the throughput ratio per capacity.
    """
    for cap in capacities:
        cfg = cache_lib.CacheConfig(capacity=cap, dim=dim, policy=policy)
        embs = jax.random.normal(jax.random.PRNGKey(0), (batch, dim))
        qt = jnp.zeros((batch, cfg.max_query_tokens), jnp.int32)
        qm = jnp.ones((batch, cfg.max_query_tokens), jnp.float32)
        rt = jnp.zeros((batch, cfg.max_response_tokens), jnp.int32)
        rm = jnp.ones((batch, cfg.max_response_tokens), jnp.float32)

        seq = jax.jit(lambda st, e, i: cache_lib.insert(
            st, cfg, e, qt[i], qm[i], rt[i], rm[i]))
        st = cache_lib.init_cache(cfg)
        st = seq(st, embs[0], 0)          # compile
        jax.block_until_ready(st["emb"])
        t0 = time.perf_counter()
        for _ in range(reps):
            for i in range(batch):
                st = seq(st, embs[i], i)
                jax.block_until_ready(st["emb"])  # the per-entry host sync
        us_seq = (time.perf_counter() - t0) / reps * 1e6

        batched = cache_lib.make_insert_batch(cfg, donate=False)
        st = cache_lib.init_cache(cfg)
        st, slots = batched(st, embs, qt, qm, rt, rm, batch)   # compile
        jax.block_until_ready(st["emb"])
        t0 = time.perf_counter()
        for _ in range(reps):
            st, slots = batched(st, embs, qt, qm, rt, rm, batch)
            jax.block_until_ready(st["emb"])
        us_bat = (time.perf_counter() - t0) / reps * 1e6

        ratio = us_seq / max(us_bat, 1e-9)
        csv_row(f"insert_batch_{cap}", us_bat,
                f"seq_us={us_seq:.0f};batch={batch}",
                speedup=round(ratio, 1))


def main(smoke: bool = False):
    if smoke:
        # CI perf-gate subset: skip the trained-embedder bench (slow model
        # training dominates) and keep one insert_batch capacity
        bench_lookup(capacity=8192)
        bench_lookup_pallas_interpret()
        bench_route()
        bench_insert()
        bench_insert_batch(capacities=(4096,), reps=3)
        return
    bench_lookup()
    bench_lookup_pallas_interpret()
    bench_embed()
    bench_route()
    bench_insert()
    bench_insert_batch()


if __name__ == "__main__":
    main()
