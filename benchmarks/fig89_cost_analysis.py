"""Figs 8+9 and §5.2.3: cache-hit distribution vs threshold + cost saving.

Paper protocol: insert the first half of each workload into the cache,
query the second half, histogram the top-1 cosine similarities, then apply
the 25x big/small per-token cost ratio.  Paper: LMSYS 68% >= 0.8 -> 35% of
baseline cost; WildChat 40% >= 0.8 -> 61% of baseline cost.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import WorkloadGenerator
from repro.kernels.cosine_topk.ops import cosine_topk
from repro.models.embedder import encode as embed_encode
from .common import csv_row, get_tokenizer, get_trained_embedder

COST_RATIO = 25.0
THRESHOLDS = np.arange(0.70, 1.001, 0.05)


def run(profile: str, n: int = 2000, seed: int = 0):
    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    wl = WorkloadGenerator(profile=profile, seed=seed)
    queries = [q.text for q in wl.sample(n)]
    embed = jax.jit(lambda t, m: embed_encode(eparams, t, m, ecfg))
    t_, m_ = tok.encode_batch(queries, 32)
    embs = np.asarray(embed(jnp.asarray(t_), jnp.asarray(m_)))

    half = n // 2
    bank = jnp.asarray(embs[:half])
    test = jnp.asarray(embs[half:])
    t0 = time.perf_counter()
    scores, _ = cosine_topk(test, bank, None, k=1, impl="xla")
    scores = np.asarray(jax.block_until_ready(scores))[:, 0]
    lookup_us = (time.perf_counter() - t0) / (n - half) * 1e6

    rows = []
    for t in THRESHOLDS:
        hit = float(np.mean(scores >= t))
        # cost per query: hit -> small (1x), miss -> big (25x); vs all-big
        rel_cost = (hit * 1.0 + (1 - hit) * COST_RATIO) / COST_RATIO
        rows.append((float(t), hit, rel_cost))
    return rows, lookup_us


def main():
    for profile in ("lmsys", "wildchat"):
        rows, lookup_us = run(profile)
        print(f"# fig{'8' if profile == 'lmsys' else '9'}: "
              f"threshold,hit_rate,relative_cost ({profile})")
        for t, hit, cost in rows:
            print(f"fig89_{profile}@{t:.2f},{lookup_us:.1f},"
                  f"hit={hit:.3f};rel_cost={cost:.3f}")
        r08 = [r for r in rows if abs(r[0] - 0.80) < 1e-6][0]
        csv_row(f"fig89_{profile}_summary", lookup_us,
                f"hits@0.8={r08[1]:.1%};cost={r08[2]:.1%}_of_baseline"
                f";paper={'68%/35%' if profile == 'lmsys' else '40%/61%'}")


if __name__ == "__main__":
    main()
