"""Figs 8+9 and §5.2.3: cache-hit distribution vs threshold + cost saving.

Paper protocol: insert the first half of each workload into the cache,
query the second half, histogram the top-1 cosine similarities, then apply
the 25x big/small per-token cost ratio.  Paper: LMSYS 68% >= 0.8 -> 35% of
baseline cost; WildChat 40% >= 0.8 -> 61% of baseline cost.

The paper's cost analysis bills INPUT tokens too, so besides the
hit-rate-only analytic model a small real engine run surfaces the
measured ``big_prompt_tokens`` / ``small_prompt_tokens`` (real, unpadded
prefilled lengths) from ``EngineStats`` and the prompt-inclusive
cost-vs-baseline ratio.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import WorkloadGenerator
from repro.kernels.cosine_topk.ops import cosine_topk
from repro.models.embedder import encode as embed_encode
from .common import VOCAB, csv_row, get_tokenizer, get_trained_embedder

COST_RATIO = 25.0
THRESHOLDS = np.arange(0.70, 1.001, 0.05)


def run(profile: str, n: int = 2000, seed: int = 0):
    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    wl = WorkloadGenerator(profile=profile, seed=seed)
    queries = [q.text for q in wl.sample(n)]
    embed = jax.jit(lambda t, m: embed_encode(eparams, t, m, ecfg))
    t_, m_ = tok.encode_batch(queries, 32)
    embs = np.asarray(embed(jnp.asarray(t_), jnp.asarray(m_)))

    half = n // 2
    bank = jnp.asarray(embs[:half])
    test = jnp.asarray(embs[half:])
    t0 = time.perf_counter()
    scores, _ = cosine_topk(test, bank, None, k=1, impl="xla")
    scores = np.asarray(jax.block_until_ready(scores))[:, 0]
    lookup_us = (time.perf_counter() - t0) / (n - half) * 1e6

    rows = []
    for t in THRESHOLDS:
        hit = float(np.mean(scores >= t))
        # cost per query: hit -> small (1x), miss -> big (25x); vs all-big
        rel_cost = (hit * 1.0 + (1 - hit) * COST_RATIO) / COST_RATIO
        rows.append((float(t), hit, rel_cost))
    return rows, lookup_us


def measured_prompt_cost(n: int = 32, seed: int = 0):
    """§5.2.3 with input tokens: serve a small workload through a real
    engine and report prompt-inclusive measured cost vs the all-Big
    baseline (both sides count prompt AND generated tokens)."""
    from repro.core import CacheConfig, RouterConfig, TweakLLMEngine
    from repro.data import WorkloadGenerator
    from repro.models import ModelConfig, build_model
    from repro.serving import GenerateConfig, Generator, SamplerConfig

    tok = get_tokenizer()
    eparams, ecfg, _ = get_trained_embedder()
    lm = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                     d_ff=128, vocab_size=VOCAB, max_seq_len=512,
                     dtype="float32", attention_impl="xla_flash",
                     flash_block_q=32, flash_block_k=32)
    gc = GenerateConfig(max_new_tokens=8, sampler=SamplerConfig(vocab_size=VOCAB))
    big_m, small_m = build_model(lm), build_model(lm.replace(num_layers=1))
    eng = TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gc),
        small=Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gc),
        cache_cfg=CacheConfig(capacity=256, dim=ecfg.d_model, topk=4),
        router_cfg=RouterConfig(tweak_threshold=0.55))
    wl = WorkloadGenerator(profile="lmsys", seed=seed)
    queries = [q.text for q in wl.sample(2 * n)]
    eng.populate(queries[:n], [f"a cached answer about topic {i}"
                               for i in range(n)])
    for i in range(n, 2 * n, 8):
        eng.handle_batch(queries[i:i + 8], max_new_tokens=8)
    s = eng.stats
    csv_row("fig89_measured_prompt_cost", 0.0,
            f"miss={s.miss};tweak={s.tweak};exact={s.exact};"
            f"big_prompt={s.big_prompt_tokens};"
            f"small_prompt={s.small_prompt_tokens};"
            f"baseline_prompt={s.baseline_prompt_tokens};"
            f"gen_big={s.big_tokens};gen_small={s.small_tokens};"
            f"cost={s.cost:.0f};baseline={s.baseline_cost:.0f}",
            rel_cost=round(s.cost / max(s.baseline_cost, 1e-9), 3))


def main():
    for profile in ("lmsys", "wildchat"):
        rows, lookup_us = run(profile)
        print(f"# fig{'8' if profile == 'lmsys' else '9'}: "
              f"threshold,hit_rate,relative_cost ({profile})")
        for t, hit, cost in rows:
            print(f"fig89_{profile}@{t:.2f},{lookup_us:.1f},"
                  f"hit={hit:.3f};rel_cost={cost:.3f}")
        r08 = [r for r in rows if abs(r[0] - 0.80) < 1e-6][0]
        csv_row(f"fig89_{profile}_summary", lookup_us,
                f"hits@0.8={r08[1]:.1%};cost={r08[2]:.1%}_of_baseline"
                f";paper={'68%/35%' if profile == 'lmsys' else '40%/61%'}")
    measured_prompt_cost()


if __name__ == "__main__":
    main()
