"""Suite entry point for the router cost-quality frontier (DESIGN.md §13).

The sweep itself lives in ``fig2_precision_recall.run_frontier`` — it
shares the Fig-2 stream protocol and trained fixtures; this module only
gives it a suite name (``--only frontier``) and the smoke hook.
"""
from __future__ import annotations

from .fig2_precision_recall import frontier_main


def main(smoke: bool = False):
    frontier_main(smoke=smoke)


if __name__ == "__main__":
    main()
