"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig2_*      precision/recall of GPTCache-style caching   (paper Fig 2)
  fig3_*      satisfaction per similarity band             (paper Fig 3)
  fig5/6/7_*  LLM-debate verdicts per band + control       (paper Figs 5-7)
  fig89_*     cache-hit distribution + cost analysis       (paper Figs 8-9)
  microbench  per-component latencies                      (paper Table 1)
  roofline_*  dry-run roofline terms per (arch x shape)    (§Roofline)
  scheduler   coalesced-vs-per-request + latency sweeps    (DESIGN.md §6)

  PYTHONPATH=src python -m benchmarks.run [--only fig2,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = ("fig2", "fig34567", "fig89", "microbench", "roofline", "scheduler")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    from . import (bench_scheduler, fig2_precision_recall, fig34567_quality,
                   fig89_cost_analysis, microbench, roofline)
    mods = {
        "fig2": fig2_precision_recall,
        "fig34567": fig34567_quality,
        "fig89": fig89_cost_analysis,
        "microbench": microbench,
        "roofline": roofline,
        "scheduler": bench_scheduler,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name in SUITES:
        if name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            mods[name].main()
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0.0,{traceback.format_exc(limit=2)!r}")
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
