"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig2_*      precision/recall of GPTCache-style caching   (paper Fig 2)
  frontier_*  router cost-quality frontier, 1-stage vs cascade (DESIGN.md §13)
  fig3_*      satisfaction per similarity band             (paper Fig 3)
  fig5/6/7_*  LLM-debate verdicts per band + control       (paper Figs 5-7)
  fig89_*     cache-hit distribution + cost analysis       (paper Figs 8-9)
  microbench  per-component latencies                      (paper Table 1)
  roofline_*  dry-run roofline terms per (arch x shape)    (§Roofline)
  scheduler   coalesced-vs-per-request + latency sweeps    (DESIGN.md §6)
  replicas    multi-replica scaling + shared-bank hits     (DESIGN.md §12)
  index       clustered (IVF) vs flat cache lookup         (DESIGN.md §7)
  generate    fused on-device vs host-loop decode          (DESIGN.md §8)
  prefill     prefix-KV reuse + suffix buckets vs full     (DESIGN.md §9)
  speculative cached-response draft verify vs plain decode (DESIGN.md §14)

  PYTHONPATH=src python -m benchmarks.run [--only fig2,...] \
      [--smoke] [--json BENCH_ci.json]

``--smoke`` runs the scaled-down CI subset
(index/scheduler/microbench/generate)
— the perf-gate job in .github/workflows/ci.yml.  ``--json`` dumps every
emitted metric in the repo-standard BENCH_*.json format that
``benchmarks.check_regression`` compares against a checked-in baseline.
"""
from __future__ import annotations

import argparse
import inspect
import json
import platform
import sys
import time
import traceback

SUITES = ("fig2", "frontier", "fig34567", "fig89", "microbench", "roofline",
          "scheduler", "replicas", "index", "generate", "prefill",
          "speculative")
SMOKE_SUITES = ("microbench", "index", "scheduler", "replicas", "generate",
                "prefill", "frontier", "speculative")
SCHEMA = "tweakllm-bench/v1"


def write_json(path: str, suites, smoke: bool) -> None:
    import jax
    from .common import RESULTS
    doc = {
        "schema": SCHEMA,
        "created_unix": int(time.time()),
        "smoke": smoke,
        "suites": list(suites),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "metrics": RESULTS,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(RESULTS)} metrics to {path}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down CI subset (index/scheduler/microbench)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all emitted metrics as BENCH json")
    args, _ = ap.parse_known_args()
    default = SMOKE_SUITES if args.smoke else SUITES
    only = tuple(args.only.split(",")) if args.only else default

    from . import (bench_frontier, bench_generate, bench_index,
                   bench_prefill, bench_replicas, bench_scheduler,
                   bench_speculative, fig2_precision_recall,
                   fig34567_quality, fig89_cost_analysis, microbench,
                   roofline)
    mods = {
        "fig2": fig2_precision_recall,
        "frontier": bench_frontier,
        "fig34567": fig34567_quality,
        "fig89": fig89_cost_analysis,
        "microbench": microbench,
        "roofline": roofline,
        "scheduler": bench_scheduler,
        "replicas": bench_replicas,
        "index": bench_index,
        "generate": bench_generate,
        "prefill": bench_prefill,
        "speculative": bench_speculative,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name in SUITES:
        if name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        try:
            fn = mods[name].main
            if "smoke" in inspect.signature(fn).parameters:
                fn(smoke=args.smoke)
            else:
                fn()
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0.0,{traceback.format_exc(limit=2)!r}")
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)
    if args.json:
        write_json(args.json, only, args.smoke)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
