"""Clustered (IVF) cache-index benchmark: lookup latency + retrieval quality.

Sweeps cache capacity x ``nprobe`` on a clustered synthetic bank (the
regime the paper's Milvus layer serves: queries are near-duplicates of
cached entries) and reports, per point:

* flat-scan and IVF lookup microseconds (jitted, serve-batch shapes),
* ``speedup`` — flat us / IVF us,
* ``recall@1`` on the near-duplicate workload (ground truth = flat scan),
* routing-band and route-decision agreement on a MIXED workload whose
  similarities span the paper's 0.7/0.8/0.9 bands (the metric that
  decides whether IVF changes any EXACT/TWEAK/MISS outcome),
* one-off ``build_index`` (k-means) seconds — maintenance cost.

The acceptance numbers (>= 4x speedup at 256k entries with recall@1
>= 0.95 and band agreement >= 0.98 at the default nprobe) come from the
FULL sweep — `make bench-index`, recorded in BENCH_index.json.  CI's
`bench-smoke` job runs only the scaled-down 64k point and gates trends
against BENCH_baseline.json via `check_regression.py`.

  PYTHONPATH=src python -m benchmarks.bench_index [--caps 16384,65536]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core import index as index_lib
from repro.core import router as router_lib

from .common import csv_row

DIM = 384
BATCH = 8
FULL_CAPS = (16384, 65536, 262144, 1048576)
NPROBES = (4, 8, 16)


def _unit(x):
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def make_bank(capacity: int, dim: int = DIM, ntrue: int = 0, seed: int = 0):
    """Clustered unit bank: ``ntrue`` directions + per-point noise.

    Noise norms are dimension-scaled (sigma / sqrt(dim) per coordinate)
    so cosine structure is dimension-independent: intra-cluster cosine
    ~ 1/sqrt(1 + sigma^2) ~ 0.89 at sigma 0.5.
    """
    ntrue = ntrue or max(32, capacity // 512)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    centers = _unit(jax.random.normal(k1, (ntrue, dim)))
    which = jax.random.randint(k2, (capacity,), 0, ntrue)
    pts = centers[which] + (0.5 / dim ** 0.5) * \
        jax.random.normal(k3, (capacity, dim))
    return _unit(pts)


def make_queries(bank, n: int, seed: int = 1):
    """(near-dup, mixed) query sets.

    near-dup: sigma-0.15 perturbations of random bank rows (top-1 cosine
    ~0.99) — the semantic-cache hit workload recall@1 is scored on.
    mixed: noise levels spreading top-1 similarity across the routing
    bands, plus far rows that should MISS, for band/decision agreement.
    """
    cap, dim = bank.shape
    s = 1.0 / dim ** 0.5
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    rows = jax.random.randint(ks[0], (n,), 0, cap)
    near = _unit(bank[rows] + 0.15 * s * jax.random.normal(ks[1], (n, dim)))
    sigmas = jnp.asarray([0.15, 0.4, 0.7, 1.2])[
        jax.random.randint(ks[2], (n,), 0, 4)]
    mixed = _unit(bank[jax.random.randint(ks[3], (n,), 0, cap)]
                  + (sigmas * s)[:, None] * jax.random.normal(ks[4], (n, dim)))
    return near, mixed


def _time(fn, *args, reps: int = 5) -> float:
    """Min-of-reps microseconds (the timeit convention): the smallest
    observation is the interference-free estimate, which keeps the CI
    perf gate's speedup ratios stable on noisy shared runners."""
    jax.block_until_ready(fn(*args))          # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts)) * 1e6


def bench_capacity(cap: int, nprobes=NPROBES, queries: int = 256,
                   reps: int = 9, seed: int = 0):
    flat_cfg = cache_lib.CacheConfig(capacity=cap, dim=DIM, topk=4)
    bank = make_bank(cap, seed=seed)
    base = cache_lib.CacheConfig(capacity=cap, dim=DIM, topk=4, index="ivf")
    state = cache_lib.init_cache(base)
    state["emb"] = bank
    state["valid"] = jnp.ones((cap,), bool)
    t0 = time.perf_counter()
    state = index_lib.build_index(state, base, seed=seed)
    build_s = time.perf_counter() - t0
    p = index_lib.resolve(base)
    near, mixed = make_queries(bank, queries, seed=seed + 1)

    flat_fn = jax.jit(lambda st, q: cache_lib.lookup(st, flat_cfg, q))
    flat_us = _time(flat_fn, state, near[:BATCH], reps=reps)
    mb = cap * DIM * 4 / 2 ** 20
    csv_row(f"index_flat_{cap}", flat_us,
            f"scan={mb:.0f}MiB;batch={BATCH};k=4")
    csv_row(f"index_build_{cap}", build_s * 1e6,
            f"kmeans;nclusters={p.nclusters};bucket={p.bucket}")

    rcfg = router_lib.RouterConfig()
    flat_scores_near, flat_idx_near = cache_lib.lookup(state, flat_cfg, near)
    flat_scores_mix, _ = cache_lib.lookup(state, flat_cfg, mixed)
    fband = np.asarray(router_lib.band_of(flat_scores_mix[:, 0]))
    fdec = np.asarray(router_lib.route(flat_scores_mix[:, 0], rcfg))

    for nprobe in nprobes:
        cfg = cache_lib.CacheConfig(capacity=cap, dim=DIM, topk=4,
                                    index="ivf", nprobe=nprobe)
        ivf_fn = jax.jit(lambda st, q: cache_lib.lookup(st, cfg, q))
        us = _time(ivf_fn, state, near[:BATCH], reps=reps)
        s_near, i_near = ivf_fn(state, near)
        s_mix, _ = ivf_fn(state, mixed)
        recall = float(np.mean(np.asarray(i_near[:, 0])
                               == np.asarray(flat_idx_near[:, 0])))
        band = np.asarray(router_lib.band_of(s_mix[:, 0]))
        dec = np.asarray(router_lib.route(s_mix[:, 0], rcfg))
        band_agree = float(np.mean(band == fband))
        dec_agree = float(np.mean(dec == fdec))
        tag = "(default)" if nprobe == index_lib.resolve(base).nprobe else ""
        csv_row(f"index_ivf_{cap}_p{nprobe}", us,
                f"rows={nprobe * p.bucket}/{cap};nclusters={p.nclusters}"
                f"{tag}",
                speedup=round(flat_us / max(us, 1e-9), 2),
                recall=round(recall, 4),
                band_agree=round(band_agree, 4),
                decision_agree=round(dec_agree, 4))


def main(smoke: bool = False, caps=None):
    if smoke:
        # CI perf-gate point: 64k is the smallest capacity whose IVF
        # speedup is comfortably clear of timer noise on shared runners
        bench_capacity(caps[0] if caps else 65536, nprobes=(4, 8),
                       queries=128, reps=7)
        return
    for cap in caps or FULL_CAPS:
        bench_capacity(cap, queries=256, reps=9)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--caps", default=None,
                    help="comma-separated capacities (default: full sweep)")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    caps = tuple(int(c) for c in args.caps.split(",")) if args.caps else None
    main(smoke=args.smoke, caps=caps)
