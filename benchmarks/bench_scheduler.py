"""Scheduler bench: coalesced vs per-request dispatch + arrival-rate sweeps.

Two parts (DESIGN.md §6):

* ``bench_coalescing`` — REAL engine, wall-clock: serves N all-distinct
  (all-MISS) queries once per-request (the seed serving loop's dispatch
  pattern) and once through the continuous-batching scheduler at several
  ``max_batch`` sizes.  Coalescing amortizes embed/lookup/generate
  dispatches across the bucket, so throughput must rise with batch size.
* ``bench_latency_sweep`` — trace-driven load generator under a
  ``SimClock``: the engine is replaced by a calibrated service-time model
  (measured from the real engine per batch bucket), and Poisson arrival
  traces sweep the offered rate across the saturation point.  Reports
  simulated mean/p95 latency, mean batch size, and dedup joins per rate —
  all deterministic, zero sleeps.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.engine import BatchResult
from repro.data import WorkloadGenerator
from repro.serving import (Scheduler, SchedulerConfig, SimClock,
                           bucket_batch, poisson_trace, replay_trace)
from repro.launch.serve import build_engine

from .common import csv_row

MAX_NEW_TOKENS = 4


def _distinct_queries(n: int, tag: str) -> List[str]:
    return [f"{tag} question number {i} about subject {i}" for i in range(n)]


def _fresh_engine():
    # threshold > 1 disables the TWEAK band: every distinct query is a pure
    # MISS, so both dispatch modes do identical per-query work and the
    # comparison isolates coalescing (not routing luck under the untrained
    # embedder, whose cross-query sims routinely clear 0.7).
    return build_engine(train_embedder_steps=0, capacity=4096, threshold=1.1)


def bench_coalescing(n: int = 96, batches=(8, 16)):
    """Criterion: coalesced dispatch beats per-request at batch >= 8."""
    # --- per-request dispatch (the seed pattern), bucket-1 shapes
    eng = _fresh_engine()
    eng.handle_batch(["warmup query zero"], max_new_tokens=MAX_NEW_TOKENS)
    queries = _distinct_queries(n, "solo")
    t0 = time.perf_counter()
    for q in queries:
        eng.handle_batch([q], max_new_tokens=MAX_NEW_TOKENS)
    dt_solo = time.perf_counter() - t0
    qps_solo = n / dt_solo
    csv_row("sched_per_request", dt_solo / n * 1e6,
            f"qps={qps_solo:.1f};all_miss;n={n}")

    # --- coalesced dispatch through the scheduler, bucket-B shapes
    for b in batches:
        eng = _fresh_engine()
        eng.handle_batch(_distinct_queries(b, "warm"),
                         max_new_tokens=MAX_NEW_TOKENS)
        sched = Scheduler(
            eng, SchedulerConfig(max_wait=10.0, max_batch=b,
                                 queue_capacity=n,
                                 max_new_tokens=MAX_NEW_TOKENS),
            clock=SimClock())
        trace = [(0.0, q) for q in _distinct_queries(n, "coal")]
        t0 = time.perf_counter()
        done = replay_trace(sched, trace)
        dt = time.perf_counter() - t0
        assert len(done) == n and sched.stats.batches == -(-n // b)
        qps = n / dt
        csv_row(f"sched_coalesced_b{b}", dt / n * 1e6,
                f"qps={qps:.1f};batches={sched.stats.batches}",
                speedup=round(qps / qps_solo, 2))


class _ModeledEngine:
    """Canned-response engine for pure queueing simulations.

    The latency sweep studies scheduler dynamics (waiting, coalescing,
    saturation), not model quality; generation cost enters through the
    calibrated ``service_model`` instead of real compute.
    """

    def handle_batch_result(self, queries, *, max_new_tokens=32):
        meta = [{"sim": 0.0, "decision": 0, "band": -1, "gen_tokens": 0}
                for _ in queries]
        return BatchResult([f"resp: {q}" for q in queries], meta)


def calibrate_service_model(buckets=(1, 2, 4, 8, 16)) -> Dict[int, float]:
    """Measured wall seconds per real-engine dispatch, by batch bucket."""
    eng = _fresh_engine()
    out: Dict[int, float] = {}
    for b in buckets:
        qs = _distinct_queries(b, f"calib{b}")
        eng.handle_batch(qs, max_new_tokens=MAX_NEW_TOKENS)   # compile
        qs = _distinct_queries(b, f"calib{b}x")
        t0 = time.perf_counter()
        eng.handle_batch(qs, max_new_tokens=MAX_NEW_TOKENS)
        out[b] = time.perf_counter() - t0
    return out


def bench_latency_sweep(n: int = 1500, load_factors=(0.25, 0.5, 1.0, 2.0),
                        max_batch: int = 16, max_wait: float = 0.02):
    """Offered-load sweep around the calibrated saturation point."""
    service = calibrate_service_model()
    for b, s in service.items():
        csv_row(f"sched_service_b{b}", s * 1e6, "calibrated_dispatch_cost")

    def service_model(b: int) -> float:
        key = bucket_batch(b)
        return service.get(key, service[max(service)] * key / max(service))

    # saturation throughput: full buckets back to back
    capacity_qps = max_batch / service[max_batch]
    wl = WorkloadGenerator(profile="lmsys", seed=0)
    texts = [q.text for q in wl.sample(n)]
    for f in load_factors:
        rate = f * capacity_qps
        sched = Scheduler(
            _ModeledEngine(),
            SchedulerConfig(max_wait=max_wait, max_batch=max_batch,
                            queue_capacity=512,
                            max_new_tokens=MAX_NEW_TOKENS),
            clock=SimClock(), service_model=service_model)
        done = replay_trace(sched, poisson_trace(texts, rate, seed=1))
        lats = np.array([r.latency for r in done])
        ss = sched.stats
        csv_row(f"sched_latency_load{f:g}", float(lats.mean()) * 1e6,
                f"rate={rate:.0f}qps;p95={np.percentile(lats, 95)*1e3:.1f}ms;"
                f"mean_batch={ss.mean_batch:.1f};joined={ss.joined};"
                f"shed={ss.rejected};"
                f"util={ss.busy_time / max(done[-1].finish, 1e-9):.2f}")


def bench_offered_load(n: int = 1200, load_factors=(0.25, 0.5, 1.0, 2.0),
                       slots: int = 16, max_batch: int = 16,
                       max_wait: float = 0.02, smoke: bool = False):
    """Continuous (slot) vs bucket-barrier dispatch under identical load.

    Same Poisson traces, same queue, same service model — only the
    dispatch discipline differs: the barrier holds arrivals for bucket
    fill and drains each batch to completion; continuous mode splices a
    request into the first slot that frees (DESIGN.md §11).  The service
    model is a fixed affine dispatch cost (NOT calibrated wall time), so
    every number here is a deterministic queueing result and the
    saturation-knee ratios transfer exactly to the CI gate.
    """
    def service_model(b: int) -> float:
        return 0.010 + 0.002 * b   # dispatch overhead + per-row cost

    if smoke:
        n, load_factors = 400, (1.0, 2.0)
    cap_qps = max_batch / service_model(max_batch)   # barrier saturation
    wl = WorkloadGenerator(profile="lmsys", seed=0)
    texts = [q.text for q in wl.sample(n)]
    knee: Dict[str, tuple] = {}
    for f in load_factors:
        trace = poisson_trace(texts, f * cap_qps, seed=1)
        for mode in ("barrier", "continuous"):
            cfg = (SchedulerConfig(continuous=True, slots=slots,
                                   max_batch=max_batch, queue_capacity=512,
                                   max_new_tokens=MAX_NEW_TOKENS)
                   if mode == "continuous" else
                   SchedulerConfig(max_wait=max_wait, max_batch=max_batch,
                                   queue_capacity=512,
                                   max_new_tokens=MAX_NEW_TOKENS))
            sched = Scheduler(_ModeledEngine(), cfg, clock=SimClock(),
                              service_model=service_model)
            done = replay_trace(sched, trace)
            lats = np.array([r.latency for r in done])
            span = max(r.finish for r in done) - trace[0][0]
            p50, p99 = np.percentile(lats, (50, 99))
            tok_s = len(done) * MAX_NEW_TOKENS / span
            csv_row(f"sched_{mode}_load{f:g}", float(lats.mean()) * 1e6,
                    f"p50={p50*1e3:.2f}ms;p99={p99*1e3:.2f}ms;"
                    f"tok_s={tok_s:.0f};done={len(done)};"
                    f"shed={sched.stats.rejected}")
            if f == max(load_factors):
                knee[mode] = (p50, p99, tok_s)
    # the saturation knee (highest swept load): the acceptance ratios —
    # continuous must cut p99 AND raise delivered tokens/s vs the barrier
    b, c = knee["barrier"], knee["continuous"]
    csv_row("sched_knee_p99", c[1] * 1e6,
            f"barrier_p99={b[1]*1e3:.2f}ms;continuous_p99={c[1]*1e3:.2f}ms",
            speedup=round(b[1] / c[1], 2))
    csv_row("sched_knee_tokens_per_s", 0.0,
            f"barrier={b[2]:.0f};continuous={c[2]:.0f}",
            speedup=round(c[2] / b[2], 2))


def main(smoke: bool = False):
    if smoke:
        # CI perf-gate subset: coalescing speedup (machine-independent
        # ratio) + the deterministic continuous-vs-barrier knee ratios;
        # the calibrated latency sweep is study-only
        bench_coalescing(n=64, batches=(8,))
        bench_offered_load(smoke=True)
        return
    bench_coalescing()
    bench_latency_sweep()
    bench_offered_load()


if __name__ == "__main__":
    main()
