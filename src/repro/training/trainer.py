"""Training step builders: plain and gradient-accumulation (microbatched).

``make_train_step`` returns a jit-able (params, opt_state, batch) ->
(params, opt_state, metrics) function.  With ``microbatches > 1`` the batch
axis is split and gradients accumulate through a lax.scan — the memory lever
for the >=300B dry-run configs (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import Model
from .optimizer import AdamWConfig, adamw_update, cosine_schedule


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1, warmup: int = 100,
                    total_steps: int = 10_000):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, micro):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(params, micro)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(acc_body, (g0, jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {}
        lr_scale = cosine_schedule(opt_state["step"], warmup=warmup,
                                   total=total_steps)
        params, opt_state = adamw_update(params, grads, opt_state, opt_cfg,
                                         lr_scale=lr_scale)
        metrics = dict(metrics or {})
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}
    return eval_step
