"""Supervised training for the cross-encoder reranker.

Binary duplicate classification over generated pairs: true duplicates are
positives; hard negatives (polarity flips / entity swaps — exactly the
near-miss regime the router cascade's 0.7–0.9 uncertainty band contains)
and random negatives are negatives.  The trained head is what lets the
cascade's second stage separate "same question, different words" from
"close embedding, different question" where cosine similarity alone
cannot (the misroutes the frontier bench measures recovery on).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.data.questions import QuestionPairGenerator
from repro.models.reranker import score_pairs
from repro.tokenizer import HashWordTokenizer
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def pair_bce_loss(params, cfg, ta, ma, tb, mb, labels):
    """Sigmoid BCE on duplicate logits; labels (B,) in {0, 1}."""
    logits = score_pairs(params, ta, ma, tb, mb, cfg)
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    return -jnp.mean(labels * logp + (1.0 - labels) * lognp)


def train_reranker(params, cfg, tokenizer: HashWordTokenizer, *,
                   steps: int = 150, batch: int = 32, max_len: int = 24,
                   lr: float = 1e-3, hard_frac: float = 0.5, seed: int = 0):
    """Returns (trained params, losses).  CPU-friendly at tiny configs."""
    gen = QuestionPairGenerator(seed=seed)
    opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0)
    opt = init_opt_state(params)

    @jax.jit
    def step(params, opt, ta, ma, tb, mb, y):
        loss, grads = jax.value_and_grad(pair_bce_loss)(
            params, cfg, ta, ma, tb, mb, y)
        params, opt = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    losses = []
    for _s in range(steps):
        pairs = gen.generate(batch, dup_frac=0.5, hard_frac=hard_frac)
        ta, ma = tokenizer.encode_batch([a.text for a, b, y in pairs],
                                        max_len)
        tb, mb = tokenizer.encode_batch([b.text for a, b, y in pairs],
                                        max_len)
        y = jnp.asarray([float(y) for a, b, y in pairs], jnp.float32)
        params, opt, loss = step(params, opt, jnp.asarray(ta),
                                 jnp.asarray(ma), jnp.asarray(tb),
                                 jnp.asarray(mb), y)
        losses.append(float(loss))
    return params, losses
