from .optimizer import AdamWConfig, init_opt_state, adamw_update, cosine_schedule
from .trainer import make_train_step, make_eval_step
