"""Contrastive training for the sentence embedder (MiniLM analogue).

InfoNCE over generated paraphrase pairs: duplicates are positives,
in-batch others + hard negatives (polarity flips / entity swaps) are
negatives.  This gives the semantic cache an embedding space where
"duplicate" actually means cosine-close — the property the paper buys
off-the-shelf from all-MiniLM-L6-v2 and we must train ourselves offline.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.data.questions import QuestionPairGenerator
from repro.models.embedder import encode as embed_encode
from repro.tokenizer import HashWordTokenizer
from .optimizer import AdamWConfig, adamw_update, init_opt_state


def info_nce_loss(params, cfg, ta, ma, tb, mb, tn, mn, temp: float = 0.07,
                  neg_margin: float = 0.4):
    """Bidirectional InfoNCE over duplicate pairs + margin push on HARD
    negatives (polarity flips / entity swaps — the paper's §6 failure mode
    for embedding-only caches)."""
    za = embed_encode(params, ta, ma, cfg)     # (B, D) unit
    zb = embed_encode(params, tb, mb, cfg)
    logits = za @ zb.T / temp                  # (B, B)
    labels = jnp.arange(za.shape[0])
    lab = -jnp.take_along_axis(jax.nn.log_softmax(logits, 1), labels[:, None], 1).mean()
    lba = -jnp.take_along_axis(jax.nn.log_softmax(logits.T, 1), labels[:, None], 1).mean()
    zn = embed_encode(params, tn, mn, cfg)     # hard negative of each anchor
    neg_sim = jnp.sum(za * zn, axis=-1)
    hard = jnp.mean(jax.nn.relu(neg_sim - (1.0 - neg_margin)))
    return 0.5 * (lab + lba) + hard


def train_embedder(params, cfg, tokenizer: HashWordTokenizer, *,
                   steps: int = 200, batch: int = 32, max_len: int = 32,
                   lr: float = 1e-3, seed: int = 0):
    """Returns trained params.  CPU-friendly at tiny configs."""
    gen = QuestionPairGenerator(seed=seed)
    opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0)
    opt = init_opt_state(params)

    @jax.jit
    def step(params, opt, ta, ma, tb, mb, tn, mn):
        loss, grads = jax.value_and_grad(info_nce_loss)(
            params, cfg, ta, ma, tb, mb, tn, mn)
        params, opt = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    losses = []
    for _s in range(steps):
        triples = [gen.triple() for _ in range(batch)]
        ta, ma = tokenizer.encode_batch([a.text for a, b, n in triples], max_len)
        tb, mb = tokenizer.encode_batch([b.text for a, b, n in triples], max_len)
        tn, mn = tokenizer.encode_batch([n.text for a, b, n in triples], max_len)
        params, opt, loss = step(params, opt, jnp.asarray(ta), jnp.asarray(ma),
                                 jnp.asarray(tb), jnp.asarray(mb),
                                 jnp.asarray(tn), jnp.asarray(mn))
        losses.append(float(loss))
    return params, losses
