"""AdamW implemented from scratch (no optax in the container).

Moments are fp32 regardless of param dtype; the update is computed in fp32
and cast back — the standard mixed-precision training recipe for bf16
params with fp32 optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, opt_state, cfg: AdamWConfig,
                 lr_scale=1.0) -> Tuple[Any, Any]:
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vh = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - cfg.lr * lr_scale * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def cosine_schedule(step, *, warmup: int, total: int, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = (s + 1.0) / jnp.maximum(warmup, 1)
    import numpy as np
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return jnp.where(s < warmup, warm, cos)
