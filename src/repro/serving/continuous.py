"""Persistent slot-based decode over the paged KV pool (DESIGN.md §11).

``DecodeSession`` removes the bucket barrier of batch-to-completion
serving: a fixed set of ``slots`` decodes together in one fused
``lax.while_loop`` chunk at a time, finished rows are harvested and
their pages freed at chunk boundaries, and newly admitted requests are
spliced into the free slots — mid-flight join/leave, the continuous
batching every modern serving stack runs (vLLM, Orca, IC-Cache).

Step-boundary protocol (host side drives it, device state is one pytree):

  admit(prompts)  -> dense prefill at the cohort's shape, pages
                     allocated, KV scattered + rows spliced in ONE
                     jitted op; first token sampled from prefill logits
                     with the session's unsplit key (the dense loop's
                     exact step-0 schedule)
  run_chunk(n)    -> fused while_loop: up to n steps, exits early when
                     every occupied row is done; ONE device call
  harvest()       -> the one device_get per chunk; finished rows return
                     their (tokens, length, ended), their block tables
                     are redirected to the TRASH page (so freed pages
                     can be re-issued without stomping) and pages freed

Bitwise contracts, locked by ``tests/test_paged_kv.py``:

* A cohort that fills every slot at step 0 and runs to completion is
  bitwise-identical to ``Generator.generate_with_lengths`` (dense fused
  loop) at the same batch/capacity — same prefill, same key schedule,
  same masked sampling, paged gather slicing to the exact capacity.
* ``run_chunk(fused=False)`` is the host-stepped oracle: the identical
  per-step computation driven from the host, one dispatch per token —
  fused chunks replay it bitwise for ANY join/leave trace.
* A row's trajectory is invariant to its co-residents: admitting into
  the same slot of a busy session produces the same tokens as a solo
  session, bitwise, because every per-row computation in the stack is
  batch-elementwise at fixed shapes.

Under temperature sampling the shared per-step key makes a row's draws
depend on the step at which it joined; the cohort-level contracts above
still hold, but cross-trace row invariance is greedy-only (the engine's
default).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import paged_kv as paged_lib
from .generate import Generator
from .sampler import sample


class NoFreeSlots(RuntimeError):
    """Admission rejected: every slot is occupied.  Harvest first."""


class FinishedRow(dict):
    """One harvested row: {"slot", "tag", "tokens", "length", "ended"}."""


class DecodeSession:
    """A persistent decode batch over ``slots`` rows of paged KV.

    Owns a ``PagePool`` sized for its slots; the generator supplies the
    model, params, sampler and prefill jit.  Capacity is one static
    bound for every row (length-bucket the prompts upstream); admission
    raises rather than truncates when a prompt would not fit.
    """

    def __init__(self, gen: Generator, *, slots: int, capacity: int,
                 seed: int = 0,
                 pool: Optional[paged_lib.PagePool] = None):
        if not gen.model.supports_paged_decode:
            raise NotImplementedError(
                f"{gen.model.cfg.name}: paged KV decode unsupported")
        self.gen = gen
        self.model = gen.model
        self.params = gen.params
        self.cfg = gen.cfg
        self.slots = slots
        self.capacity = capacity
        self.mnt = gen.cfg.max_new_tokens
        if pool is None:
            pool = paged_lib.PagePool(
                gen.model, paged_lib.PagePoolConfig(
                    page_size=gen.cfg.page_size,
                    num_pages=max(
                        gen.cfg.pool_pages,
                        slots * (-(-capacity // gen.cfg.page_size)))))
        self.pool = pool
        self._leases: Dict[int, Any] = {}     # slot -> (tbl_row, writable_row)
        self._tags: Dict[int, Any] = {}       # slot -> caller's request tag
        self._free_slots: List[int] = list(range(slots - 1, -1, -1))
        self._build_ops()
        self.state = self._init_state(seed)

    # ------------------------------------------------------------- jits
    def _build_ops(self):
        model, cfg = self.model, self.cfg
        eos, mnt = cfg.eos_id, self.mnt
        sampler = cfg.sampler

        def splice_one(kp, vp, bt, pos, slot_pos, k, v, pos_d, slot_pos_d,
                       slot_ids, tbl, writable):
            """Scatter one layer's cohort KV into pages + splice rows."""
            kb, cap = k.shape[0], k.shape[1]
            page = kp.shape[1]
            npg = tbl.shape[1]
            trash = kp.shape[0] - 1
            pad = npg * page - cap
            kpg = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(
                kb, npg, page, *k.shape[2:])
            vpg = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(
                kb, npg, page, *v.shape[2:])
            tbl_w = jnp.where(writable, tbl, trash)
            kp = kp.at[tbl_w].set(kpg.astype(kp.dtype))
            vp = vp.at[tbl_w].set(vpg.astype(vp.dtype))
            bt = bt.at[slot_ids].set(tbl)
            slot_pos = slot_pos.at[slot_ids].set(slot_pos_d)
            pos = pos.at[slot_ids].set(
                jnp.broadcast_to(pos_d, (kb,)).astype(jnp.int32))
            return {"kp": kp, "vp": vp, "block_tbl": bt, "pos": pos,
                    "slot_pos": slot_pos}

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _admit(state, dense_caches, logits0, slot_ids, tbl, writable):
            """Splice a prefilled cohort into free slots, one device call.

            Step-0 sampling uses the session key UNSPLIT — exactly the
            dense fused loop's schedule, so an inaugural full cohort
            replays ``_decode_fused`` bitwise.
            """
            dense = paged_lib.kv_leaves(dense_caches)
            it = iter(dense)

            def splice(leaf):
                d = next(it)
                depth = leaf["kp"].ndim - 4
                fn = splice_one
                for _ in range(depth):
                    fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0,
                                               None, None, None))
                return fn(leaf["kp"], leaf["vp"], leaf["block_tbl"],
                          leaf["pos"], leaf["slot_pos"],
                          d["k"], d["v"], d["pos"], d["slot_pos"],
                          slot_ids, tbl, writable)

            caches = paged_lib.map_kv_leaves(state["caches"], splice)
            t0 = sample(state["key"], logits0, sampler)
            done0 = t0 == eos
            row_toks = jnp.full((t0.shape[0], mnt), eos, jnp.int32)
            row_toks = jax.lax.dynamic_update_slice_in_dim(
                row_toks, t0[:, None], 0, axis=1)
            return {
                "caches": caches,
                "key": state["key"],
                "tok": state["tok"].at[slot_ids].set(t0),
                "toks": state["toks"].at[slot_ids].set(row_toks),
                "n_emitted": state["n_emitted"].at[slot_ids].set(1),
                "lengths": state["lengths"].at[slot_ids].set(
                    jnp.where(done0, 1, mnt).astype(jnp.int32)),
                "eos_done": state["eos_done"].at[slot_ids].set(done0),
                "occupied": state["occupied"].at[slot_ids].set(True),
            }

        def step_body(params, state):
            """One decode step over every slot — the chunk loop body.

            Identical semantics to the dense fused body (split key,
            decode, masked sample, record length on fresh EOS), with
            per-row write columns instead of the global step counter so
            rows at different depths coexist.
            """
            key, sub = jax.random.split(state["key"])
            logits, caches = model.decode_step(
                params, state["tok"], state["caches"])
            inactive = (~state["occupied"] | state["eos_done"]
                        | (state["n_emitted"] >= mnt))
            t = jnp.where(inactive, eos, sample(sub, logits, sampler))
            new_eos = state["eos_done"] | (~inactive & (t == eos))
            col = state["n_emitted"]
            hot = (jnp.arange(mnt, dtype=jnp.int32)[None, :] == col[:, None]
                   ) & ~inactive[:, None]
            toks = jnp.where(hot, t[:, None], state["toks"])
            lengths = jnp.where(new_eos & ~state["eos_done"], col + 1,
                                state["lengths"])
            n_emitted = jnp.where(inactive, col, col + 1)
            return {"caches": caches, "key": key, "tok": t, "toks": toks,
                    "n_emitted": n_emitted, "lengths": lengths,
                    "eos_done": new_eos, "occupied": state["occupied"]}

        def active(state):
            return (state["occupied"] & ~state["eos_done"]
                    & (state["n_emitted"] < mnt))

        @functools.partial(jax.jit, donate_argnums=(1,),
                           static_argnames=("steps",))
        def _chunk(params, state, steps):
            """Up to ``steps`` decode steps in ONE device call."""
            def cond(carry):
                i, state = carry
                return (i < steps) & jnp.any(active(state))

            def body(carry):
                i, state = carry
                return i + 1, step_body(params, state)

            _, state = jax.lax.while_loop(cond, body, (jnp.int32(0), state))
            return state

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _step_once(params, state):
            """The chunk body as a standalone dispatch — the host-stepped
            oracle (one sync per token BY DESIGN, like PR 4's host loop)."""
            return step_body(params, state)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _evict(state, slot_ids):
            """Clear harvested slots: block tables -> TRASH page so the
            freed pages can be re-issued without ever being stomped."""
            def clear(leaf):
                trash = leaf["kp"].shape[-4] - 1
                depth = leaf["kp"].ndim - 4
                idx = (slice(None),) * depth
                bt = leaf["block_tbl"].at[idx + (slot_ids,)].set(trash)
                sp = leaf["slot_pos"].at[idx + (slot_ids,)].set(-1)
                pos = leaf["pos"].at[idx + (slot_ids,)].set(0)
                out = dict(leaf)
                out.update(block_tbl=bt, slot_pos=sp, pos=pos)
                return out

            caches = paged_lib.map_kv_leaves(state["caches"], clear)
            out = dict(state)
            out.update(
                caches=caches,
                tok=state["tok"].at[slot_ids].set(eos),
                toks=state["toks"].at[slot_ids].set(eos),
                n_emitted=state["n_emitted"].at[slot_ids].set(0),
                lengths=state["lengths"].at[slot_ids].set(0),
                eos_done=state["eos_done"].at[slot_ids].set(False),
                occupied=state["occupied"].at[slot_ids].set(False))
            return out

        self._admit = _admit
        self._chunk = _chunk
        self._step_once = _step_once
        self._evict = _evict
        self._active = active

    def _init_state(self, seed: int):
        npg = self.pool.pages_per_seq(self.capacity)
        dense0 = self.model.init_caches(self.slots, self.capacity)
        tbl0 = np.full((self.slots, npg), self.pool.trash_page, np.int32)
        caches0 = paged_lib.pack_caches(
            self.pool.storage, dense0,
            jax.device_put(tbl0),
            jax.device_put(np.zeros((self.slots, npg), bool)))
        self.pool.adopt(caches0)
        b, mnt = self.slots, self.mnt
        eos = self.cfg.eos_id
        return {
            "caches": caches0,
            "key": jax.random.PRNGKey(jax.device_put(np.uint32(seed))),
            "tok": jnp.full((b,), eos, jnp.int32),
            "toks": jnp.full((b, mnt), eos, jnp.int32),
            "n_emitted": jnp.zeros((b,), jnp.int32),
            "lengths": jnp.zeros((b,), jnp.int32),
            "eos_done": jnp.zeros((b,), bool),
            "occupied": jnp.zeros((b,), bool),
        }

    # --------------------------------------------------------- protocol
    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    def admit(self, tokens, tags: Optional[Sequence[Any]] = None,
              slots: Optional[Sequence[int]] = None) -> List[int]:
        """Splice a cohort of prompts (k, S) into free slots.

        Returns the slot ids used.  ``tags`` ride along to ``harvest``
        (request ids); ``slots`` pins explicit slot choices (tests use
        this to prove slot-stable bitwise identity).  All-or-nothing:
        raises ``NoFreeSlots`` / ``PagePoolExhausted`` / ``ValueError``
        before touching device state.
        """
        tokens = jnp.asarray(tokens, jnp.int32)
        k, s = tokens.shape
        if s + self.mnt + 1 > self.capacity:
            raise ValueError(
                f"prompt of {s} tokens + {self.mnt} new exceeds session "
                f"capacity {self.capacity}")
        if slots is None:
            if k > len(self._free_slots):
                raise NoFreeSlots(
                    f"cohort of {k} rows, {len(self._free_slots)} free slots")
            chosen = [self._free_slots[-1 - i] for i in range(k)]
        else:
            chosen = [int(x) for x in slots]  # hostsync: ok caller-supplied host ints
            if len(chosen) != k or len(set(chosen)) != k:
                raise ValueError("slots must name one distinct free slot "
                                 "per row")
            if any(c not in self._free_slots for c in chosen):
                raise NoFreeSlots(f"requested slots {chosen} not all free")
        tbl, writable = self.pool.alloc_block_table(k, self.capacity)
        try:
            logits0, dense = self.gen._prefill(
                self.params, {"tokens": tokens}, self.capacity)
            self.state = self._admit(
                self.state, dense, logits0,
                jax.device_put(np.asarray(chosen, np.int32)),  # hostsync: ok host slot ids entering jit
                jax.device_put(tbl.astype(np.int32)),
                jax.device_put(writable))
        except Exception:
            self.pool.free_block_table(tbl, writable)
            raise
        for c in chosen:
            self._free_slots.remove(c)
        for i, c in enumerate(chosen):
            self._leases[c] = (tbl[i], writable[i])
            self._tags[c] = None if tags is None else tags[i]
        return chosen

    def run_chunk(self, steps: int, *, fused: bool = True) -> None:
        """Advance every occupied row by up to ``steps`` decode steps.

        ``fused=True`` is one device call; ``fused=False`` is the
        host-stepped differential oracle (same computation, one dispatch
        per token) — byte-identical by the PR 4 fused-loop argument.
        """
        if fused:
            self.state = self._chunk(self.params, self.state, steps)
            return
        for _ in range(steps):
            live = jax.device_get(jnp.any(self._active(self.state)))  # hostsync: ok differential oracle syncs per step BY DESIGN
            if not bool(live):  # hostsync: ok oracle-path host flag, see above
                break
            self.state = self._step_once(self.params, self.state)

    def harvest(self) -> List[FinishedRow]:  # hostsync: ok the ONE per-chunk sync; the rest is host numpy on its result
        """Collect finished rows, free their pages, clear their slots.

        THE one device->host sync per step boundary: flags, lengths and
        the token block come down in a single ``device_get``.
        """
        occupied, eos_done, n_emitted, lengths, toks = jax.device_get(
            (self.state["occupied"], self.state["eos_done"],
             self.state["n_emitted"], self.state["lengths"],
             self.state["toks"]))  # hostsync: ok the one per-chunk sync
        fin = np.flatnonzero(occupied & (eos_done | (n_emitted >= self.mnt)))
        if fin.size == 0:
            return []
        out = []
        for c in fin:
            c = int(c)
            out.append(FinishedRow(
                slot=c, tag=self._tags.pop(c),
                tokens=toks[c].copy(), length=int(lengths[c]),
                ended=bool(eos_done[c])))
        self.state = self._evict(
            self.state, jax.device_put(fin.astype(np.int32)))
        for c in fin:
            self.pool.free_block_table(*self._leases.pop(int(c)))
            self._free_slots.append(int(c))
        self._free_slots.sort(reverse=True)
        return out

    def drain(self, *, chunk: int = 0, fused: bool = True
              ) -> List[FinishedRow]:
        """Run chunks until every occupied slot has finished and been
        harvested (end-of-stream).  ``chunk=0`` uses the full budget."""
        steps = chunk or self.mnt
        out: List[FinishedRow] = []
        for _ in range(self.slots * self.mnt + 1):
            if len(self._free_slots) == self.slots:
                break
            self.run_chunk(steps, fused=fused)
            out.extend(self.harvest())
        return out


def leaked_pages(*generators) -> int:
    """Total leaked (live minus pinned) KV pages across paged generators.

    A replica's page accounting must return to zero once every in-flight
    request is harvested (DESIGN.md §11/§12): ``live_pages`` counts refs
    the pool still holds, ``pinned_pages`` the deliberately persistent
    shared-prefix pins.  Dense (non-paged) generators have no pool and
    contribute nothing.  Deduplicates repeated generator objects so a
    big/small pair sharing one Generator is not double-counted.
    """
    total = 0
    for gen in {id(g): g for g in generators}.values():
        pool = getattr(gen, "pool", None)
        if pool is not None:
            total += pool.live_pages - pool.pinned_pages
    return total
