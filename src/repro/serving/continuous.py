"""Persistent slot-based decode over the paged KV pool (DESIGN.md §11).

``DecodeSession`` removes the bucket barrier of batch-to-completion
serving: a fixed set of ``slots`` decodes together in one fused
``lax.while_loop`` chunk at a time, finished rows are harvested and
their pages freed at chunk boundaries, and newly admitted requests are
spliced into the free slots — mid-flight join/leave, the continuous
batching every modern serving stack runs (vLLM, Orca, IC-Cache).

Step-boundary protocol (host side drives it, device state is one pytree):

  admit(prompts)  -> dense prefill at the cohort's shape, pages
                     allocated, KV scattered + rows spliced in ONE
                     jitted op; first token sampled from prefill logits
                     with the session's unsplit key (the dense loop's
                     exact step-0 schedule)
  run_chunk(n)    -> fused while_loop: up to n steps, exits early when
                     every occupied row is done; ONE device call
  harvest()       -> the one device_get per chunk; finished rows return
                     their (tokens, length, ended), their block tables
                     are redirected to the TRASH page (so freed pages
                     can be re-issued without stomping) and pages freed

Bitwise contracts, locked by ``tests/test_paged_kv.py``:

* A cohort that fills every slot at step 0 and runs to completion is
  bitwise-identical to ``Generator.generate_with_lengths`` (dense fused
  loop) at the same batch/capacity — same prefill, same key schedule,
  same masked sampling, paged gather slicing to the exact capacity.
* ``run_chunk(fused=False)`` is the host-stepped oracle: the identical
  per-step computation driven from the host, one dispatch per token —
  fused chunks replay it bitwise for ANY join/leave trace.
* A row's trajectory is invariant to its co-residents: admitting into
  the same slot of a busy session produces the same tokens as a solo
  session, bitwise, because every per-row computation in the stack is
  batch-elementwise at fixed shapes.

Under temperature sampling the shared per-step key makes a row's draws
depend on the step at which it joined; the cohort-level contracts above
still hold, but cross-trace row invariance is greedy-only (the engine's
default).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import paged_kv as paged_lib
from .generate import Generator
from .sampler import greedy_ids, mask_vocab, sample


class NoFreeSlots(RuntimeError):
    """Admission rejected: every slot is occupied.  Harvest first."""


class FinishedRow(dict):
    """One harvested row: {"slot", "tag", "tokens", "length", "ended"}."""


class DecodeSession:
    """A persistent decode batch over ``slots`` rows of paged KV.

    Owns a ``PagePool`` sized for its slots; the generator supplies the
    model, params, sampler and prefill jit.  Capacity is one static
    bound for every row (length-bucket the prompts upstream); admission
    raises rather than truncates when a prompt would not fit.
    """

    def __init__(self, gen: Generator, *, slots: int, capacity: int,
                 seed: int = 0,
                 pool: Optional[paged_lib.PagePool] = None,
                 spec_k: int = 1):
        if not gen.model.supports_paged_decode:
            raise NotImplementedError(
                f"{gen.model.cfg.name}: paged KV decode unsupported")
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        if spec_k > 1:
            # Speculation is lossless only under deterministic greedy
            # argmax (DESIGN.md §14); same gating as GenerateConfig.
            if gen.cfg.sampler.temperature > 0:
                raise ValueError(
                    "spec_k > 1 requires greedy sampling "
                    f"(temperature={gen.cfg.sampler.temperature})")
            if not gen.model.supports_spec_decode:
                raise ValueError(
                    f"{gen.model.cfg.name}: speculative decode unsupported "
                    f"for this architecture")
            if spec_k > gen.cfg.max_new_tokens:
                raise ValueError(
                    f"spec_k={spec_k} exceeds the "
                    f"max_new_tokens={gen.cfg.max_new_tokens} budget")
        self.gen = gen
        self.model = gen.model
        self.params = gen.params
        self.cfg = gen.cfg
        self.slots = slots
        self.capacity = capacity
        self.spec_k = spec_k
        self.mnt = gen.cfg.max_new_tokens
        if pool is None:
            pool = paged_lib.PagePool(
                gen.model, paged_lib.PagePoolConfig(
                    page_size=gen.cfg.page_size,
                    num_pages=max(
                        gen.cfg.pool_pages,
                        slots * (-(-capacity // gen.cfg.page_size)))))
        self.pool = pool
        self._leases: Dict[int, Any] = {}     # slot -> (tbl_row, writable_row)
        self._tags: Dict[int, Any] = {}       # slot -> caller's request tag
        self._free_slots: List[int] = list(range(slots - 1, -1, -1))
        self._build_ops()
        self.state = self._init_state(seed)

    # ------------------------------------------------------------- jits
    def _build_ops(self):
        model, cfg = self.model, self.cfg
        eos, mnt = cfg.eos_id, self.mnt
        sampler = cfg.sampler
        spec_k = self.spec_k            # trace-time constant

        def splice_one(kp, vp, bt, pos, slot_pos, k, v, pos_d, slot_pos_d,
                       slot_ids, tbl, writable):
            """Scatter one layer's cohort KV into pages + splice rows."""
            kb, cap = k.shape[0], k.shape[1]
            page = kp.shape[1]
            npg = tbl.shape[1]
            trash = kp.shape[0] - 1
            pad = npg * page - cap
            kpg = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(
                kb, npg, page, *k.shape[2:])
            vpg = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(
                kb, npg, page, *v.shape[2:])
            tbl_w = jnp.where(writable, tbl, trash)
            kp = kp.at[tbl_w].set(kpg.astype(kp.dtype))
            vp = vp.at[tbl_w].set(vpg.astype(vp.dtype))
            bt = bt.at[slot_ids].set(tbl)
            slot_pos = slot_pos.at[slot_ids].set(slot_pos_d)
            pos = pos.at[slot_ids].set(
                jnp.broadcast_to(pos_d, (kb,)).astype(jnp.int32))
            return {"kp": kp, "vp": vp, "block_tbl": bt, "pos": pos,
                    "slot_pos": slot_pos}

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _admit(state, dense_caches, logits0, slot_ids, tbl, writable,
                   did=None, dlen=None):
            """Splice a prefilled cohort into free slots, one device call.

            Step-0 sampling uses the session key UNSPLIT — exactly the
            dense fused loop's schedule, so an inaugural full cohort
            replays ``_decode_fused`` bitwise.  A spec_k > 1 session also
            splices the cohort's draft buffers (``did`` (k, mnt) /
            ``dlen`` (k,)) and arms speculation for rows whose draft
            predicted the first emitted token (DESIGN.md §14) — so
            mid-flight joins speculate exactly like inaugural rows.
            """
            dense = paged_lib.kv_leaves(dense_caches)
            it = iter(dense)

            def splice(leaf):
                d = next(it)
                depth = leaf["kp"].ndim - 4
                fn = splice_one
                for _ in range(depth):
                    fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0,
                                               None, None, None))
                return fn(leaf["kp"], leaf["vp"], leaf["block_tbl"],
                          leaf["pos"], leaf["slot_pos"],
                          d["k"], d["v"], d["pos"], d["slot_pos"],
                          slot_ids, tbl, writable)

            caches = paged_lib.map_kv_leaves(state["caches"], splice)
            t0 = sample(state["key"], logits0, sampler)
            done0 = t0 == eos
            row_toks = jnp.full((t0.shape[0], mnt), eos, jnp.int32)
            row_toks = jax.lax.dynamic_update_slice_in_dim(
                row_toks, t0[:, None], 0, axis=1)
            out = {
                "caches": caches,
                "key": state["key"],
                "tok": state["tok"].at[slot_ids].set(t0),
                "toks": state["toks"].at[slot_ids].set(row_toks),
                "n_emitted": state["n_emitted"].at[slot_ids].set(1),
                "lengths": state["lengths"].at[slot_ids].set(
                    jnp.where(done0, 1, mnt).astype(jnp.int32)),
                "eos_done": state["eos_done"].at[slot_ids].set(done0),
                "occupied": state["occupied"].at[slot_ids].set(True),
            }
            if spec_k > 1:
                spec0 = ~done0 & (dlen > 0) & (t0 == did[:, 0])
                out.update(
                    draft=state["draft"].at[slot_ids].set(did),
                    draft_len=state["draft_len"].at[slot_ids].set(dlen),
                    spec_on=state["spec_on"].at[slot_ids].set(spec0),
                    prop=state["prop"], acc=state["acc"],
                    spec_steps=state["spec_steps"])
            return out

        def step_body(params, state):
            """One decode step over every slot — the chunk loop body.

            Identical semantics to the dense fused body (split key,
            decode, masked sample, record length on fresh EOS), with
            per-row write columns instead of the global step counter so
            rows at different depths coexist.
            """
            key, sub = jax.random.split(state["key"])
            logits, caches = model.decode_step(
                params, state["tok"], state["caches"])
            inactive = (~state["occupied"] | state["eos_done"]
                        | (state["n_emitted"] >= mnt))
            t = jnp.where(inactive, eos, sample(sub, logits, sampler))
            new_eos = state["eos_done"] | (~inactive & (t == eos))
            col = state["n_emitted"]
            hot = (jnp.arange(mnt, dtype=jnp.int32)[None, :] == col[:, None]
                   ) & ~inactive[:, None]
            toks = jnp.where(hot, t[:, None], state["toks"])
            lengths = jnp.where(new_eos & ~state["eos_done"], col + 1,
                                state["lengths"])
            n_emitted = jnp.where(inactive, col, col + 1)
            return {"caches": caches, "key": key, "tok": t, "toks": toks,
                    "n_emitted": n_emitted, "lengths": lengths,
                    "eos_done": new_eos, "occupied": state["occupied"]}

        def step_body_spec(params, state):
            """One (slots, k) verify block over every row — the spec_k > 1
            chunk body (DESIGN.md §14).

            Every occupied row runs the same k-wide ``decode_block``; a
            row still speculating verifies its draft and accepts
            ``a ∈ [1, k]`` tokens, a row whose draft diverged or ran out
            accepts exactly its one greedy token (``a = 1`` — position 0
            of the block is bitwise the plain decode step, since in-block
            causal masking hides the optimistic writes), and the k - a
            rejected cache positions are rewound.  Greedy-only, so the
            session key is carried untouched.  Token-for-token identical
            to the plain ``step_body`` trace for any join/leave pattern.
            """
            k = spec_k
            tok, ne = state["tok"], state["n_emitted"]
            draft, dlen = state["draft"], state["draft_len"]
            b = tok.shape[0]
            act = (state["occupied"] & ~state["eos_done"] & (ne < mnt))
            spec = act & state["spec_on"]
            gidx = jnp.clip(
                ne[:, None] + jnp.arange(k - 1, dtype=jnp.int32)[None, :],
                0, mnt - 1)
            x = jnp.concatenate(
                [tok[:, None], jnp.take_along_axis(draft, gidx, axis=1)],
                axis=1)                                          # (B, k)
            logits, caches = model.decode_block(params, x, state["caches"])
            g = greedy_ids(mask_vocab(logits, sampler))          # (B, k)
            dpos = (ne[:, None]
                    + jnp.arange(k - 1, dtype=jnp.int32)[None, :])
            dval = jnp.take_along_axis(
                draft, jnp.clip(dpos, 0, mnt - 1), axis=1)
            match = (g[:, :k - 1] == dval) & (dpos < dlen[:, None])
            lmatch = jnp.sum(
                jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
            iota_k = jnp.broadcast_to(
                jnp.arange(k, dtype=jnp.int32)[None, :], (b, k))
            eos_idx = jnp.min(jnp.where(g == eos, iota_k, k), axis=1)
            a_spec = jnp.minimum(jnp.minimum(lmatch + 1, eos_idx + 1),
                                 mnt - ne)
            a = jnp.where(spec, a_spec,
                          jnp.where(act, 1, 0).astype(jnp.int32))
            last = jnp.clip(a - 1, 0, k - 1)
            tlast = jnp.take_along_axis(g, last[:, None], axis=1)[:, 0]
            ended_now = (a > 0) & (tlast == eos)
            lengths = jnp.where(ended_now, ne + a, state["lengths"])
            cm = jnp.broadcast_to(
                jnp.arange(mnt, dtype=jnp.int32)[None, :], (b, mnt))
            sel = jnp.clip(cm - ne[:, None], 0, k - 1)
            val = jnp.take_along_axis(g, sel, axis=1)
            in_rng = (cm >= ne[:, None]) & (cm < (ne + a)[:, None])
            toks = jnp.where(in_rng, val, state["toks"])
            tok = jnp.where(a > 0, tlast, tok)
            caches = paged_lib.rewind_kv(caches, k - a)
            ne2 = ne + a
            n_fed = jnp.clip(dlen - ne, 0, k - 1)
            return {
                "caches": caches, "key": state["key"], "tok": tok,
                "toks": toks, "n_emitted": ne2, "lengths": lengths,
                "eos_done": state["eos_done"] | ended_now,
                "occupied": state["occupied"],
                "draft": draft, "draft_len": dlen,
                # Full acceptance keeps a row speculating (drafts re-sync
                # after a local tweak); rejection or exhaustion drops it.
                "spec_on": spec & (a == k) & (ne2 < dlen),
                "prop": state["prop"] + jnp.sum(jnp.where(spec, n_fed, 0)),
                "acc": state["acc"] + jnp.sum(
                    jnp.where(spec, jnp.minimum(lmatch, a), 0)),
                "spec_steps": state["spec_steps"]
                + jnp.any(spec).astype(jnp.int32),
            }

        if spec_k > 1:
            # Spec sessions decode in k-wide verify blocks; _chunk and
            # _step_once pick this up through the closure.
            step_body = step_body_spec

        def active(state):
            return (state["occupied"] & ~state["eos_done"]
                    & (state["n_emitted"] < mnt))

        @functools.partial(jax.jit, donate_argnums=(1,),
                           static_argnames=("steps",))
        def _chunk(params, state, steps):
            """Up to ``steps`` decode steps in ONE device call."""
            def cond(carry):
                i, state = carry
                return (i < steps) & jnp.any(active(state))

            def body(carry):
                i, state = carry
                return i + 1, step_body(params, state)

            _, state = jax.lax.while_loop(cond, body, (jnp.int32(0), state))
            return state

        @functools.partial(jax.jit, donate_argnums=(1,))
        def _step_once(params, state):
            """The chunk body as a standalone dispatch — the host-stepped
            oracle (one sync per token BY DESIGN, like PR 4's host loop)."""
            return step_body(params, state)

        @functools.partial(jax.jit, donate_argnums=(0,))
        def _evict(state, slot_ids):
            """Clear harvested slots: block tables -> TRASH page so the
            freed pages can be re-issued without ever being stomped."""
            def clear(leaf):
                trash = leaf["kp"].shape[-4] - 1
                depth = leaf["kp"].ndim - 4
                idx = (slice(None),) * depth
                bt = leaf["block_tbl"].at[idx + (slot_ids,)].set(trash)
                sp = leaf["slot_pos"].at[idx + (slot_ids,)].set(-1)
                pos = leaf["pos"].at[idx + (slot_ids,)].set(0)
                out = dict(leaf)
                out.update(block_tbl=bt, slot_pos=sp, pos=pos)
                return out

            caches = paged_lib.map_kv_leaves(state["caches"], clear)
            out = dict(state)
            out.update(
                caches=caches,
                tok=state["tok"].at[slot_ids].set(eos),
                toks=state["toks"].at[slot_ids].set(eos),
                n_emitted=state["n_emitted"].at[slot_ids].set(0),
                lengths=state["lengths"].at[slot_ids].set(0),
                eos_done=state["eos_done"].at[slot_ids].set(False),
                occupied=state["occupied"].at[slot_ids].set(False))
            if spec_k > 1:
                out.update(
                    draft=state["draft"].at[slot_ids].set(0),
                    draft_len=state["draft_len"].at[slot_ids].set(0),
                    spec_on=state["spec_on"].at[slot_ids].set(False))
            return out

        self._admit = _admit
        self._chunk = _chunk
        self._step_once = _step_once
        self._evict = _evict
        self._active = active

    def _init_state(self, seed: int):
        npg = self.pool.pages_per_seq(self.capacity)
        dense0 = self.model.init_caches(self.slots, self.capacity)
        tbl0 = np.full((self.slots, npg), self.pool.trash_page, np.int32)
        caches0 = paged_lib.pack_caches(
            self.pool.storage, dense0,
            jax.device_put(tbl0),
            jax.device_put(np.zeros((self.slots, npg), bool)))
        self.pool.adopt(caches0)
        b, mnt = self.slots, self.mnt
        eos = self.cfg.eos_id
        state = {
            "caches": caches0,
            "key": jax.random.PRNGKey(jax.device_put(np.uint32(seed))),
            "tok": jnp.full((b,), eos, jnp.int32),
            "toks": jnp.full((b, mnt), eos, jnp.int32),
            "n_emitted": jnp.zeros((b,), jnp.int32),
            "lengths": jnp.zeros((b,), jnp.int32),
            "eos_done": jnp.zeros((b,), bool),
            "occupied": jnp.zeros((b,), bool),
        }
        if self.spec_k > 1:
            # rewind_kv carries a per-row top-level position; paged
            # leaves are already per-row, so this only lifts the counter.
            state["caches"] = paged_lib.row_pos_caches(state["caches"], b)
            state.update(
                draft=jnp.zeros((b, mnt), jnp.int32),
                draft_len=jnp.zeros((b,), jnp.int32),
                spec_on=jnp.zeros((b,), bool),
                prop=jnp.zeros((), jnp.int32),
                acc=jnp.zeros((), jnp.int32),
                spec_steps=jnp.zeros((), jnp.int32))
        return state

    # --------------------------------------------------------- protocol
    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def spec_stats(self) -> Dict[str, int]:
        """Cumulative speculation counters (DESIGN.md §14).

        ``proposed`` drafted tokens fed to verify blocks, ``accepted``
        drafted tokens emitted, ``spec_steps`` verify iterations that had
        at least one speculating row.  Call at step boundaries: reading
        them costs one device sync (a spec_k == 1 session costs nothing).
        """
        if self.spec_k == 1:
            return {"proposed": 0, "accepted": 0, "spec_steps": 0}
        prop, acc, steps = jax.device_get(  # hostsync: ok stats readout at a step boundary, caller-paced
            (self.state["prop"], self.state["acc"],
             self.state["spec_steps"]))
        return {"proposed": int(prop),    # hostsync: ok already host-side
                "accepted": int(acc),     # hostsync: ok already host-side
                "spec_steps": int(steps)}  # hostsync: ok already host-side

    def admit(self, tokens, tags: Optional[Sequence[Any]] = None,
              slots: Optional[Sequence[int]] = None,
              drafts: Optional[Any] = None) -> List[int]:
        """Splice a cohort of prompts (k, S) into free slots.

        Returns the slot ids used.  ``tags`` ride along to ``harvest``
        (request ids); ``slots`` pins explicit slot choices (tests use
        this to prove slot-stable bitwise identity).  ``drafts`` is an
        optional ``(ids (k, D), lens (k,))`` pair of host int arrays —
        per-row draft continuations (cached-response token ids) that a
        ``spec_k > 1`` session verifies in k-wide blocks (DESIGN.md §14);
        rows whose draft is empty (``lens == 0``) decode plainly.
        All-or-nothing: raises ``NoFreeSlots`` / ``PagePoolExhausted`` /
        ``ValueError`` before touching device state.
        """
        if drafts is not None and self.spec_k == 1:
            raise ValueError("drafts require a spec_k > 1 session")
        tokens = jnp.asarray(tokens, jnp.int32)
        k, s = tokens.shape
        if s + self.mnt + 1 > self.capacity:
            raise ValueError(
                f"prompt of {s} tokens + {self.mnt} new exceeds session "
                f"capacity {self.capacity}")
        if slots is None:
            if k > len(self._free_slots):
                raise NoFreeSlots(
                    f"cohort of {k} rows, {len(self._free_slots)} free slots")
            chosen = [self._free_slots[-1 - i] for i in range(k)]
        else:
            chosen = [int(x) for x in slots]  # hostsync: ok caller-supplied host ints
            if len(chosen) != k or len(set(chosen)) != k:
                raise ValueError("slots must name one distinct free slot "
                                 "per row")
            if any(c not in self._free_slots for c in chosen):
                raise NoFreeSlots(f"requested slots {chosen} not all free")
        spec_args = ()
        if self.spec_k > 1:
            # Pad/clip to the mnt-column draft block the chunk body
            # indexes — same host-side normalisation as the fused path.
            did = np.zeros((k, self.mnt), np.int32)
            dlen = np.zeros((k,), np.int32)
            if drafts is not None:
                raw_ids = np.asarray(drafts[0], np.int32)  # hostsync: ok drafts are host-resident cached-response ids
                w = min(raw_ids.shape[1], self.mnt)
                did[:, :w] = raw_ids[:, :w]
                dlen = np.minimum(np.asarray(drafts[1], np.int32), self.mnt)  # hostsync: ok drafts are host-resident cached-response ids
            spec_args = (jax.device_put(did), jax.device_put(dlen))
        tbl, writable = self.pool.alloc_block_table(k, self.capacity)
        try:
            logits0, dense = self.gen._prefill(
                self.params, {"tokens": tokens}, self.capacity)
            self.state = self._admit(
                self.state, dense, logits0,
                jax.device_put(np.asarray(chosen, np.int32)),  # hostsync: ok host slot ids entering jit
                jax.device_put(tbl.astype(np.int32)),
                jax.device_put(writable), *spec_args)
        except Exception:
            self.pool.free_block_table(tbl, writable)
            raise
        for c in chosen:
            self._free_slots.remove(c)
        for i, c in enumerate(chosen):
            self._leases[c] = (tbl[i], writable[i])
            self._tags[c] = None if tags is None else tags[i]
        return chosen

    def run_chunk(self, steps: int, *, fused: bool = True) -> None:
        """Advance every occupied row by up to ``steps`` decode steps.

        ``fused=True`` is one device call; ``fused=False`` is the
        host-stepped differential oracle (same computation, one dispatch
        per token) — byte-identical by the PR 4 fused-loop argument.
        On a ``spec_k > 1`` session a "step" is one verify-block
        iteration, which emits up to ``spec_k`` tokens per speculating
        row — the chunk still exits early once every row is done.
        """
        if fused:
            self.state = self._chunk(self.params, self.state, steps)
            return
        for _ in range(steps):
            live = jax.device_get(jnp.any(self._active(self.state)))  # hostsync: ok differential oracle syncs per step BY DESIGN
            if not bool(live):  # hostsync: ok oracle-path host flag, see above
                break
            self.state = self._step_once(self.params, self.state)

    def harvest(self) -> List[FinishedRow]:  # hostsync: ok the ONE per-chunk sync; the rest is host numpy on its result
        """Collect finished rows, free their pages, clear their slots.

        THE one device->host sync per step boundary: flags, lengths and
        the token block come down in a single ``device_get``.
        """
        occupied, eos_done, n_emitted, lengths, toks = jax.device_get(
            (self.state["occupied"], self.state["eos_done"],
             self.state["n_emitted"], self.state["lengths"],
             self.state["toks"]))  # hostsync: ok the one per-chunk sync
        fin = np.flatnonzero(occupied & (eos_done | (n_emitted >= self.mnt)))
        if fin.size == 0:
            return []
        out = []
        for c in fin:
            c = int(c)
            out.append(FinishedRow(
                slot=c, tag=self._tags.pop(c),
                tokens=toks[c].copy(), length=int(lengths[c]),
                ended=bool(eos_done[c])))
        self.state = self._evict(
            self.state, jax.device_put(fin.astype(np.int32)))
        for c in fin:
            self.pool.free_block_table(*self._leases.pop(int(c)))
            self._free_slots.append(int(c))
        self._free_slots.sort(reverse=True)
        return out

    def drain(self, *, chunk: int = 0, fused: bool = True
              ) -> List[FinishedRow]:
        """Run chunks until every occupied slot has finished and been
        harvested (end-of-stream).  ``chunk=0`` uses the full budget."""
        steps = chunk or self.mnt
        out: List[FinishedRow] = []
        for _ in range(self.slots * self.mnt + 1):
            if len(self._free_slots) == self.slots:
                break
            self.run_chunk(steps, fused=fused)
            out.extend(self.harvest())
        return out


def leaked_pages(*generators) -> int:
    """Total leaked (live minus pinned) KV pages across paged generators.

    A replica's page accounting must return to zero once every in-flight
    request is harvested (DESIGN.md §11/§12): ``live_pages`` counts refs
    the pool still holds, ``pinned_pages`` the deliberately persistent
    shared-prefix pins.  Dense (non-paged) generators have no pool and
    contribute nothing.  Deduplicates repeated generator objects so a
    big/small pair sharing one Generator is not double-counted.
    """
    total = 0
    for gen in {id(g): g for g in generators}.values():
        pool = getattr(gen, "pool", None)
        if pool is not None:
            total += pool.live_pages - pool.pinned_pages
    return total
