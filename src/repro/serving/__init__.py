from .sampler import SamplerConfig, sample
from .generate import GenerateConfig, Generator, PrefixCache
from .batcher import pad_to_buckets, bucket_batch, bucket_len, floor_len_bucket
from .scheduler import (Clock, SimClock, WallClock, QueueFull, Request,
                        ReplicaScheduler, Scheduler, SchedulerConfig,
                        SchedulerStats, poisson_trace, replay_trace)
from .paged_kv import (PagePool, PagePoolConfig, PagePoolExhausted,
                       PinnedPrefix)
from .continuous import DecodeSession, FinishedRow, NoFreeSlots, leaked_pages
