from .sampler import SamplerConfig, sample
from .generate import GenerateConfig, Generator
from .batcher import pad_to_buckets, bucket_batch, bucket_len
