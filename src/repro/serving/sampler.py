"""Token samplers: greedy / temperature / top-k, fp32 logits in, id out."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 => greedy
    top_k: int = 0             # 0 => full softmax
    vocab_size: int = 0        # mask padded logits above this (0 = off)


def greedy_ids(logits):
    """Greedy argmax over the last axis with EXPLICIT tie-breaking.

    ``jnp.argmax`` happens to return the first maximal index on most
    backends, but that is an implementation detail, not a contract.
    Speculative decode (DESIGN.md §14) compares verify-time greedy
    choices against decode-time greedy choices token-for-token, so ties
    MUST break identically everywhere: this spells out lowest-id-wins as
    a min-reduction over the argmax set, which no backend may reorder.
    Works on any (..., V) logits block.
    """
    v = logits.shape[-1]
    top = jnp.max(logits, axis=-1, keepdims=True)
    is_top = logits == top
    iota = jnp.broadcast_to(jnp.arange(v, dtype=jnp.int32), is_top.shape)
    return jnp.min(jnp.where(is_top, iota, v), axis=-1).astype(jnp.int32)


def mask_vocab(logits, cfg: SamplerConfig):
    """Mask padded logit lanes above ``cfg.vocab_size`` (0 = off)."""
    if cfg.vocab_size:
        v = logits.shape[-1]
        keep = jnp.arange(v) < cfg.vocab_size
        # explicit broadcast: the sanitizer harness runs with
        # jax_numpy_rank_promotion="raise"
        keep = jnp.broadcast_to(keep, logits.shape)
        logits = jnp.where(keep, logits, -jnp.inf)
    return logits


def sample(key, logits, cfg: SamplerConfig):
    """logits (B, V) -> token ids (B,) int32."""
    logits = mask_vocab(logits, cfg)
    if cfg.temperature <= 0.0:
        return greedy_ids(logits)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def masked_sample(key, logits, done, eos_id: int, cfg: SamplerConfig):
    """Decode-loop step sampler with done-masking.

    Samples (B,) ids, forces rows already finished to keep emitting EOS,
    and returns the updated done mask.  Used by the fused on-device decode
    loop; the host-loop oracle applies the identical masking inline on the
    host side (same semantics, same key usage: one draw per step, even for
    finished rows), which the fused-vs-host differential tests pin down.
    """
    t = sample(key, logits, cfg)
    t = jnp.where(done, eos_id, t)
    return t, done | (t == eos_id)
