"""Token samplers: greedy / temperature / top-k, fp32 logits in, id out."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0   # 0 => greedy
    top_k: int = 0             # 0 => full softmax
    vocab_size: int = 0        # mask padded logits above this (0 = off)


def sample(key, logits, cfg: SamplerConfig):
    """logits (B, V) -> token ids (B,) int32."""
    if cfg.vocab_size:
        v = logits.shape[-1]
        mask = jnp.arange(v) < cfg.vocab_size
        logits = jnp.where(mask[None, :], logits, -jnp.inf)
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k > 0:
        kth = jax.lax.top_k(logits, cfg.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def masked_sample(key, logits, done, eos_id: int, cfg: SamplerConfig):
    """Decode-loop step sampler with done-masking.

    Samples (B,) ids, forces rows already finished to keep emitting EOS,
    and returns the updated done mask.  Used by the fused on-device decode
    loop; the host-loop oracle applies the identical masking inline on the
    host side (same semantics, same key usage: one draw per step, even for
    finished rows), which the fused-vs-host differential tests pin down.
    """
    t = sample(key, logits, cfg)
    t = jnp.where(done, eos_id, t)
    return t, done | (t == eos_id)
