"""Batched generation: jitted prefill + a fused on-device decode loop.

The decode loop is a single jitted ``jax.lax.while_loop`` (DESIGN.md §8)
carrying ``(step, token, caches, key, done, tokens, lengths)``: one device
call returns the whole ``(B, max_new_tokens)`` block plus per-row REAL
generated lengths, replacing ``max_new_tokens`` sequential decode
dispatches (and as many host syncs) with exactly one of each.  Finished
rows keep emitting EOS inside the loop (done-masking), the loop exits
early once every row has emitted EOS, and the per-step key split matches
the host loop exactly, so fused and host decode are byte-identical.

The original host-driven loop is retained behind
``GenerateConfig(fused=False)`` (or ``generate(..., fused=False)``) as the
differential-testing oracle; compiled artifacts are cached per
(batch, prompt_len, max_new_tokens) bucket by ``jax.jit`` itself.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from . import paged_kv as paged_lib
from .sampler import SamplerConfig, masked_sample, sample


@dataclasses.dataclass(frozen=True)
class GenerateConfig:
    max_new_tokens: int = 32
    eos_id: int = 2
    sampler: SamplerConfig = SamplerConfig()
    # Fused on-device lax.while_loop decode (default).  False falls back to
    # the host-driven per-step loop — the differential-testing oracle.
    fused: bool = True
    # Paged KV decode (DESIGN.md §11): prefill stays dense, then the KV is
    # scattered into pool pages and the SAME fused loop carries the paged
    # caches — bitwise-identical outputs, pool-backed storage.  With a
    # prefix_cache, the shared prefix's full pages are pinned once and
    # shared by every row.  pool_pages=0 sizes the pool to the first
    # paged call's need.
    paged: bool = False
    page_size: int = 16
    pool_pages: int = 0


@dataclasses.dataclass(frozen=True)
class PrefixCache:
    """Prefilled KV state of a shared prompt prefix (DESIGN.md §9).

    ``caches`` is the model's caches pytree for the prefix alone (capacity
    exactly ``length``), already materialised at serve batch size
    ``batch`` — one build per (model, batch bucket), reused read-only by
    every suffix prefill at that bucket.  ``token_ids`` records what was
    prefilled so owners (the engine) can detect staleness.
    """
    caches: Any
    length: int
    batch: int
    token_ids: Tuple[int, ...]


class Generator:
    """Wraps a Model with jitted prefill/decode for repeated serving calls."""

    def __init__(self, model: Model, params, gen_cfg: GenerateConfig):
        self.model = model
        self.params = params
        self.cfg = gen_cfg
        # Fallback per-call seeds when the caller threads none: every batch
        # gets a fresh key stream instead of replaying PRNGKey(0) forever.
        self._auto_seed = itertools.count()
        # Page pool for cfg.paged decode, built lazily on first use so
        # dense-only generators allocate nothing (DESIGN.md §11).
        self._pool: Optional[paged_lib.PagePool] = None

        @functools.partial(jax.jit, static_argnames=("capacity",))
        def _prefill(params, batch, capacity):
            return model.prefill(params, batch, capacity)

        @functools.partial(jax.jit, static_argnames=("capacity",))
        def _prefill_with_prefix(params, batch, capacity, prefix):
            # prefix is a read-only pytree argument: jit specializes per
            # (batch, suffix, prefix) shape bucket, so each bucket compiles
            # its own broadcast of the shared KV exactly once.
            return model.prefill_with_prefix(params, batch, capacity, prefix)

        @jax.jit
        def _prefill_prefix(params, tokens):
            return model.prefill_prefix(params, tokens)

        @jax.jit
        def _step(params, token, caches, key):
            logits, caches = model.decode_step(params, token, caches)
            nxt = sample(key, logits, gen_cfg.sampler)
            return nxt, caches

        @functools.partial(jax.jit, static_argnames=("mnt",))
        def _decode_fused(params, logits0, caches, key, mnt):
            """Whole decode in ONE device call.

            Returns (tokens (B, mnt) — EOS-padded past each row's end,
            lengths (B,) — real generated tokens including the terminating
            EOS, ended (B,) — whether the row emitted EOS within budget).
            """
            eos = gen_cfg.eos_id
            b = logits0.shape[0]
            # Step 0 samples from the prefill logits with the unsplit key —
            # the exact key schedule of the host loop.
            tok = sample(key, logits0, gen_cfg.sampler)
            done = tok == eos
            toks = jnp.full((b, mnt), eos, jnp.int32)
            toks = jax.lax.dynamic_update_slice_in_dim(
                toks, tok[:, None], 0, axis=1)
            lengths = jnp.where(done, 1, mnt).astype(jnp.int32)

            def cond(carry):
                step, _, _, _, done, _, _ = carry
                return (step < mnt) & ~jnp.all(done)

            def body(carry):
                step, tok, caches, key, done, toks, lengths = carry
                key, sub = jax.random.split(key)
                logits, caches = model.decode_step(params, tok, caches)
                t, new_done = masked_sample(sub, logits, done, eos,
                                            gen_cfg.sampler)
                # A row finishing at column `step` generated step+1 real
                # tokens (its EOS included) — recorded on device so the
                # host never scans rows for EOS.
                lengths = jnp.where(new_done & ~done, step + 1, lengths)
                toks = jax.lax.dynamic_update_slice_in_dim(
                    toks, t[:, None], step, axis=1)
                return step + 1, t, caches, key, new_done, toks, lengths

            carry = (jnp.int32(1), tok, caches, key, done, toks, lengths)
            _, _, _, _, done, toks, lengths = jax.lax.while_loop(
                cond, body, carry)
            return toks, lengths, done

        self._prefill = _prefill
        self._prefill_with_prefix = _prefill_with_prefix
        self._prefill_prefix = _prefill_prefix
        self._step = _step
        self._decode_fused = _decode_fused

    # ------------------------------------------------------ paged decode
    @property
    def pool(self) -> Optional[paged_lib.PagePool]:
        """The page pool behind ``cfg.paged`` decode (None until used)."""
        return self._pool

    def _ensure_pool(self, batch: int, capacity: int) -> paged_lib.PagePool:
        if self._pool is None:
            need = batch * (-(-capacity // self.cfg.page_size))
            self._pool = paged_lib.PagePool(
                self.model, paged_lib.PagePoolConfig(
                    page_size=self.cfg.page_size,
                    num_pages=max(self.cfg.pool_pages, need)))
        return self._pool

    def _page_in(self, caches, batch: int, capacity: int,
                 prefix_cache: Optional[PrefixCache]):
        """Scatter a dense prefill's caches into pool pages.

        Returns (paged caches, (block_tbl, writable)) — the latter is
        the host-side lease the caller must release via
        ``pool.free_block_table`` once decode finishes.  With a prefix
        cache, the prefix's full pages are pinned once (keyed by its
        token ids) and shared read-only by every row.
        """
        pool = self._ensure_pool(batch, capacity)
        pin = (pool.ensure_pinned(prefix_cache)
               if prefix_cache is not None else None)
        tbl, writable = pool.alloc_block_table(batch, capacity, pin)
        try:
            paged = paged_lib.pack_caches(
                pool.storage, caches,
                jax.device_put(tbl.astype(np.int32)),
                jax.device_put(writable))
        except Exception:
            pool.free_block_table(tbl, writable)
            raise
        pool.adopt(paged)
        return paged, (tbl, writable)

    # ------------------------------------------------------ prefix cache
    @property
    def supports_prefix_prefill(self) -> bool:
        return self.model.supports_prefix_prefill

    def build_prefix_cache(self, prefix_ids: Sequence[int],
                           batch: int) -> PrefixCache:
        """Prefill a shared prefix once at ``batch`` rows (DESIGN.md §9).

        Every row holds the same ids, so the KV is computed per batch
        bucket with the exact shapes the suffix prefills will see; the
        result is reused read-only across all subsequent
        ``generate*(..., prefix_cache=...)`` calls at that bucket.
        Prefilling the duplicate rows is deliberately preferred over a
        batch-1 build + host-side broadcast: it is a one-time cost of a
        few dozen token-rows per bucket, stays agnostic to where each
        cache leaf keeps its batch axis (scan-stacked vs remainder
        layers), and trivially preserves the byte-identical contract.
        """
        ids = tuple(int(t) for t in prefix_ids)  # hostsync: ok one-time prefix build, host-side ids
        if not ids:
            raise ValueError("prefix_ids must be non-empty")
        toks = jnp.broadcast_to(jnp.asarray(ids, jnp.int32)[None, :],
                                (batch, len(ids)))
        caches = self._prefill_prefix(self.params, toks)
        return PrefixCache(caches=caches, length=len(ids), batch=batch,
                           token_ids=ids)

    def generate(self, batch: Dict[str, jnp.ndarray], *,
                 max_new_tokens: Optional[int] = None,
                 seed: Optional[int] = None,
                 fused: Optional[bool] = None,
                 prefix_cache: Optional[PrefixCache] = None) -> np.ndarray:
        """batch: {tokens (B,S), [frames|prefix_embeds]} -> (B, T_new) ids.

        Rows that finish early are EOS-padded out to ``max_new_tokens``.
        """
        return self.generate_with_lengths(
            batch, max_new_tokens=max_new_tokens, seed=seed, fused=fused,
            prefix_cache=prefix_cache)[0]

    def generate_with_lengths(
            self, batch: Dict[str, jnp.ndarray], *,
            max_new_tokens: Optional[int] = None,
            seed: Optional[int] = None,
            fused: Optional[bool] = None,
            prefix_cache: Optional[PrefixCache] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Generate and return (tokens (B, T_new), lengths (B,), ended (B,)).

        ``lengths`` counts each row's REAL generated tokens — up to and
        including its terminating EOS when ``ended`` is True, the full
        budget otherwise.  ``max_new_tokens=0`` is an explicit request for
        nothing: returns an empty (B, 0) block with zero-length rows and
        runs no device work at all.

        With ``prefix_cache``, ``batch["tokens"]`` holds only the suffix:
        prefill attends over the stored prefix KV and the whole call is
        byte-identical to generating from the ``[prefix | suffix]``
        concatenation (same capacity, same key schedule).
        """
        # `is None`, not falsiness: an explicit max_new_tokens=0 must not
        # silently fall back to the config default.
        mnt = self.cfg.max_new_tokens if max_new_tokens is None else max_new_tokens
        if mnt < 0:
            raise ValueError(f"max_new_tokens must be >= 0, got {mnt}")
        b, s = batch["tokens"].shape
        if mnt == 0:
            return (np.zeros((b, 0), np.int32), np.zeros((b,), np.int32),
                    np.zeros((b,), bool))
        if seed is None:
            seed = next(self._auto_seed)
        use_fused = self.cfg.fused if fused is None else fused
        if prefix_cache is not None:
            if b != prefix_cache.batch:
                raise ValueError(
                    f"prefix cache was built for batch {prefix_cache.batch}, "
                    f"got a batch of {b} rows — build one per batch bucket")
            capacity = prefix_cache.length + s + mnt + 1
            logits, caches = self._prefill_with_prefix(
                self.params, batch, capacity, prefix_cache.caches)
        else:
            capacity = s + mnt + 1
            if self.model.cfg.num_prefix_tokens:
                capacity += self.model.cfg.num_prefix_tokens
            logits, caches = self._prefill(self.params, batch, capacity)
        page_lease = None
        if self.cfg.paged:
            if not self.model.supports_paged_decode:
                raise NotImplementedError(
                    f"{self.model.cfg.name}: paged KV decode unsupported "
                    f"for this architecture — use dense decode")
            caches, page_lease = self._page_in(caches, b, capacity,
                                               prefix_cache)
        # device_put the seed explicitly: PRNGKey(python_int) would move
        # the scalar implicitly, which the transfer-guard harness forbids
        key = jax.random.PRNGKey(jax.device_put(np.uint32(seed)))
        try:
            if use_fused:
                toks, lengths, ended = self._decode_fused(
                    self.params, logits, caches, key, mnt)
                # THE per-generate-call device->host sync: the whole token
                # block + lengths + ended flags in one device_get
                return jax.device_get((toks, lengths, ended))  # hostsync: ok the one per-call sync
            return self._host_loop(logits, caches, key, mnt)
        finally:
            if page_lease is not None:
                self._pool.free_block_table(*page_lease)

    def _host_loop(self, logits, caches, key, mnt: int):  # hostsync: ok differential oracle syncs per step BY DESIGN
        """Host-driven per-step decode: the differential-testing oracle.

        One device dispatch + one host sync per token; same sampling, key
        schedule, done-masking, and outputs as the fused loop.
        """
        eos = self.cfg.eos_id
        tok = sample(key, logits, self.cfg.sampler)
        t = np.asarray(tok)
        b = t.shape[0]
        out = np.full((b, mnt), eos, np.int32)
        out[:, 0] = t
        done = t == eos
        lengths = np.where(done, 1, mnt).astype(np.int32)
        for i in range(1, mnt):
            if done.all():
                break
            key, sub = jax.random.split(key)
            tok, caches = self._step(self.params, tok, caches, sub)
            t = np.asarray(tok)
            t = np.where(done, eos, t)
            out[:, i] = t
            lengths[~done & (t == eos)] = i + 1
            done |= t == eos
        return out, lengths, done
