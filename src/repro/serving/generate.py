"""Batched generation loop: jitted prefill + jitted decode steps.

Host drives the loop (early-exit when every sequence hit EOS); the compiled
artifacts are cached per (batch, prompt_len) bucket by jax.jit itself.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from .sampler import SamplerConfig, sample


@dataclasses.dataclass(frozen=True)
class GenerateConfig:
    max_new_tokens: int = 32
    eos_id: int = 2
    sampler: SamplerConfig = SamplerConfig()


class Generator:
    """Wraps a Model with jitted prefill/decode for repeated serving calls."""

    def __init__(self, model: Model, params, gen_cfg: GenerateConfig):
        self.model = model
        self.params = params
        self.cfg = gen_cfg

        @functools.partial(jax.jit, static_argnames=("capacity",))
        def _prefill(params, batch, capacity):
            return model.prefill(params, batch, capacity)

        @jax.jit
        def _step(params, token, caches, key):
            logits, caches = model.decode_step(params, token, caches)
            nxt = sample(key, logits, gen_cfg.sampler)
            return nxt, caches

        self._prefill = _prefill
        self._step = _step

    def generate(self, batch: Dict[str, jnp.ndarray], *,
                 max_new_tokens: Optional[int] = None, seed: int = 0) -> np.ndarray:
        """batch: {tokens (B,S), [frames|prefix_embeds]} -> (B, T_new) ids."""
        mnt = max_new_tokens or self.cfg.max_new_tokens
        b, s = batch["tokens"].shape
        capacity = s + mnt + 1
        if self.model.cfg.num_prefix_tokens:
            capacity += self.model.cfg.num_prefix_tokens
        logits, caches = self._prefill(self.params, batch, capacity)
        key = jax.random.PRNGKey(seed)
        tok = sample(key, logits, self.cfg.sampler)
        out = [np.asarray(tok)]
        done = np.asarray(tok) == self.cfg.eos_id
        for i in range(mnt - 1):
            if done.all():
                break
            key, sub = jax.random.split(key)
            tok, caches = self._step(self.params, tok, caches, sub)
            t = np.asarray(tok)
            t = np.where(done, self.cfg.eos_id, t)
            out.append(t)
            done |= t == self.cfg.eos_id
        return np.stack(out, axis=1)  # (B, T_new)
