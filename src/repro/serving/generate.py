"""Batched generation: jitted prefill + a fused on-device decode loop.

The decode loop is a single jitted ``jax.lax.while_loop`` (DESIGN.md §8)
carrying ``(step, token, caches, key, done, tokens, lengths)``: one device
call returns the whole ``(B, max_new_tokens)`` block plus per-row REAL
generated lengths, replacing ``max_new_tokens`` sequential decode
dispatches (and as many host syncs) with exactly one of each.  Finished
rows keep emitting EOS inside the loop (done-masking), the loop exits
early once every row has emitted EOS, and the per-step key split matches
the host loop exactly, so fused and host decode are byte-identical.

The original host-driven loop is retained behind
``GenerateConfig(fused=False)`` (or ``generate(..., fused=False)``) as the
differential-testing oracle; compiled artifacts are cached per
(batch, prompt_len, max_new_tokens) bucket by ``jax.jit`` itself.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from . import paged_kv as paged_lib
from .sampler import SamplerConfig, greedy_ids, mask_vocab, masked_sample, sample


@dataclasses.dataclass(frozen=True)
class GenerateConfig:
    max_new_tokens: int = 32
    eos_id: int = 2
    sampler: SamplerConfig = SamplerConfig()
    # Fused on-device lax.while_loop decode (default).  False falls back to
    # the host-driven per-step loop — the differential-testing oracle.
    fused: bool = True
    # Paged KV decode (DESIGN.md §11): prefill stays dense, then the KV is
    # scattered into pool pages and the SAME fused loop carries the paged
    # caches — bitwise-identical outputs, pool-backed storage.  With a
    # prefix_cache, the shared prefix's full pages are pinned once and
    # shared by every row.  pool_pages=0 sizes the pool to the first
    # paged call's need.
    paged: bool = False
    page_size: int = 16
    pool_pages: int = 0
    # Draft-verify speculative decode (DESIGN.md §14): when a call supplies
    # per-row draft token ids, each fused-loop iteration verifies a
    # (B, spec_k) block in ONE forward and accepts the longest greedy-
    # matching prefix plus one correction token — token-for-token identical
    # to plain fused decode, lossless only because greedy argmax is
    # deterministic.  spec_k is the verify block width; 1 degenerates to
    # per-row single-token decode (still draft-driven bookkeeping).
    spec_k: int = 1

    def __post_init__(self):
        # Reject incoherent combos up front — no silent fallback.
        if self.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
        if self.spec_k > self.max_new_tokens:
            raise ValueError(
                f"spec_k ({self.spec_k}) > max_new_tokens "
                f"({self.max_new_tokens}): a verify block can never exceed "
                f"the decode budget")
        if self.spec_k > 1 and self.sampler.temperature > 0:
            raise ValueError(
                "speculative decode is greedy-only (temperature 0): the "
                "lossless acceptance rule compares argmax choices; set "
                "spec_k=1 or temperature=0.0")


@dataclasses.dataclass(frozen=True)
class PrefixCache:
    """Prefilled KV state of a shared prompt prefix (DESIGN.md §9).

    ``caches`` is the model's caches pytree for the prefix alone (capacity
    exactly ``length``), already materialised at serve batch size
    ``batch`` — one build per (model, batch bucket), reused read-only by
    every suffix prefill at that bucket.  ``token_ids`` records what was
    prefilled so owners (the engine) can detect staleness.
    """
    caches: Any
    length: int
    batch: int
    token_ids: Tuple[int, ...]


class Generator:
    """Wraps a Model with jitted prefill/decode for repeated serving calls."""

    def __init__(self, model: Model, params, gen_cfg: GenerateConfig):
        self.model = model
        self.params = params
        self.cfg = gen_cfg
        if gen_cfg.spec_k > 1 and not model.supports_spec_decode:
            raise ValueError(
                f"{model.cfg.name}: spec_k={gen_cfg.spec_k} but this "
                f"architecture cannot verify draft blocks (recurrent state "
                f"/ windowed KV can't rewind) — use spec_k=1")
        # Fallback per-call seeds when the caller threads none: every batch
        # gets a fresh key stream instead of replaying PRNGKey(0) forever.
        self._auto_seed = itertools.count()
        # Page pool for cfg.paged decode, built lazily on first use so
        # dense-only generators allocate nothing (DESIGN.md §11).
        self._pool: Optional[paged_lib.PagePool] = None
        # Speculation counters: cumulative across calls, plus the last
        # call's slice — the engine aggregates these into EngineStats.
        self.spec_stats = {"proposed": 0, "accepted": 0, "spec_steps": 0}
        self.last_spec_stats = {"proposed": 0, "accepted": 0,
                                "spec_steps": 0}

        @functools.partial(jax.jit, static_argnames=("capacity",))
        def _prefill(params, batch, capacity):
            return model.prefill(params, batch, capacity)

        @functools.partial(jax.jit, static_argnames=("capacity",))
        def _prefill_with_prefix(params, batch, capacity, prefix):
            # prefix is a read-only pytree argument: jit specializes per
            # (batch, suffix, prefix) shape bucket, so each bucket compiles
            # its own broadcast of the shared KV exactly once.
            return model.prefill_with_prefix(params, batch, capacity, prefix)

        @jax.jit
        def _prefill_prefix(params, tokens):
            return model.prefill_prefix(params, tokens)

        @jax.jit
        def _step(params, token, caches, key):
            logits, caches = model.decode_step(params, token, caches)
            nxt = sample(key, logits, gen_cfg.sampler)
            return nxt, caches

        @functools.partial(jax.jit, static_argnames=("mnt",))
        def _decode_fused(params, logits0, caches, key, mnt):
            """Whole decode in ONE device call.

            Returns (tokens (B, mnt) — EOS-padded past each row's end,
            lengths (B,) — real generated tokens including the terminating
            EOS, ended (B,) — whether the row emitted EOS within budget).
            """
            eos = gen_cfg.eos_id
            b = logits0.shape[0]
            # Step 0 samples from the prefill logits with the unsplit key —
            # the exact key schedule of the host loop.
            tok = sample(key, logits0, gen_cfg.sampler)
            done = tok == eos
            toks = jnp.full((b, mnt), eos, jnp.int32)
            toks = jax.lax.dynamic_update_slice_in_dim(
                toks, tok[:, None], 0, axis=1)
            lengths = jnp.where(done, 1, mnt).astype(jnp.int32)

            def cond(carry):
                step, _, _, _, done, _, _ = carry
                return (step < mnt) & ~jnp.all(done)

            def body(carry):
                step, tok, caches, key, done, toks, lengths = carry
                key, sub = jax.random.split(key)
                logits, caches = model.decode_step(params, tok, caches)
                t, new_done = masked_sample(sub, logits, done, eos,
                                            gen_cfg.sampler)
                # A row finishing at column `step` generated step+1 real
                # tokens (its EOS included) — recorded on device so the
                # host never scans rows for EOS.
                lengths = jnp.where(new_done & ~done, step + 1, lengths)
                toks = jax.lax.dynamic_update_slice_in_dim(
                    toks, t[:, None], step, axis=1)
                return step + 1, t, caches, key, new_done, toks, lengths

            carry = (jnp.int32(1), tok, caches, key, done, toks, lengths)
            _, _, _, _, done, toks, lengths = jax.lax.while_loop(
                cond, body, carry)
            return toks, lengths, done

        @functools.partial(jax.jit, static_argnames=("mnt", "k"))
        def _decode_fused_spec(params, logits0, caches, draft_pack, mnt, k):
            """Draft-verify speculative decode, whole budget in ONE call.

            ``draft_pack`` is the (B, mnt + 1) int32 ``[draft_len |
            draft_ids]`` concatenation — ONE host->device transfer per
            call (two small puts mid-stream measurably stall behind the
            in-flight prefill on the CPU backend).

            Greedy-only (the caller validates), so no PRNG key is carried
            at all — the key schedule is vacuously identical to the plain
            fused loop's.  Two phases (DESIGN.md §14):

            1. While any active row still has draft tokens, verify a
               (B, k) block per iteration: feed ``[last_tok, draft...]``,
               accept the longest prefix whose greedy choices match the
               draft plus the first correction token (``a ∈ [1, k]``
               per active row), and REWIND the k - a optimistically
               written cache positions.
            2. Plain per-row single-token decode (k=1 block — bitwise
               the same computation as ``decode_step``) for rows whose
               drafts are exhausted or diverged.

            Returns (tokens (B, mnt) EOS-padded, lengths (B,), ended (B,),
            proposed, accepted, spec_steps) — the last three are scalar
            int32 speculation counters (drafted tokens fed / drafted
            tokens emitted / verify-block iterations).
            """
            eos = gen_cfg.eos_id
            scfg = gen_cfg.sampler
            b = logits0.shape[0]
            draft_len = draft_pack[:, 0]
            draft_ids = draft_pack[:, 1:]
            d = draft_ids.shape[1]
            caches = paged_lib.row_pos_caches(caches, b)
            tok = greedy_ids(mask_vocab(logits0, scfg))
            eos_done = tok == eos
            toks = jnp.full((b, mnt), eos, jnp.int32)
            toks = jax.lax.dynamic_update_slice_in_dim(
                toks, tok[:, None], 0, axis=1)
            lengths = jnp.where(eos_done, 1, mnt).astype(jnp.int32)
            ne = jnp.ones((b,), jnp.int32)          # tokens emitted per row
            # Speculate only while the draft tracks the stream: it must
            # predict token 0 correctly to be worth a verify block at all.
            spec_on = (~eos_done) & (draft_len > 0) & (tok == draft_ids[:, 0])
            zero = jnp.zeros((), jnp.int32)

            def cond1(carry):
                _, _, eos_done, _, _, ne, spec_on, _, _, _ = carry
                return jnp.any(~eos_done & (ne < mnt) & spec_on)

            def body1(carry):
                (tok, caches, eos_done, toks, lengths, ne, spec_on,
                 prop, acc, steps) = carry
                active = ~eos_done & (ne < mnt) & spec_on
                # Verify block x: last emitted token, then the draft's
                # predictions for output positions [ne, ne + k - 1).
                gidx = jnp.clip(
                    ne[:, None] + jnp.arange(k - 1, dtype=jnp.int32)[None, :],
                    0, d - 1)
                dtoks = jnp.take_along_axis(draft_ids, gidx, axis=1)
                x = jnp.concatenate([tok[:, None], dtoks], axis=1)   # (B, k)
                logits, caches = model.decode_block(params, x, caches)
                g = greedy_ids(mask_vocab(logits, scfg))             # (B, k)
                # g[:, i] is the TRUE greedy token at output position
                # ne + i provided the fed draft prefix matched — the
                # cumprod keeps only the leading matched run, so later
                # coincidental matches never count.
                dpos = (ne[:, None]
                        + jnp.arange(k - 1, dtype=jnp.int32)[None, :])
                dval = jnp.take_along_axis(
                    draft_ids, jnp.clip(dpos, 0, d - 1), axis=1)
                match = (g[:, :k - 1] == dval) & (dpos < draft_len[:, None])
                lmatch = jnp.sum(
                    jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
                iota_k = jnp.broadcast_to(
                    jnp.arange(k, dtype=jnp.int32)[None, :], (b, k))
                eos_idx = jnp.min(jnp.where(g == eos, iota_k, k), axis=1)
                a = jnp.minimum(jnp.minimum(lmatch + 1, eos_idx + 1),
                                mnt - ne)
                a = jnp.where(active, a, 0)
                last = jnp.clip(a - 1, 0, k - 1)
                tlast = jnp.take_along_axis(g, last[:, None], axis=1)[:, 0]
                ended_now = (a > 0) & (tlast == eos)
                lengths = jnp.where(ended_now, ne + a, lengths)
                # Block write of the a accepted tokens into the output
                # buffer (per-row offsets, so a gather-select like the KV
                # block write rather than a dynamic slice).
                cm = jnp.broadcast_to(
                    jnp.arange(mnt, dtype=jnp.int32)[None, :], (b, mnt))
                sel = jnp.clip(cm - ne[:, None], 0, k - 1)
                val = jnp.take_along_axis(g, sel, axis=1)
                in_rng = (cm >= ne[:, None]) & (cm < (ne + a)[:, None])
                toks = jnp.where(in_rng, val, toks)
                tok = jnp.where(a > 0, tlast, tok)
                # Drop the k - a rejected cache positions; inactive rows
                # (a = 0) roll back the whole block.
                caches = paged_lib.rewind_kv(caches, k - a)
                ne2 = ne + a
                n_fed = jnp.clip(draft_len - ne, 0, k - 1)
                prop = prop + jnp.sum(jnp.where(active, n_fed, 0))
                acc = acc + jnp.sum(jnp.where(active,
                                              jnp.minimum(lmatch, a), 0))
                # Full acceptance keeps the row speculating (drafts can
                # re-sync after a local tweak); any rejection or draft
                # exhaustion drops it to phase 2 for good.
                spec_on = active & (a == k) & (ne2 < draft_len)
                eos_done = eos_done | ended_now
                return (tok, caches, eos_done, toks, lengths, ne2, spec_on,
                        prop, acc, steps + 1)

            carry = (tok, caches, eos_done, toks, lengths, ne, spec_on,
                     zero, zero, zero)
            (tok, caches, eos_done, toks, lengths, ne, _, prop, acc,
             steps) = jax.lax.while_loop(cond1, body1, carry)

            def cond2(carry):
                _, _, eos_done, _, _, ne = carry
                return jnp.any(~eos_done & (ne < mnt))

            def body2(carry):
                tok, caches, eos_done, toks, lengths, ne = carry
                logits, caches = model.decode_block(
                    params, tok[:, None], caches)
                g1 = greedy_ids(mask_vocab(logits, scfg))[:, 0]
                active = ~eos_done & (ne < mnt)
                t = jnp.where(active, g1, tok)
                end_now = active & (t == eos)
                lengths = jnp.where(end_now, ne + 1, lengths)
                hot = ((jnp.broadcast_to(
                    jnp.arange(mnt, dtype=jnp.int32)[None, :], (b, mnt))
                    == ne[:, None]) & active[:, None])
                toks = jnp.where(
                    hot, jnp.broadcast_to(t[:, None], (b, mnt)), toks)
                ne = ne + active.astype(jnp.int32)
                return t, caches, eos_done | end_now, toks, lengths, ne

            carry2 = (tok, caches, eos_done, toks, lengths, ne)
            _, _, eos_done, toks, lengths, _ = jax.lax.while_loop(
                cond2, body2, carry2)
            return toks, lengths, eos_done, prop, acc, steps

        self._prefill = _prefill
        self._prefill_with_prefix = _prefill_with_prefix
        self._prefill_prefix = _prefill_prefix
        self._step = _step
        self._decode_fused = _decode_fused
        self._decode_fused_spec = _decode_fused_spec

    # ------------------------------------------------------ paged decode
    @property
    def pool(self) -> Optional[paged_lib.PagePool]:
        """The page pool behind ``cfg.paged`` decode (None until used)."""
        return self._pool

    def _ensure_pool(self, batch: int, capacity: int) -> paged_lib.PagePool:
        if self._pool is None:
            need = batch * (-(-capacity // self.cfg.page_size))
            self._pool = paged_lib.PagePool(
                self.model, paged_lib.PagePoolConfig(
                    page_size=self.cfg.page_size,
                    num_pages=max(self.cfg.pool_pages, need)))
        return self._pool

    def _page_in(self, caches, batch: int, capacity: int,
                 prefix_cache: Optional[PrefixCache]):
        """Scatter a dense prefill's caches into pool pages.

        Returns (paged caches, (block_tbl, writable)) — the latter is
        the host-side lease the caller must release via
        ``pool.free_block_table`` once decode finishes.  With a prefix
        cache, the prefix's full pages are pinned once (keyed by its
        token ids) and shared read-only by every row.
        """
        pool = self._ensure_pool(batch, capacity)
        pin = (pool.ensure_pinned(prefix_cache)
               if prefix_cache is not None else None)
        tbl, writable = pool.alloc_block_table(batch, capacity, pin)
        try:
            paged = paged_lib.pack_caches(
                pool.storage, caches,
                jax.device_put(tbl.astype(np.int32)),
                jax.device_put(writable))
        except Exception:
            pool.free_block_table(tbl, writable)
            raise
        pool.adopt(paged)
        return paged, (tbl, writable)

    # ------------------------------------------------------ prefix cache
    @property
    def supports_prefix_prefill(self) -> bool:
        return self.model.supports_prefix_prefill

    @property
    def speculation_ready(self) -> bool:
        """True when callers should bother threading drafts (DESIGN.md §14).

        spec_k=1 would verify one token per forward — all bookkeeping, no
        win — so the engine only harvests cached-response drafts when the
        configured block is actually wider than plain decode.
        """
        return (self.cfg.spec_k > 1 and self.cfg.fused
                and self.cfg.sampler.temperature <= 0
                and self.model.supports_spec_decode)

    def build_prefix_cache(self, prefix_ids: Sequence[int],
                           batch: int) -> PrefixCache:
        """Prefill a shared prefix once at ``batch`` rows (DESIGN.md §9).

        Every row holds the same ids, so the KV is computed per batch
        bucket with the exact shapes the suffix prefills will see; the
        result is reused read-only across all subsequent
        ``generate*(..., prefix_cache=...)`` calls at that bucket.
        Prefilling the duplicate rows is deliberately preferred over a
        batch-1 build + host-side broadcast: it is a one-time cost of a
        few dozen token-rows per bucket, stays agnostic to where each
        cache leaf keeps its batch axis (scan-stacked vs remainder
        layers), and trivially preserves the byte-identical contract.
        """
        ids = tuple(int(t) for t in prefix_ids)  # hostsync: ok one-time prefix build, host-side ids
        if not ids:
            raise ValueError("prefix_ids must be non-empty")
        toks = jnp.broadcast_to(jnp.asarray(ids, jnp.int32)[None, :],
                                (batch, len(ids)))
        caches = self._prefill_prefix(self.params, toks)
        return PrefixCache(caches=caches, length=len(ids), batch=batch,
                           token_ids=ids)

    def generate(self, batch: Dict[str, jnp.ndarray], *,
                 max_new_tokens: Optional[int] = None,
                 seed: Optional[int] = None,
                 fused: Optional[bool] = None,
                 prefix_cache: Optional[PrefixCache] = None) -> np.ndarray:
        """batch: {tokens (B,S), [frames|prefix_embeds]} -> (B, T_new) ids.

        Rows that finish early are EOS-padded out to ``max_new_tokens``.
        """
        return self.generate_with_lengths(
            batch, max_new_tokens=max_new_tokens, seed=seed, fused=fused,
            prefix_cache=prefix_cache)[0]

    def generate_with_lengths(
            self, batch: Dict[str, jnp.ndarray], *,
            max_new_tokens: Optional[int] = None,
            seed: Optional[int] = None,
            fused: Optional[bool] = None,
            prefix_cache: Optional[PrefixCache] = None,
            drafts: Optional[Tuple[np.ndarray, np.ndarray]] = None,
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Generate and return (tokens (B, T_new), lengths (B,), ended (B,)).

        ``lengths`` counts each row's REAL generated tokens — up to and
        including its terminating EOS when ``ended`` is True, the full
        budget otherwise.  ``max_new_tokens=0`` is an explicit request for
        nothing: returns an empty (B, 0) block with zero-length rows and
        runs no device work at all.

        With ``prefix_cache``, ``batch["tokens"]`` holds only the suffix:
        prefill attends over the stored prefix KV and the whole call is
        byte-identical to generating from the ``[prefix | suffix]``
        concatenation (same capacity, same key schedule).

        With ``drafts`` — a ``(draft_ids (B, D) int32, draft_lens (B,))``
        pair of per-row predicted output tokens (the TWEAK path feeds the
        cached response here) — decode runs the speculative verify loop
        at ``cfg.spec_k`` tokens per forward (DESIGN.md §14).  Greedy +
        fused only; outputs are token-for-token identical to the plain
        call, just cheaper.  Rows whose draft is empty (len 0) decode
        plainly inside the same call.
        """
        # `is None`, not falsiness: an explicit max_new_tokens=0 must not
        # silently fall back to the config default.
        mnt = self.cfg.max_new_tokens if max_new_tokens is None else max_new_tokens
        if mnt < 0:
            raise ValueError(f"max_new_tokens must be >= 0, got {mnt}")
        b, s = batch["tokens"].shape
        if mnt == 0:
            return (np.zeros((b, 0), np.int32), np.zeros((b,), np.int32),
                    np.zeros((b,), bool))
        if seed is None:
            seed = next(self._auto_seed)
        use_fused = self.cfg.fused if fused is None else fused
        if drafts is not None:
            # Incoherent speculation requests fail loudly (satellite 2):
            # silently decoding plainly would fake the perf win.
            if not use_fused:
                raise ValueError(
                    "speculative decode requires the fused loop — the host "
                    "oracle is the plain differential baseline (fused=True)")
            if self.cfg.sampler.temperature > 0:
                raise ValueError(
                    "speculative decode is greedy-only (temperature 0): "
                    "lossless acceptance compares argmax choices")
            if not self.model.supports_spec_decode:
                raise NotImplementedError(
                    f"{self.model.cfg.name}: draft-verify decode "
                    f"unsupported for this architecture — drop the drafts")
            if self.cfg.spec_k > mnt:
                raise ValueError(
                    f"spec_k ({self.cfg.spec_k}) > max_new_tokens ({mnt}) "
                    f"for this call: shrink the block or raise the budget")
        draft_pack = None
        if drafts is not None:
            # Pack [draft_len | draft_ids] padded/clipped to exactly mnt
            # columns (jit buckets by (batch, mnt) like the plain fused
            # loop; a draft longer than the budget can never be consumed)
            # and ship it BEFORE the prefill dispatch: one transfer, on an
            # idle stream — two puts issued after the prefill stall behind
            # the in-flight compute and cost ~3x as much wall time.
            raw_ids, raw_lens = drafts
            raw_ids = np.asarray(raw_ids, np.int32)  # hostsync: ok drafts are host-resident cached-response ids
            pack = np.zeros((b, mnt + 1), np.int32)
            w = min(raw_ids.shape[1], mnt)
            pack[:, 1:1 + w] = raw_ids[:, :w]
            pack[:, 0] = np.minimum(np.asarray(raw_lens, np.int32), mnt)  # hostsync: ok drafts are host-resident cached-response ids
            draft_pack = jax.device_put(pack)
        if prefix_cache is not None:
            if b != prefix_cache.batch:
                raise ValueError(
                    f"prefix cache was built for batch {prefix_cache.batch}, "
                    f"got a batch of {b} rows — build one per batch bucket")
            capacity = prefix_cache.length + s + mnt + 1
            logits, caches = self._prefill_with_prefix(
                self.params, batch, capacity, prefix_cache.caches)
        else:
            capacity = s + mnt + 1
            if self.model.cfg.num_prefix_tokens:
                capacity += self.model.cfg.num_prefix_tokens
            logits, caches = self._prefill(self.params, batch, capacity)
        page_lease = None
        if self.cfg.paged:
            if not self.model.supports_paged_decode:
                raise NotImplementedError(
                    f"{self.model.cfg.name}: paged KV decode unsupported "
                    f"for this architecture — use dense decode")
            caches, page_lease = self._page_in(caches, b, capacity,
                                               prefix_cache)
        # device_put the seed explicitly: PRNGKey(python_int) would move
        # the scalar implicitly, which the transfer-guard harness forbids
        key = jax.random.PRNGKey(jax.device_put(np.uint32(seed)))
        try:
            if draft_pack is not None:
                toks, lengths, ended, prop, acc, steps = self._decode_fused_spec(
                    self.params, logits, caches, draft_pack,
                    mnt, self.cfg.spec_k)
                toks, lengths, ended, prop, acc, steps = jax.device_get(  # hostsync: ok the one per-call sync
                    (toks, lengths, ended, prop, acc, steps))
                self.last_spec_stats = {
                    "proposed": int(prop),    # hostsync: ok already host-side
                    "accepted": int(acc),     # hostsync: ok already host-side
                    "spec_steps": int(steps)  # hostsync: ok already host-side
                }
                for stat, inc in self.last_spec_stats.items():
                    self.spec_stats[stat] += inc
                return toks, lengths, ended
            if use_fused:
                toks, lengths, ended = self._decode_fused(
                    self.params, logits, caches, key, mnt)
                # THE per-generate-call device->host sync: the whole token
                # block + lengths + ended flags in one device_get
                return jax.device_get((toks, lengths, ended))  # hostsync: ok the one per-call sync
            return self._host_loop(logits, caches, key, mnt)
        finally:
            if page_lease is not None:
                self._pool.free_block_table(*page_lease)

    def _host_loop(self, logits, caches, key, mnt: int):  # hostsync: ok differential oracle syncs per step BY DESIGN
        """Host-driven per-step decode: the differential-testing oracle.

        One device dispatch + one host sync per token; same sampling, key
        schedule, done-masking, and outputs as the fused loop.
        """
        eos = self.cfg.eos_id
        tok = sample(key, logits, self.cfg.sampler)
        t = np.asarray(tok)
        b = t.shape[0]
        out = np.full((b, mnt), eos, np.int32)
        out[:, 0] = t
        done = t == eos
        lengths = np.where(done, 1, mnt).astype(np.int32)
        for i in range(1, mnt):
            if done.all():
                break
            key, sub = jax.random.split(key)
            tok, caches = self._step(self.params, tok, caches, sub)
            t = np.asarray(tok)
            t = np.where(done, eos, t)
            out[:, i] = t
            lengths[~done & (t == eos)] = i + 1
            done |= t == eos
        return out, lengths, done
