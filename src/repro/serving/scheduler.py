"""Continuous-batching request scheduler over TweakLLMEngine (DESIGN.md §6).

The engine exposes a synchronous, caller-batched ``handle_batch``; this
module turns it into a serving frontend: requests are *submitted*
individually with arrival timestamps, admitted through a bounded queue
(backpressure), coalesced into bucket-shaped serve batches, deduplicated
against identical in-flight queries, and dispatched when a batch bucket
fills or the oldest request's max-wait deadline expires.

Pipeline (DESIGN.md §6): queue -> coalesce -> dedup -> dispatch.

* **Dedup** — N concurrent copies of the same query text join one group;
  a dispatch sends one copy to the engine, so N copies of the same MISS
  trigger exactly ONE Big-LLM generation.  All N requests receive the
  response; scheduler stats count the N-1 extras as ``joined``.
* **Determinism** — time enters only through the injected ``Clock``; the
  scheduler never sleeps and never reads wall time itself.  Under
  ``SimClock`` an entire arrival trace replays deterministically
  (``replay_trace``), which is how the test suite proves scheduler
  semantics equivalent to sequential ``handle_batch`` calls.
* **Backpressure** — ``submit`` raises ``QueueFull`` once
  ``queue_capacity`` requests are pending; the caller sheds load.
* **Service model** — optionally, dispatches occupy the (single) engine
  for ``service_model(batch_size)`` simulated seconds; ``poll`` will not
  dispatch again before ``busy_until``, giving real queueing dynamics for
  the arrival-rate sweeps in ``benchmarks/bench_scheduler.py``.
* **Continuous mode** (``SchedulerConfig(continuous=True)``, DESIGN.md
  §11) — replaces the bucket barrier with ``slots`` persistent decode
  slots: a request dispatches the moment a slot frees and occupies it
  for ``service_model(slots)/slots`` seconds (its steady-state share of
  a full fused-decode step).  This is the request-level mirror of
  ``serving/continuous.DecodeSession`` splicing rows into the paged
  fused loop at step boundaries; with a deterministic engine the served
  responses and EngineStats are byte-identical to barrier mode
  (``tests/test_scheduler.py`` locks this), only the latency/throughput
  dynamics change.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Dict, Iterable, List, Optional, Protocol, Tuple

from .batcher import bucket_batch


# ------------------------------------------------------------------ time
class Clock(Protocol):
    def now(self) -> float: ...


class WallClock:
    """Real time, for interactive / production use."""

    def now(self) -> float:
        return time.monotonic()


class SimClock:
    """Deterministic, manually-advanced clock — the simulation substrate.

    Never goes backwards; tests and benches own time entirely, so traces
    replay bit-identically with zero sleeps.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)  # hostsync: ok host wall-clock, never a device value

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance by negative dt={dt}")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        self._t = max(self._t, float(t))  # hostsync: ok host wall-clock, never a device value
        return self._t


class QueueFull(RuntimeError):
    """Admission rejected: the bounded request queue is at capacity."""


# ------------------------------------------------------------- requests
@dataclasses.dataclass
class SchedulerConfig:
    max_wait: float = 0.05        # flush deadline for the oldest request (s)
    max_batch: int = 32           # unique queries per dispatch (snaps UP to
                                  # a BATCH_BUCKETS shape so full dispatches
                                  # hit an existing engine compile bucket)
    queue_capacity: int = 1024    # bounded admission queue (backpressure)
    dedup: bool = True            # coalesce identical in-flight texts
    max_new_tokens: int = 32
    # Continuous (slot-based) mode, DESIGN.md §11: instead of holding a
    # bucket open behind the max_wait barrier, a request is dispatched
    # the moment a decode slot frees — the request-level mirror of
    # ``DecodeSession``'s mid-flight join/leave.  ``slots`` is the
    # persistent batch width; each admitted request occupies one slot
    # for ``service_model(slots) / slots`` simulated seconds (its
    # steady-state share of a full fused-decode step), so the service
    # process matches the device reality: rows at different depths
    # decode together and one finishing does not stall the rest.
    continuous: bool = False
    slots: int = 8
    # ReplicaScheduler only: let an idle replica steal queued groups from
    # a backed-up one (DESIGN.md §12).  Ignored by the single-lane
    # Scheduler.
    steal: bool = True
    # Default per-request routing operating point (DESIGN.md §13); None
    # defers to the engine's RouterConfig.default_cost.  A request-level
    # ``submit(text, cost_threshold=...)`` overrides this.
    cost_threshold: Optional[float] = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        if self.max_wait < 0:
            raise ValueError("max_wait must be >= 0")
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        self.max_batch = bucket_batch(self.max_batch)


@dataclasses.dataclass
class Request:
    """One submitted query; filled in when its dispatch completes."""
    rid: int
    text: str
    arrival: float
    # routing operating point for this request (None = engine default);
    # part of the dedup key — two copies of one text at different
    # operating points may route differently, so they must not coalesce
    cost_threshold: Optional[float] = None
    response: Optional[str] = None
    meta: Optional[dict] = None
    joined: bool = False          # rode along on another request's dispatch
    finish: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.response is not None

    @property
    def latency(self) -> Optional[float]:
        return None if self.finish is None else self.finish - self.arrival


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    completed: int = 0
    rejected: int = 0             # QueueFull admissions
    joined: int = 0               # dedup-coalesced copies (N-1 per group)
    batches: int = 0              # engine dispatches
    dispatched: int = 0           # unique queries sent to the engine
    stolen: int = 0               # groups moved between replica lanes
    big_tokens: int = 0
    small_tokens: int = 0
    busy_time: float = 0.0        # modeled engine-busy simulated seconds
    latency_sum: float = 0.0
    latency_max: float = 0.0

    @property
    def mean_latency(self) -> float:
        return self.latency_sum / max(self.completed, 1)

    @property
    def mean_batch(self) -> float:
        return self.dispatched / max(self.batches, 1)


# ------------------------------------------------------------ scheduler
class Scheduler:
    """Event-driven continuous-batching frontend (DESIGN.md §6).

    Drive it with ``submit`` + ``poll``; ``poll`` dispatches every batch
    whose flush condition holds at ``clock.now()`` and returns the
    requests completed by this call.  ``next_wakeup`` tells a simulation
    driver the earliest time ``poll`` would act, so traces replay
    event-to-event with no busy waiting (see ``replay_trace``).
    """

    def __init__(self, engine, cfg: Optional[SchedulerConfig] = None, *,
                 clock: Optional[Clock] = None,
                 service_model: Optional[Callable[[int], float]] = None):
        self.engine = engine
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.clock = clock if clock is not None else WallClock()
        self.service_model = service_model
        self.stats = SchedulerStats()
        # FIFO of dedup groups; each group shares one query text and is
        # ordered by arrival (index 0 = primary, the rest join its dispatch)
        self._groups: List[List[Request]] = []
        self._by_text: Dict[Tuple[str, Optional[float]],
                            List[Request]] = {}
        # completions park here until a poll/flush RETURNS them: if one
        # dispatch in a multi-batch poll raises, earlier batches' completed
        # requests survive and are delivered by the next call
        self._completed: List[Request] = []
        self._n_pending = 0
        self._busy_until = 0.0
        # continuous mode: when each decode slot next frees (multiset —
        # slots hold no host state here, only their busy horizon; the
        # device-side identity lives in DecodeSession's leases)
        self._slot_free: List[float] = [0.0] * self.cfg.slots
        self._rid = itertools.count()

    # -------------------------------------------------------- admission
    @property
    def pending(self) -> int:
        return self._n_pending

    def submit(self, text: str,
               cost_threshold: Optional[float] = None) -> Request:
        """Admit one request at ``clock.now()``; raises QueueFull.

        ``cost_threshold`` picks this request's routing operating point
        (DESIGN.md §13); None falls back to ``cfg.cost_threshold``, then
        to the engine's default.
        """
        if self._n_pending >= self.cfg.queue_capacity:
            self.stats.rejected += 1
            raise QueueFull(
                f"request queue at capacity ({self.cfg.queue_capacity})")
        if cost_threshold is None:
            cost_threshold = self.cfg.cost_threshold
        req = Request(next(self._rid), text, self.clock.now(),
                      cost_threshold=cost_threshold)
        self.stats.submitted += 1
        key = (text, cost_threshold)
        group = self._by_text.get(key) if self.cfg.dedup else None
        if group is not None:
            group.append(req)
        else:
            group = [req]
            self._groups.append(group)
            if self.cfg.dedup:
                self._by_text[key] = group
        self._n_pending += 1
        return req

    # --------------------------------------------------------- dispatch
    def next_wakeup(self) -> Optional[float]:
        """Earliest time ``poll`` would dispatch; None when queue empty."""
        if not self._groups:
            return None
        t = self._groups[0][0].arrival
        if self.cfg.continuous:
            # no fill barrier: dispatch the moment a slot frees
            return max(t, min(self._slot_free))
        if len(self._groups) < self.cfg.max_batch:
            t += self.cfg.max_wait          # waiting to fill the bucket
        return max(t, self._busy_until)

    def poll(self) -> List[Request]:
        """Dispatch every due batch at ``clock.now()``; returns completions
        (including any parked by an earlier, partially-failed call)."""
        while True:
            w = self.next_wakeup()
            if w is None or w > self.clock.now():
                out, self._completed = self._completed, []
                return out
            self._dispatch()

    def flush(self) -> List[Request]:
        """Drain the queue now, ignoring deadlines (end-of-stream)."""
        while self._groups:
            self._dispatch()
        out, self._completed = self._completed, []
        return out

    def _dispatch(self) -> None:
        if self.cfg.continuous:
            self._dispatch_continuous()
            return
        take = min(len(self._groups), self.cfg.max_batch)
        groups = self._groups[:take]
        result = self._serve(groups)
        start = max(self.clock.now(), self._busy_until)
        service = self.service_model(take) if self.service_model else 0.0
        finish = start + service
        self._busy_until = finish
        self.stats.busy_time += service
        self._complete(groups, result, finish)

    def _dispatch_continuous(self) -> None:
        """Slot-based dispatch: the cohort is whatever fits the slots that
        are free RIGHT NOW (no fill barrier) — the request-level analogue
        of ``DecodeSession.admit`` splicing rows in at a step boundary."""
        start = max(self.clock.now(), min(self._slot_free))
        free = [i for i, t in enumerate(self._slot_free) if t <= start]
        take = min(len(self._groups), len(free), self.cfg.max_batch)
        groups = self._groups[:take]
        result = self._serve(groups)
        # each request holds one slot for its steady-state share of a
        # full-slot fused decode: finishing frees ONLY that slot
        service = (self.service_model(self.cfg.slots) / self.cfg.slots
                   if self.service_model else 0.0)
        finish = start + service
        for i in free[:take]:
            self._slot_free[i] = finish
        self.stats.busy_time += service * take
        self._complete(groups, result, finish)

    def _serve(self, groups):
        # engine first, queue mutation after: if the engine raises, every
        # request stays pending (and countable) for a retry or flush
        texts = [g[0].text for g in groups]
        costs = [g[0].cost_threshold for g in groups]
        # only surface the kwarg when an operating point was actually set:
        # cost-oblivious engines (baselines, test doubles) keep working
        kw = ({"cost_thresholds": costs}
              if any(c is not None for c in costs) else {})
        result = self.engine.handle_batch_result(
            texts, max_new_tokens=self.cfg.max_new_tokens, **kw)
        del self._groups[:len(groups)]
        if self.cfg.dedup:
            for g in groups:
                self._by_text.pop((g[0].text, g[0].cost_threshold), None)
        return result

    def _complete(self, groups, result, finish: float) -> None:
        self.stats.batches += 1
        self.stats.dispatched += len(groups)
        self.stats.big_tokens += result.big_tokens
        self.stats.small_tokens += result.small_tokens
        for group, resp, meta in zip(groups, result.responses, result.meta):
            for j, req in enumerate(group):
                req.response = resp
                req.meta = dict(meta)
                req.joined = j > 0
                req.finish = finish
                self.stats.completed += 1
                self.stats.joined += int(j > 0)
                lat = finish - req.arrival
                self.stats.latency_sum += lat
                self.stats.latency_max = max(self.stats.latency_max, lat)
                self._completed.append(req)
        self._n_pending -= sum(len(g) for g in groups)


# ------------------------------------------------------------ replicas
@dataclasses.dataclass
class _Lane:
    """One replica's dispatch state: its queue and busy horizons.

    Mirrors the single-lane Scheduler's fields — ``groups`` is the FIFO of
    dedup groups assigned to this replica, ``busy_until`` the barrier-mode
    horizon, ``slot_free`` the continuous-mode per-slot horizons (the PR 7
    slot accounting, now PER REPLICA: each replica owns one DecodeSession's
    worth of persistent decode slots).
    """
    engine: object
    groups: List[List[Request]] = dataclasses.field(default_factory=list)
    busy_until: float = 0.0
    slot_free: List[float] = dataclasses.field(default_factory=list)
    dispatched: int = 0
    batches: int = 0
    stolen_in: int = 0


class ReplicaScheduler:
    """Replica-aware frontend: N engines, one submit surface (DESIGN.md §12).

    Same ``submit`` / ``poll`` / ``flush`` / ``next_wakeup`` protocol as
    :class:`Scheduler` (``replay_trace`` drives either), with three
    replica-level mechanisms:

    * **Least-loaded dispatch** — a new group lands on the lane with the
      shortest queue, ties broken by the earlier free horizon, then lane
      index (deterministic under SimClock).
    * **Global dedup** — the dedup map spans lanes: N concurrent copies of
      one text join one group on ONE lane, so the whole fleet still runs
      exactly one generation per unique in-flight query.  With a shared
      cache bank the single MISS commit then serves every replica.
    * **Work stealing** — at each poll, a replica that is idle with an
      empty queue takes the newest half of the backlog a busy lane cannot
      dispatch right now (``cfg.steal``).  Least-loaded admission keeps
      queues balanced in steady state; stealing is the safety net when
      they drift (a replica stalls, heterogeneous service times).

    Backpressure (``queue_capacity``) and ``stats`` are fleet-global;
    per-lane counters live on ``lanes[i]``.
    """

    def __init__(self, engines, cfg: Optional[SchedulerConfig] = None, *,
                 clock: Optional[Clock] = None,
                 service_model: Optional[Callable[[int], float]] = None):
        if not engines:
            raise ValueError("ReplicaScheduler needs at least one engine")
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.clock = clock if clock is not None else WallClock()
        self.service_model = service_model
        self.stats = SchedulerStats()
        self.lanes = [_Lane(engine=e, slot_free=[0.0] * self.cfg.slots)
                      for e in engines]
        self._by_text: Dict[Tuple[str, Optional[float]],
                            List[Request]] = {}
        self._completed: List[Request] = []
        self._n_pending = 0
        self._rid = itertools.count()

    @property
    def engines(self) -> List[object]:
        return [lane.engine for lane in self.lanes]

    @property
    def pending(self) -> int:
        return self._n_pending

    # -------------------------------------------------------- admission
    def _free_at(self, lane: _Lane) -> float:
        return min(lane.slot_free) if self.cfg.continuous else lane.busy_until

    def submit(self, text: str,
               cost_threshold: Optional[float] = None) -> Request:
        """Admit one request at ``clock.now()``; raises QueueFull."""
        if self._n_pending >= self.cfg.queue_capacity:
            self.stats.rejected += 1
            raise QueueFull(
                f"request queue at capacity ({self.cfg.queue_capacity})")
        if cost_threshold is None:
            cost_threshold = self.cfg.cost_threshold
        req = Request(next(self._rid), text, self.clock.now(),
                      cost_threshold=cost_threshold)
        self.stats.submitted += 1
        key = (text, cost_threshold)
        group = self._by_text.get(key) if self.cfg.dedup else None
        if group is not None:
            group.append(req)           # joins its group's lane, wherever
        else:
            group = [req]
            lane = min(self.lanes,
                       key=lambda l: (len(l.groups), self._free_at(l)))
            lane.groups.append(group)
            if self.cfg.dedup:
                self._by_text[key] = group
        self._n_pending += 1
        return req

    # --------------------------------------------------------- dispatch
    def _lane_wakeup(self, lane: _Lane) -> Optional[float]:
        if not lane.groups:
            return None
        t = lane.groups[0][0].arrival
        if self.cfg.continuous:
            return max(t, min(lane.slot_free))
        if len(lane.groups) < self.cfg.max_batch:
            t += self.cfg.max_wait
        return max(t, lane.busy_until)

    def next_wakeup(self) -> Optional[float]:
        """Earliest time any lane would dispatch; None when all idle."""
        wakeups = [w for w in (self._lane_wakeup(lane) for lane in self.lanes)
                   if w is not None]
        return min(wakeups) if wakeups else None

    def _steal(self, now: float) -> None:
        """Rebalance: idle-empty lanes take backlog busy lanes can't serve.

        A donor's *surplus* is whatever its queue holds beyond what it can
        dispatch at ``now`` (nothing while busy; one batch / its free slots
        when free).  The thief takes the newest ceil(surplus/2) groups —
        the donor keeps its oldest, deadline-closest work.
        """
        if not self.cfg.steal or len(self.lanes) < 2:
            return
        for thief in self.lanes:
            if thief.groups or self._free_at(thief) > now:
                continue
            donor = max(self.lanes, key=lambda l: len(l.groups))
            if donor is thief:
                continue
            surplus = len(donor.groups)
            if self._free_at(donor) <= now:
                if self.cfg.continuous:
                    cap = sum(t <= now for t in donor.slot_free)
                else:
                    cap = self.cfg.max_batch
                surplus -= min(cap, self.cfg.max_batch)
            if surplus <= 0:
                continue
            take = surplus - surplus // 2
            moved = donor.groups[-take:]
            del donor.groups[-take:]
            # dedup-map entries follow their group objects; only lane
            # ownership moves
            thief.groups.extend(moved)
            thief.stolen_in += len(moved)
            self.stats.stolen += len(moved)

    def poll(self) -> List[Request]:
        """Dispatch every due lane at ``clock.now()`` (earliest-wakeup
        first); returns completions parked so far."""
        while True:
            now = self.clock.now()
            self._steal(now)
            due = [(w, i) for i, lane in enumerate(self.lanes)
                   if (w := self._lane_wakeup(lane)) is not None and w <= now]
            if not due:
                out, self._completed = self._completed, []
                return out
            self._dispatch(self.lanes[min(due)[1]])

    def flush(self) -> List[Request]:
        """Drain every lane now, ignoring deadlines (end-of-stream)."""
        while True:
            served = False
            for lane in self.lanes:
                if lane.groups:
                    self._dispatch(lane)
                    served = True
            if not served:
                break
        out, self._completed = self._completed, []
        return out

    def _dispatch(self, lane: _Lane) -> None:
        if self.cfg.continuous:
            start = max(self.clock.now(), min(lane.slot_free))
            free = [i for i, t in enumerate(lane.slot_free) if t <= start]
            take = min(len(lane.groups), len(free), self.cfg.max_batch)
            groups = lane.groups[:take]
            result = self._serve(lane, groups)
            service = (self.service_model(self.cfg.slots) / self.cfg.slots
                       if self.service_model else 0.0)
            finish = start + service
            for i in free[:take]:
                lane.slot_free[i] = finish
            self.stats.busy_time += service * take
        else:
            take = min(len(lane.groups), self.cfg.max_batch)
            groups = lane.groups[:take]
            result = self._serve(lane, groups)
            start = max(self.clock.now(), lane.busy_until)
            service = self.service_model(take) if self.service_model else 0.0
            finish = start + service
            lane.busy_until = finish
            self.stats.busy_time += service
        lane.dispatched += len(groups)
        lane.batches += 1
        self._complete(groups, result, finish)

    def _serve(self, lane: _Lane, groups):
        # engine first, queue mutation after — same crash discipline as
        # the single-lane Scheduler
        texts = [g[0].text for g in groups]
        costs = [g[0].cost_threshold for g in groups]
        kw = ({"cost_thresholds": costs}
              if any(c is not None for c in costs) else {})
        result = lane.engine.handle_batch_result(
            texts, max_new_tokens=self.cfg.max_new_tokens, **kw)
        del lane.groups[:len(groups)]
        if self.cfg.dedup:
            for g in groups:
                self._by_text.pop((g[0].text, g[0].cost_threshold), None)
        return result

    def _complete(self, groups, result, finish: float) -> None:
        self.stats.batches += 1
        self.stats.dispatched += len(groups)
        self.stats.big_tokens += result.big_tokens
        self.stats.small_tokens += result.small_tokens
        for group, resp, meta in zip(groups, result.responses, result.meta):
            for j, req in enumerate(group):
                req.response = resp
                req.meta = dict(meta)
                req.joined = j > 0
                req.finish = finish
                self.stats.completed += 1
                self.stats.joined += int(j > 0)
                lat = finish - req.arrival
                self.stats.latency_sum += lat
                self.stats.latency_max = max(self.stats.latency_max, lat)
                self._completed.append(req)
        self._n_pending -= sum(len(g) for g in groups)


# ------------------------------------------------------------- replay
def replay_trace(sched: Scheduler, trace: Iterable[Tuple[float, str]], *,
                 drain: bool = True) -> List[Request]:
    """Replay (arrival_time, text) events through a SimClock'd scheduler.

    Advances the scheduler's clock event-to-event (deadline fires between
    arrivals are honored in order), submits each arrival, and finally
    drains the queue.  Rejected (QueueFull) arrivals are shed and counted
    in ``sched.stats.rejected``.  Returns completed requests; sort by
    ``rid`` to recover submission order.
    """
    clock = sched.clock
    if not isinstance(clock, SimClock):
        raise TypeError("replay_trace requires a Scheduler on a SimClock")
    done: List[Request] = []
    for t, text in trace:
        while True:
            w = sched.next_wakeup()
            if w is None or w > t:
                break
            clock.advance_to(w)
            done.extend(sched.poll())
        clock.advance_to(t)
        try:
            sched.submit(text)
        except QueueFull:
            continue
        done.extend(sched.poll())
    while drain:
        w = sched.next_wakeup()
        if w is None:
            break
        clock.advance_to(w)
        done.extend(sched.poll())
    return done


def poisson_trace(texts: List[str], rate: float, *,
                  seed: int = 0) -> List[Tuple[float, str]]:
    """Poisson-process arrival trace over ``texts`` at ``rate`` req/s."""
    import numpy as np
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(texts)).tolist()
    t, out = 0.0, []
    for g, text in zip(gaps, texts):
        t += g
        out.append((t, text))
    return out
