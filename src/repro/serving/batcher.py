"""Request batching: pad-to-bucket grouping so jit re-compiles are bounded.

The TweakLLM engine splits each incoming batch into MISS / TWEAK / EXACT
sub-batches with different prompt shapes; the batcher pads each sub-batch to
the nearest (batch, length) bucket so the number of compiled specializations
stays small under production traffic.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64)
LEN_BUCKETS = (16, 32, 64, 128, 256, 512, 1024)


def bucket_batch(n: int) -> int:
    for b in BATCH_BUCKETS:
        if n <= b:
            return b
    return ((n + BATCH_BUCKETS[-1] - 1) // BATCH_BUCKETS[-1]) * BATCH_BUCKETS[-1]


def bucket_len(n: int) -> int:
    for b in LEN_BUCKETS:
        if n <= b:
            return b
    return ((n + LEN_BUCKETS[-1] - 1) // LEN_BUCKETS[-1]) * LEN_BUCKETS[-1]


def floor_len_bucket(n: int) -> int:
    """Largest length bucket <= n (n itself below the smallest bucket).

    Clamping an encode budget to this guarantees ``pad_to_buckets`` cannot
    round the row length back ABOVE the budget — buckets are fixed points
    of ``bucket_len``.  Callers with n below the smallest bucket must
    bound-check ``bucket_len(n)`` themselves.
    """
    if n < LEN_BUCKETS[0]:
        return n
    if n >= LEN_BUCKETS[-1]:
        return (n // LEN_BUCKETS[-1]) * LEN_BUCKETS[-1]
    best = LEN_BUCKETS[0]
    for b in LEN_BUCKETS:
        if b <= n:
            best = b
    return best


def pad_to_buckets(tokens: np.ndarray, mask: np.ndarray,
                   pad_id: int = 0) -> Tuple[np.ndarray, np.ndarray, int]:
    """Pad (B, L) token/mask arrays up to bucket sizes.  Returns real B."""
    b, l = tokens.shape
    bb, lb = bucket_batch(b), bucket_len(l)
    out_t = np.full((bb, lb), pad_id, tokens.dtype)
    out_m = np.zeros((bb, lb), mask.dtype)
    out_t[:b, :l] = tokens
    out_m[:b, :l] = mask
    if bb > b:  # pad rows must still be valid model input: repeat row 0
        out_t[b:] = out_t[0]
        out_m[b:] = out_m[0]
    return out_t, out_m, b
