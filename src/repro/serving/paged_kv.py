"""Paged KV pool: fixed-size pages, free-list allocator, refcounted sharing.

Dense serving KV (PR 4/5) allocates one ``(B, capacity)`` cache per batch
and throws it away when the batch drains — the longest row sizes every
row, and a shared tweak prefix is re-broadcast into every batch's cache.
This module replaces that with a device-resident page pool (DESIGN.md
§11):

* **Storage** — for every attention layer, K/V live in ``(num_pages + 1,
  page_size, hk, dh)`` page arrays (scan-stacked layers carry their
  leading ``periods`` dim).  A sequence owns a *block table*: the page
  ids backing its logical slots ``[0, capacity)`` in order.  The last
  page array row is the TRASH page — writes by evicted/empty rows land
  there, so a freed page can be re-issued without ever being stomped.
* **Allocator** — a host-side free list + per-page refcounts.  Pages are
  device-resident; the *bookkeeping* is plain numpy on host values (page
  ids never originate from device arrays, so allocation costs zero
  device syncs).  Exhaustion raises ``PagePoolExhausted`` BEFORE any
  device state is touched — never corrupts.
* **Pinned prefixes** — the shared tweak prefix (DESIGN.md §9) is written
  into pages ONCE and pinned; every TWEAK row's block table points at
  those pages (refcount += users).  Only whole pages are shared; the
  prefix remainder rides in each row's first private page.
* **Bitwise contract** — ``decode_attention`` gathers pages through the
  block table back into logical-slot order and SLICES to the exact dense
  capacity, then runs the identical attend.  The gather is pure data
  movement, so paged decode is bitwise-identical to the dense path
  (differential-tested in ``tests/test_paged_kv.py``).

The jitted entry points (``pack_caches``, ``write_pinned``) are the
allocator's device half: they scatter prefilled dense KV into pages.
Both are declared in ``analysis/registry.py`` and contract-checked.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PagePoolExhausted(RuntimeError):
    """Allocation rejected: not enough free pages.  Pool state unchanged."""


# ------------------------------------------------------------ tree utils

def _is_dense_leaf(x) -> bool:
    return isinstance(x, dict) and {"k", "v", "pos", "slot_pos"} <= set(x)


def _is_paged_leaf(x) -> bool:
    return isinstance(x, dict) and "kp" in x


def map_kv_leaves(tree, fn):
    """Map ``fn`` over every KV-cache leaf dict in a caches pytree.

    Walks the transformer caches structure (``{"scan": (...), "rem":
    (...), "pos"}``); non-KV leaves (the top-level pos counter, SSM /
    RG-LRU states) pass through untouched — the paged gate in
    ``Model.supports_paged_decode`` guarantees none are present.
    """
    if _is_dense_leaf(tree) or _is_paged_leaf(tree):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: map_kv_leaves(v, fn) for k, v in tree.items()}
    if isinstance(tree, (tuple, list)):
        return type(tree)(map_kv_leaves(v, fn) for v in tree)
    return tree


def kv_leaves(tree) -> List[dict]:
    """Collect the KV leaf dicts of a caches pytree, in tree order."""
    out: List[dict] = []

    def grab(leaf):
        out.append(leaf)
        return leaf

    map_kv_leaves(tree, grab)
    return out


# ------------------------------------------------------------- jitted ops

def _pack_one(kp, vp, k, v, pos, slot_pos, tbl, writable):
    """Scatter one layer's dense KV (B, cap, hk, dh) into its pages.

    ``tbl`` (B, npg) maps logical page j of row b to a physical page;
    ``writable`` masks out pinned (shared) and trash entries — their
    writes are redirected to the TRASH page, so shared prefix pages are
    never re-written with the per-row copies (the values would be
    identical; redirecting keeps them read-only by construction).
    """
    b, cap = k.shape[0], k.shape[1]
    page = kp.shape[1]
    npg = tbl.shape[1]
    trash = kp.shape[0] - 1
    pad = npg * page - cap
    kpg = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(
        b, npg, page, *k.shape[2:])
    vpg = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).reshape(
        b, npg, page, *v.shape[2:])
    tbl_w = jnp.where(writable, tbl, trash)
    kp = kp.at[tbl_w].set(kpg.astype(kp.dtype))
    vp = vp.at[tbl_w].set(vpg.astype(vp.dtype))
    pos_b = jnp.broadcast_to(pos, (b,)).astype(jnp.int32)
    return {"kp": kp, "vp": vp, "block_tbl": tbl, "pos": pos_b,
            "slot_pos": slot_pos}


def _stack_depth(leaf: dict) -> int:
    key = "k" if "k" in leaf else "kp"
    return leaf[key].ndim - 4


@functools.partial(jax.jit, donate_argnums=(0,))
def pack_caches(pool_tree, dense_caches, tbl, writable):
    """Scatter a dense prefill's caches into pool pages -> paged caches.

    ``pool_tree`` mirrors the caches container structure with ``{"kp",
    "vp"}`` leaves and is DONATED: page writes happen in place.  The
    returned pytree swaps each dense KV leaf for its paged form
    ``{"kp", "vp", "block_tbl", "pos" (B,), "slot_pos"}`` — structure-
    and shape-stable under ``decode_step``, so the PR 4 fused loop
    carries it unchanged.  Scan-stacked leaves broadcast the block table
    across their leading periods dim (same page ids in every layer; each
    layer has its own storage array).
    """
    pools = kv_leaves(pool_tree)
    it = iter(pools)

    def pack(leaf):
        pool = next(it)
        depth = _stack_depth(leaf)
        fn = _pack_one
        for _ in range(depth):
            fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, 0, 0, None, None))
        out = fn(pool["kp"], pool["vp"], leaf["k"], leaf["v"], leaf["pos"],
                 leaf["slot_pos"], tbl, writable)
        if depth:
            lead = leaf["k"].shape[:depth]
            out["block_tbl"] = jnp.broadcast_to(tbl, lead + tbl.shape)
        return out

    return map_kv_leaves(dense_caches, pack)


def _write_pin_one(kp, vp, k, v, pin_ids, page):
    """Write row 0's first ``n_pin`` full pages of prefix KV into pages."""
    n_pin = pin_ids.shape[0]
    kpg = k[0, :n_pin * page].reshape(n_pin, page, *k.shape[2:])
    vpg = v[0, :n_pin * page].reshape(n_pin, page, *v.shape[2:])
    kp = kp.at[pin_ids].set(kpg.astype(kp.dtype))
    vp = vp.at[pin_ids].set(vpg.astype(vp.dtype))
    return {"kp": kp, "vp": vp}


@functools.partial(jax.jit, donate_argnums=(0,))
def write_pinned(pool_tree, prefix_caches, pin_ids):
    """Write a shared prefix's KV into pinned pages, once (DESIGN.md §11).

    ``prefix_caches`` is the ``PrefixCache.caches`` pytree (every row
    identical by construction); row 0's K/V fill ``pin_ids``.  Only the
    full pages (``len(pin_ids) * page_size`` tokens) are pinned — the
    remainder is packed per-row by ``pack_caches``.
    """
    prefixes = kv_leaves(prefix_caches)
    it = iter(prefixes)

    def write(leaf):
        pre = next(it)
        page = leaf["kp"].shape[-3]
        depth = _stack_depth(leaf)
        fn = functools.partial(_write_pin_one, page=page)
        for _ in range(depth):
            fn = jax.vmap(fn, in_axes=(0, 0, 0, 0, None))
        return fn(leaf["kp"], leaf["vp"], pre["k"], pre["v"], pin_ids)

    return map_kv_leaves(pool_tree, write)


def row_pos_caches(caches, batch: int):
    """Broadcast every cache position to per-row (B,) (DESIGN.md §14).

    Block (speculative) decode advances rows by different amounts per
    step — after the first divergence a scalar ``pos`` cannot represent
    the batch.  A fresh prefill's dense leaves carry scalar ``pos``;
    this lifts them (and the top-level counter) to ``(B,)`` so
    ``decode_attention_block`` / ``rewind_kv`` can treat dense and paged
    caches uniformly.  Paged leaves are already per-row: no-op there.
    """
    def fix(leaf):
        depth = _stack_depth(leaf)      # scan-stacked leading dims
        if leaf["pos"].ndim > depth:    # already per-row (paged, or re-call)
            return leaf
        out = dict(leaf)
        out["pos"] = jnp.broadcast_to(
            leaf["pos"][..., None] if leaf["pos"].ndim else leaf["pos"],
            leaf["pos"].shape + (batch,)).astype(jnp.int32)
        return out

    out = map_kv_leaves(caches, fix)
    out["pos"] = jnp.broadcast_to(caches["pos"], (batch,)).astype(jnp.int32)
    return out


def rewind_kv(caches, rollback):
    """Rewind per-row positions by ``rollback`` (B,) ints >= 0 (§14).

    The speculative verify step writes k positions optimistically; when a
    row accepts only ``a`` of them the trailing ``k - a`` K/V entries are
    stale.  Rewinding moves ``pos`` back and marks the abandoned slots
    invalid (``slot_pos = -1``), which the decode attend masks out — the
    stale K/V values are hidden until the next write overwrites them.
    Works on dense and paged leaves alike; caches must already be in
    per-row-``pos`` form (``row_pos_caches``).
    """
    def rew(leaf):
        out = dict(leaf)
        pos = leaf["pos"] - jnp.broadcast_to(rollback, leaf["pos"].shape)
        sp = leaf["slot_pos"]
        c = jax.lax.broadcasted_iota(jnp.int32, sp.shape, sp.ndim - 1)
        out["pos"] = pos
        out["slot_pos"] = jnp.where(c >= pos[..., None], -1, sp)
        return out

    out = map_kv_leaves(caches, rew)
    out["pos"] = caches["pos"] - rollback
    return out


def extract_pool(paged_caches):
    """Recover the pool storage pytree from packed/stepped paged caches."""
    return map_kv_leaves(
        paged_caches, lambda leaf: {"kp": leaf["kp"], "vp": leaf["vp"]})


# ---------------------------------------------------------------- pool

@dataclasses.dataclass(frozen=True)
class PagePoolConfig:
    page_size: int = 16
    num_pages: int = 256

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        if self.num_pages < 1:
            raise ValueError("num_pages must be >= 1")


@dataclasses.dataclass
class PinnedPrefix:
    """One pinned shared-prefix page set (the PR 5 tweak prefix)."""
    key: Tuple[int, ...]          # the prefix token ids
    ids: np.ndarray               # (n_pin,) page ids, refcounted
    tokens: int                   # tokens covered = n_pin * page_size


class PagePool:
    """Device-resident KV page pool with a host-side free-list allocator.

    One pool serves one model: page id ``p`` names page ``p`` in EVERY
    layer's storage array.  Allocation/free/refcounting run on host ints
    (zero device syncs); the device half (scattering KV into pages) is
    the jitted ``pack_caches`` / ``write_pinned`` ops, which DONATE the
    storage so writes are in place.  ``storage`` always refers to the
    latest arrays — callers must thread returned pytrees back via
    ``adopt`` (the pack ops invalidate the donated input).
    """

    def __init__(self, model, cfg: PagePoolConfig):
        self.cfg = cfg
        self.model = model
        template = model.init_caches(1, cfg.page_size)
        n = cfg.num_pages + 1  # +1: the TRASH page (never allocated)

        def make(leaf):
            shape = leaf["k"].shape       # (stack..., 1, page, hk, dh)
            depth = leaf["k"].ndim - 4
            pshape = shape[:depth] + (n, cfg.page_size) + shape[depth + 2:]
            return {"kp": jnp.zeros(pshape, leaf["k"].dtype),
                    "vp": jnp.zeros(pshape, leaf["v"].dtype)}

        self.storage = map_kv_leaves(template, make)
        self._refcount = np.zeros(cfg.num_pages, np.int32)
        self._free: List[int] = list(range(cfg.num_pages - 1, -1, -1))
        self._pins: Dict[Tuple[int, ...], PinnedPrefix] = {}

    # ----------------------------------------------------- host allocator
    @property
    def trash_page(self) -> int:
        return self.cfg.num_pages

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.cfg.num_pages - len(self._free)

    @property
    def pinned_pages(self) -> int:
        return sum(len(p.ids) for p in self._pins.values())

    def pages_per_seq(self, capacity: int) -> int:
        return -(-capacity // self.cfg.page_size)

    def alloc(self, n: int) -> np.ndarray:  # hostsync: ok free-list bookkeeping, pure host numpy
        """Take ``n`` free pages (refcount 1 each); raises, never corrupts."""
        if n > len(self._free):
            raise PagePoolExhausted(
                f"need {n} pages, only {len(self._free)} of "
                f"{self.cfg.num_pages} free")
        ids = np.asarray([self._free.pop() for _ in range(n)], np.int32)
        self._refcount[ids] = 1
        return ids

    def incref(self, ids: np.ndarray, count: int = 1) -> None:  # hostsync: ok refcount bookkeeping, pure host numpy
        np.add.at(self._refcount, np.asarray(ids, np.int64), count)

    def decref(self, ids) -> None:  # hostsync: ok refcount bookkeeping, pure host numpy
        """Drop one reference per id; pages return to the free list at 0."""
        for p in np.asarray(ids, np.int64).ravel():
            c = int(self._refcount[p]) - 1
            if c < 0:
                raise RuntimeError(f"page {p} over-freed")
            self._refcount[p] = c
            if c == 0:
                self._free.append(int(p))

    def adopt(self, paged_caches) -> None:
        """Re-point ``storage`` at the arrays inside a packed/stepped tree."""
        self.storage = extract_pool(paged_caches)

    # ------------------------------------------------------ row tables
    def alloc_block_table(self, batch: int, capacity: int,  # hostsync: ok free-list bookkeeping, pure host numpy
                          pin: Optional[PinnedPrefix] = None,
                          ) -> Tuple[np.ndarray, np.ndarray]:
        """(block_tbl (B, npg) int32, writable (B, npg) bool) for a batch.

        With ``pin``, the leading pinned pages are shared by every row
        (refcount += batch) and marked read-only; private pages cover the
        rest of ``capacity``.  All-or-nothing: exhaustion leaves
        refcounts untouched.
        """
        npg = self.pages_per_seq(capacity)
        n_pin = 0 if pin is None else len(pin.ids)
        if n_pin > npg:
            raise ValueError(
                f"pinned prefix ({n_pin} pages) exceeds capacity ({npg})")
        private = npg - n_pin
        if batch * private > len(self._free):
            raise PagePoolExhausted(
                f"need {batch * private} pages, only {len(self._free)} of "
                f"{self.cfg.num_pages} free")
        rows = self.alloc(batch * private).reshape(batch, private)
        writable = np.zeros((batch, npg), bool)
        writable[:, n_pin:] = True
        if pin is None:
            return rows, writable
        self.incref(pin.ids, count=batch)
        tbl = np.concatenate(
            [np.broadcast_to(pin.ids, (batch, n_pin)), rows], axis=1)
        return np.ascontiguousarray(tbl, dtype=np.int32), writable

    def free_block_table(self, tbl: np.ndarray,  # hostsync: ok free-list bookkeeping, pure host numpy
                         writable: np.ndarray) -> None:
        """Release a batch's pages: private pages free, pinned decref."""
        self.decref(np.asarray(tbl)[np.asarray(writable)])
        pinned = np.asarray(tbl)[~np.asarray(writable)]
        pinned = pinned[pinned != self.trash_page]
        self.decref(pinned)

    # ---------------------------------------------------- pinned prefixes
    def ensure_pinned(self, prefix_cache) -> Optional[PinnedPrefix]:
        """Pin a ``PrefixCache``'s full pages once; cached by token ids.

        Returns None when the prefix is shorter than one page (nothing
        shareable — the whole prefix rides in each row's private pages).
        """
        key = tuple(prefix_cache.token_ids)
        hit = self._pins.get(key)
        if hit is not None:
            return hit
        n_pin = prefix_cache.length // self.cfg.page_size
        if n_pin == 0:
            return None
        ids = self.alloc(n_pin)
        try:
            self.storage = write_pinned(
                self.storage, prefix_cache.caches,
                jax.device_put(ids))
        except Exception:
            self.decref(ids)
            raise
        pin = PinnedPrefix(key=key, ids=ids,
                           tokens=n_pin * self.cfg.page_size)
        self._pins[key] = pin
        return pin

    def unpin(self, key: Tuple[int, ...]) -> None:
        pin = self._pins.pop(tuple(key), None)
        if pin is not None:
            self.decref(pin.ids)

    def refcounts(self) -> np.ndarray:
        return self._refcount.copy()
