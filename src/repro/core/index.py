"""IVF-style clustered index over the semantic-cache embedding bank.

The flat cache lookup is a brute-force O(capacity * D) cosine scan; this
module makes lookup cost grow with *probed clusters* instead of capacity
(DESIGN.md §7) — the TPU-native analogue of a Milvus/FAISS IVF index:

* **Centroids** (nclusters, D): spherical k-means over the bank, trained
  host-side in :func:`build_index` (maintenance path, not the hot loop).
* **Member table** (nclusters, bucket): a PADDED, fixed-shape list of the
  bank rows assigned to each cluster, so the two-stage lookup
  (query -> top-``nprobe`` centroids -> scan only member rows) jits once
  per batch bucket and never sees a data-dependent shape.
* **Back-pointers** ``assign``/``slot_pos`` (capacity,): the cluster and
  member-table position each bank slot is CURRENTLY filed under.  Member
  lists are append-only between rebuilds; an overwritten slot's old entry
  goes stale *lazily* — a member entry (c, p) = s is live iff
  ``valid[s] & assign[s] == c & slot_pos[s] == p``.  That keeps insert a
  cheap fixed-shape append (no swap-remove scatter chains) while
  guaranteeing every valid slot has EXACTLY ONE live entry, which is what
  makes lookup at ``nprobe == nclusters`` score- and decision-identical
  to the flat scan.
* **Rebalance**: inserts land in the nearest centroid's list, falling
  back to the least-loaded cluster when that list is full (total table
  slack is ``ivf_slack`` x capacity, so space exists while the
  equivalence invariant holds).  When even the fallback is full the entry
  overwrites the fallback's last member slot and raises ``ivf_overflow``
  — the signal (with the ``ivf_pending`` write counter) that
  :func:`maybe_reindex` uses to trigger a host-side k-means rebuild.

All lookup/insert entry points are jit-safe and operate on the cache
state dict from ``repro.core.cache`` (ivf arrays ride inside it, so the
engine's donated-buffer calls need no API change).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cosine_topk.ops import cosine_topk_gather

IVF_KEYS = ("ivf_centroids", "ivf_members", "ivf_count", "ivf_assign",
            "ivf_pos", "ivf_pending", "ivf_overflow")

# member-table slack: total member slots = slack * capacity, so the
# least-loaded fallback always has space until churn accumulates
# slack*capacity stale appends (a rebuild fires long before that).
# Kept small on purpose — the probe scans nprobe * bucket rows, so every
# unit of slack is paid for on every lookup.
SLACK = 2


@dataclasses.dataclass(frozen=True)
class IVFParams:
    nclusters: int
    bucket: int
    nprobe: int
    reindex_every: int


def resolve(cfg) -> IVFParams:
    """Resolve the auto (0) CacheConfig knobs into concrete table shapes.

    ``bucket`` is floored at ``ceil(capacity / nclusters)`` (and at topk)
    whatever the user asked for: the table must be able to hold every
    valid slot or the flat-scan equivalence (and build_index's spill)
    would have no space to preserve it.

    Auto ``nclusters`` targets a ~2k-row shortlist at the default nprobe
    (capacity/128 clusters -> bucket ~256 at slack 2): measured on CPU,
    the gathered-shortlist scan falls off a locality cliff past ~4k rows,
    and k-means cost caps the cluster count at 2048.
    """
    nclusters = cfg.nclusters or min(max(64, cfg.capacity // 128), 2048)
    nclusters = min(nclusters, cfg.capacity)
    bucket = cfg.ivf_bucket or -(-cfg.capacity // nclusters) * SLACK
    bucket = max(bucket, -(-cfg.capacity // nclusters),
                 min(cfg.topk, cfg.capacity))
    bucket = min(bucket, cfg.capacity)
    nprobe = min(cfg.nprobe or 8, nclusters)
    reindex_every = cfg.reindex_every or max(64, cfg.capacity // 4)
    return IVFParams(nclusters, bucket, nprobe, reindex_every)


def init_ivf(cfg):
    p = resolve(cfg)
    return {
        "ivf_centroids": jnp.zeros((p.nclusters, cfg.dim), jnp.float32),
        "ivf_members": jnp.full((p.nclusters, p.bucket), -1, jnp.int32),
        "ivf_count": jnp.zeros((p.nclusters,), jnp.int32),
        "ivf_assign": jnp.full((cfg.capacity,), -1, jnp.int32),
        "ivf_pos": jnp.full((cfg.capacity,), -1, jnp.int32),
        "ivf_pending": jnp.zeros((), jnp.int32),
        "ivf_overflow": jnp.zeros((), bool),
    }


# ---------------------------------------------------------------- insert

def nearest_clusters(centroids, embs):
    """(B,) nearest-centroid id per row — ONE GEMM, hoisted out of the
    sequential filing scan (only the least-loaded fallback depends on the
    evolving counts; this argmax does not)."""
    return jnp.argmax(jnp.einsum("bd,cd->bc", embs, centroids),
                      axis=1).astype(jnp.int32)


def file_row(ivf, c_near, slot, on):
    """File one row (precomputed nearest cluster) into the member table.

    ivf: dict view of the IVF_KEYS arrays; slot i32; on bool (False rows
    — padding / FIFO-lapped duplicates — are dropped).  Pure fixed-shape
    updates, usable inside jit/scan.
    """
    nclusters, bucket = ivf["ivf_members"].shape
    capacity = ivf["ivf_assign"].shape[0]
    # nearest list full -> rebalance to the least-loaded cluster
    c = jnp.where(ivf["ivf_count"][c_near] >= bucket,
                  jnp.argmin(ivf["ivf_count"]).astype(jnp.int32), c_near)
    ovf = ivf["ivf_count"][c] >= bucket
    p = jnp.minimum(ivf["ivf_count"][c], bucket - 1)
    wc = jnp.where(on, c, nclusters)        # OOB -> dropped scatter
    ws = jnp.where(on, slot, capacity)
    new = dict(ivf)
    new["ivf_members"] = ivf["ivf_members"].at[wc, p].set(slot, mode="drop")
    new["ivf_count"] = ivf["ivf_count"].at[wc].add(
        jnp.where(ovf, 0, 1), mode="drop")
    new["ivf_assign"] = ivf["ivf_assign"].at[ws].set(c, mode="drop")
    new["ivf_pos"] = ivf["ivf_pos"].at[ws].set(p, mode="drop")
    new["ivf_pending"] = ivf["ivf_pending"] + on.astype(jnp.int32)
    new["ivf_overflow"] = ivf["ivf_overflow"] | (on & ovf)
    return new


def append_one(ivf, emb, slot, on):
    """File one (already-normalized) embedding under its nearest centroid
    (single-entry path; batches should use :func:`update_batch`)."""
    c = jnp.argmax(ivf["ivf_centroids"] @ emb).astype(jnp.int32)
    return file_row(ivf, c, slot, on)


def update_batch(state, cfg, embs, slots):
    """File a batch of inserted rows (slots < 0 are dropped).

    Filing is sequential by construction — two rows landing in the same
    cluster must take consecutive member positions — so it runs as a
    lax.scan, one device dispatch for the whole batch (B is a serve-batch
    bucket, not capacity); the nearest-centroid routing is hoisted to a
    single (B, nclusters) GEMM.  ``embs`` must already be unit-normalized.
    """
    ivf = {k: state[k] for k in IVF_KEYS}
    cn = nearest_clusters(state["ivf_centroids"], embs)

    def step(carry, x):
        c_near, slot = x
        return file_row(carry, c_near, slot, slot >= 0), None

    ivf, _ = jax.lax.scan(step, ivf, (cn, slots.astype(jnp.int32)))
    out = dict(state)
    out.update(ivf)
    return out


# ---------------------------------------------------------------- lookup

def candidates(members, count, valid, assign, slot_pos, centroids, q_embs,
               nprobe: int):
    """Two-stage probe: centroid route -> padded member shortlist.

    Returns (cand_idx (B, nprobe*bucket) i32 bank rows, live (B, M) bool).
    Fixed shapes throughout: M never depends on data.
    """
    bucket = members.shape[1]
    csims = jnp.einsum("bd,cd->bc", q_embs.astype(jnp.float32), centroids)
    _, probe = jax.lax.top_k(csims, nprobe)                  # (B, nprobe)
    cand = jnp.take(members, probe, axis=0)                  # (B, np, bucket)
    cnt = jnp.take(count, probe, axis=0)                     # (B, np)
    pcol = jnp.arange(bucket, dtype=jnp.int32)[None, None, :]
    s = jnp.clip(cand, 0, None)
    live = ((cand >= 0) & (pcol < cnt[..., None])
            & jnp.take(valid, s)
            & (jnp.take(assign, s) == probe[..., None])
            & (jnp.take(slot_pos, s) == pcol))
    b = q_embs.shape[0]
    return cand.reshape(b, -1), live.reshape(b, -1)


def lookup(state, cfg, q_embs):
    """IVF lookup: (scores (B, k), indices (B, k)) like the flat scan.

    At ``nprobe == nclusters`` this is score- and decision-identical to
    the flat lookup (every valid slot appears exactly once live); at the
    default nprobe it scans ``nprobe * bucket`` rows instead of
    ``capacity``.
    """
    p = resolve(cfg)
    cand, live = candidates(
        state["ivf_members"], state["ivf_count"], state["valid"],
        state["ivf_assign"], state["ivf_pos"], state["ivf_centroids"],
        q_embs, p.nprobe)
    k = min(cfg.topk, cfg.capacity)
    return cosine_topk_gather(q_embs, state["emb"], cand, live, k=k,
                              impl=cfg.lookup_impl,
                              block_m=min(cfg.block_n, cand.shape[1]))


# ------------------------------------------------------------- rebuild

def _spherical_kmeans(x: np.ndarray, k: int, iters: int,  # hostsync: ok host-driven maintenance path
                      rng: np.random.Generator) -> np.ndarray:
    """Lloyd iterations with cosine assignment (rows of x unit-norm).

    The (n, k) assignment matmul runs through jnp (it dominates); the
    tiny centroid updates stay in numpy.  Empty clusters reseed to a
    random training row.
    """
    n = x.shape[0]
    init = rng.choice(n, size=k, replace=n < k)
    cent = x[init].copy()
    assign_fn = jax.jit(lambda xc, c: jnp.argmax(xc @ c.T, axis=1))
    for _ in range(iters):
        a = np.concatenate([
            np.asarray(assign_fn(x[i:i + 8192], cent))
            for i in range(0, n, 8192)])
        sums = np.zeros_like(cent)
        np.add.at(sums, a, x)
        counts = np.bincount(a, minlength=k)
        empty = counts == 0
        norms = np.linalg.norm(sums, axis=1, keepdims=True)
        cent = np.where(empty[:, None], x[rng.choice(n, size=k)],
                        sums / np.maximum(norms, 1e-8))
    return cent.astype(np.float32)


def build_index(state, cfg, seed: int = 0, sample: int = 65536):  # hostsync: ok host-driven maintenance path
    """Host-side recluster/rebalance: fresh k-means + compact member table.

    Maintenance path (called by ``maybe_reindex`` every ``reindex_every``
    writes or on overflow), so it optimizes for correctness: k-means
    trains on a <= ``sample`` row subset, every valid row is then filed
    under its nearest centroid, and clusters past ``bucket`` spill their
    FARTHEST members to the nearest cluster with space — no valid row is
    ever dropped, preserving the nprobe == nclusters equivalence.
    """
    p = resolve(cfg)
    emb = np.asarray(state["emb"], np.float32)
    valid = np.asarray(state["valid"])
    rows = np.nonzero(valid)[0]
    out = dict(state)
    out.update(init_ivf(cfg))
    # a recluster renames every cluster, so the per-cluster admission EMA
    # (cache.ADM_KEYS, riding outside IVF_KEYS) restarts optimistic —
    # carrying stats across incompatible cluster identities would
    # suppress inserts on whatever clusters inherit a shut id
    if "adm_ema" in state:
        out["adm_ema"] = jnp.ones_like(state["adm_ema"])
        out["adm_count"] = jnp.zeros_like(state["adm_count"])
    if len(rows) == 0:
        return out
    rng = np.random.default_rng(seed)
    train = emb[rng.choice(rows, size=min(len(rows), sample), replace=False)]
    cent = _spherical_kmeans(train, p.nclusters, cfg.kmeans_iters, rng)

    sim_fn = jax.jit(lambda xc, c: xc @ c.T)
    assign = np.full((cfg.capacity,), -1, np.int64)
    best_sim = np.zeros((cfg.capacity,), np.float32)
    for i in range(0, len(rows), 8192):
        chunk = rows[i:i + 8192]
        s = np.asarray(sim_fn(emb[chunk], cent))
        assign[chunk] = s.argmax(axis=1)
        best_sim[chunk] = s.max(axis=1)

    counts = np.bincount(assign[rows], minlength=p.nclusters)
    # spill: clusters past bucket hand their farthest rows to the nearest
    # cluster with space (total slack guarantees space exists)
    for c in np.nonzero(counts > p.bucket)[0]:
        mem = rows[assign[rows] == c]
        spill = mem[np.argsort(best_sim[mem])[:len(mem) - p.bucket]]
        sims = np.asarray(sim_fn(emb[spill], cent))
        for r, s in zip(spill, sims):
            s = np.where(counts < p.bucket, s, -np.inf)
            tgt = int(s.argmax())
            assign[r] = tgt
            counts[tgt] += 1
            counts[c] -= 1

    # vectorized table build: stable-sort rows by cluster, positions are
    # ranks within each run (a python per-row loop is minutes at 1M rows)
    order = rows[np.argsort(assign[rows], kind="stable")]
    sorted_c = assign[order]
    starts = np.searchsorted(sorted_c, np.arange(p.nclusters))
    posn = (np.arange(len(order)) - starts[sorted_c]).astype(np.int32)
    members = np.full((p.nclusters, p.bucket), -1, np.int32)
    count = np.bincount(sorted_c, minlength=p.nclusters).astype(np.int32)
    slot_pos = np.full((cfg.capacity,), -1, np.int32)
    members[sorted_c, posn] = order
    slot_pos[order] = posn

    out["ivf_centroids"] = jnp.asarray(cent)
    out["ivf_members"] = jnp.asarray(members)
    out["ivf_count"] = jnp.asarray(count)
    out["ivf_assign"] = jnp.asarray(assign.astype(np.int32))
    out["ivf_pos"] = jnp.asarray(slot_pos)
    return out


def maybe_reindex(state, cfg, seed: int = 0):
    """Engine maintenance hook: rebuild when stale-append debt piles up.

    Returns (state, rebuilt).  Cheap no-op for flat caches; for IVF it
    reads two device scalars (pending write count + overflow flag).
    """
    if getattr(cfg, "index", "flat") != "ivf":
        return state, False
    # one device_get for both maintenance scalars
    overflow, pending = jax.device_get(  # hostsync: ok two scalars, once per insert batch
        (state["ivf_overflow"], state["ivf_pending"]))
    if overflow or pending >= resolve(cfg).reindex_every:
        return build_index(state, cfg, seed=seed), True
    return state, False
