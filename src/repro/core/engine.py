"""TweakLLMEngine — the paper's Figure-1 pipeline, end to end.

Per incoming batch of text queries:
  1. tokenize + embed (MiniLM-class embedder, unit vectors)
  2. semantic-cache lookup (Pallas cosine top-k / sharded variant)
  3. threshold routing -> EXACT | TWEAK | MISS sub-batches (host split —
     the TPU analogue of per-request branching; see DESIGN.md §3)
  4. MISS  -> Big LLM generates; (query, response) inserted into the cache
     TWEAK -> Small LLM prefills the Appendix-A tweak prompt and decodes
     EXACT -> cached response returned verbatim (§6.1 fast path)

Step 2/3 run as one fused ``lookup_and_touch`` device call (EXACT and
TWEAK hits update LRU/LFU bookkeeping in the same step), and a miss batch
commits to the cache through one jitted ``insert_batch`` call with donated
buffers — O(1) host↔device syncs per serve batch (DESIGN.md §5).

Cost accounting mirrors the paper's §5.2.3 analysis: per-token cost ratio
``big_cost_per_token`` : ``small_cost_per_token`` defaults to 25:1.
Token counts are REAL generated tokens (up to and including each row's
first EOS), never the padded bucket length.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.embedder import encode as embed_encode
from repro.serving.batcher import (bucket_batch, bucket_len, floor_len_bucket,
                                   pad_to_buckets)
from repro.serving.generate import Generator
from repro.tokenizer import HashWordTokenizer

from . import cache as cache_lib
from . import index as index_lib
from . import router as router_lib
from . import tweak as tweak_lib


@dataclasses.dataclass
class EngineStats:
    total: int = 0
    miss: int = 0
    tweak: int = 0
    exact: int = 0
    big_tokens: int = 0
    small_tokens: int = 0
    big_cost_per_token: float = 25.0
    small_cost_per_token: float = 1.0

    @property
    def cost(self) -> float:
        return (self.big_tokens * self.big_cost_per_token
                + self.small_tokens * self.small_cost_per_token)

    @property
    def baseline_cost(self) -> float:
        """What the same generated-token volume would cost all-Big."""
        return (self.big_tokens + self.small_tokens) * self.big_cost_per_token

    @property
    def hit_rate(self) -> float:
        return (self.tweak + self.exact) / max(self.total, 1)


@dataclasses.dataclass
class BatchResult:
    """Per-batch serve result with per-request metadata.

    The continuous-batching scheduler (serving/scheduler.py, DESIGN.md §6)
    consumes this instead of the bare response list: ``meta`` rows carry
    the routing decision, top-1 similarity, similarity band, and the REAL
    generated-token count for each request, and the token deltas let the
    caller attribute cost to a dispatch without diffing ``EngineStats``.
    """
    responses: List[str]
    meta: List[dict]            # per row: sim, decision, band, gen_tokens
    big_tokens: int = 0         # tokens the Big LLM generated for this batch
    small_tokens: int = 0      # tokens the Small LLM generated for this batch


class TweakLLMEngine:
    def __init__(self, *, tokenizer: HashWordTokenizer,
                 embedder_params, embedder_cfg,
                 big: Generator, small: Generator,
                 cache_cfg: cache_lib.CacheConfig,
                 router_cfg: router_lib.RouterConfig = router_lib.RouterConfig(),
                 max_query_len: int = 64):
        self.tok = tokenizer
        self.embedder_params = embedder_params
        self.embedder_cfg = embedder_cfg
        self.big = big
        self.small = small
        self.cache_cfg = cache_cfg
        self.router_cfg = router_cfg
        self.max_query_len = max_query_len
        self.state = cache_lib.init_cache(cache_cfg)
        self.stats = EngineStats()
        # host-side mirror of cached texts (display only; tokens are truth)
        self._text_store: Dict[int, Tuple[str, str]] = {}
        self._insert_seq = 0
        # per-batch seed counter threaded into every Big/Small generate
        # call: distinct serve batches sample from distinct key streams
        # (the seed replayed PRNGKey(0) for every batch)
        self._seed_seq = itertools.count()

        self._embed = jax.jit(
            lambda p, t, m: embed_encode(p, t, m, embedder_cfg))
        # fused lookup + route + hit-accounting; cache state donated so the
        # touch happens in place (DESIGN.md §5)
        self._lookup_touch = jax.jit(
            lambda s, q: cache_lib.lookup_and_touch(s, cache_cfg,
                                                    router_cfg, q),
            donate_argnums=(0,))
        self._insert_batch = cache_lib.make_insert_batch(cache_cfg)

    # ------------------------------------------------------------- embed
    def embed_texts(self, texts: List[str]) -> jnp.ndarray:
        toks, mask = self.tok.encode_batch(texts, self.max_query_len)
        toks, mask, b = pad_to_buckets(toks, mask)
        return self._embed(self.embedder_params, jnp.asarray(toks),
                           jnp.asarray(mask))[:b]

    # ------------------------------------------------------------- serve
    def handle_batch(self, queries: List[str], *, max_new_tokens: int = 32,
                     collect_meta: bool = False):
        res = self.handle_batch_result(queries, max_new_tokens=max_new_tokens)
        if collect_meta:
            return res.responses, res.meta
        return res.responses

    def handle_batch_result(self, queries: List[str], *,
                            max_new_tokens: int = 32) -> BatchResult:
        """Serve a batch and return responses plus per-request metadata."""
        queries = [tweak_lib.preprocess_query(q) for q in queries]
        n = len(queries)
        if n == 0:
            return BatchResult([], [])
        # fail fast on an unservable max_new_tokens BEFORE any state
        # mutation (lookup touches recency on device; EXACT rows bill
        # stats) so a ValueError cannot leave half-served accounting
        self._tweak_encode_len(max_new_tokens)
        embs = self.embed_texts(queries)
        self.state, scores, idxs, dec = self._lookup_touch(self.state, embs)
        top1 = np.asarray(scores[:, 0])
        top1_idx = np.asarray(idxs[:, 0])
        decisions = np.asarray(dec)

        responses: List[Optional[str]] = [None] * n
        gen_tokens = [0] * n

        # EXACT: verbatim cached response
        for i in np.nonzero(decisions == router_lib.EXACT)[0]:
            slot = int(top1_idx[i])
            cached = self._text_store.get(slot)
            responses[i] = cached[1] if cached else self._decode_cached(slot)
            self.stats.exact += 1
        # TWEAK: small LLM refines cached response
        tweak_ids = np.nonzero(decisions == router_lib.TWEAK)[0]
        if len(tweak_ids):
            self._run_tweak(queries, tweak_ids, top1_idx, responses,
                            max_new_tokens, gen_tokens)
        # MISS: big LLM generates from scratch + cache insert
        miss_ids = np.nonzero(decisions == router_lib.MISS)[0]
        if len(miss_ids):
            self._run_miss(queries, miss_ids, embs, responses,
                           max_new_tokens, gen_tokens)

        self.stats.total += n
        # band_of mirrored on host: top1 is already here, so no extra
        # device dispatch + sync per serve batch just for meta
        bands = np.full(n, -1, np.int32)
        for bi, (lo, hi) in enumerate(router_lib.BANDS):
            bands[(top1 >= lo) & (top1 < hi)] = bi
        meta = [{"sim": float(top1[i]), "decision": int(decisions[i]),
                 "band": int(bands[i]), "gen_tokens": gen_tokens[i]}
                for i in range(n)]
        miss_mask = decisions == router_lib.MISS
        return BatchResult(
            responses, meta,
            big_tokens=int(sum(t for i, t in enumerate(gen_tokens)
                               if miss_mask[i])),
            small_tokens=int(sum(t for i, t in enumerate(gen_tokens)
                                 if not miss_mask[i])))

    # ------------------------------------------------------------- paths
    def _next_seed(self) -> int:
        return next(self._seed_seq)

    def _decode_cached(self, slot: int) -> str:
        toks = np.asarray(self.state["r_tokens"][slot])
        mask = np.asarray(self.state["r_mask"][slot])
        return self.tok.decode_ids([int(t) for t, m in zip(toks, mask) if m > 0])

    def _decode_cached_query(self, slot: int) -> str:
        """Decode a slot's cached QUERY tokens (BOS stripped)."""
        toks = np.asarray(self.state["q_tokens"][slot])
        mask = np.asarray(self.state["q_mask"][slot])
        return self.tok.decode_ids([int(t) for t, m in zip(toks, mask)
                                    if m > 0 and int(t) != self.tok.bos])

    @staticmethod
    def _visible_ids(row: np.ndarray, n_gen: int, ended: bool) -> List[int]:
        """Visible ids of a generated row from its device-reported length.

        ``n_gen`` counts real generated tokens including the terminating
        EOS when ``ended``; the visible response is everything before it.
        The lengths come back from the fused decode loop, so no per-row
        EOS scan is needed here.
        """
        return [int(t) for t in row[:n_gen - 1 if ended else n_gen]]

    def _tweak_encode_len(self, max_new_tokens: int) -> int:
        """Prompt-token budget for the tweak path, bucket-rounding-safe.

        The naive budget ``max_seq_len - max_new_tokens - 1`` goes
        non-positive when ``max_new_tokens + 1 >= max_seq_len``, and even a
        positive budget can be rounded back past ``max_seq_len`` by
        ``pad_to_buckets`` (length buckets round UP).  Clamp to the largest
        length bucket that still fits; raise when nothing fits.
        """
        msl = self.small.model.cfg.max_seq_len
        budget = msl - max_new_tokens - 1
        if budget < 1:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} leaves no room for the "
                f"tweak prompt: small model max_seq_len={msl} requires "
                f"max_new_tokens <= {msl - 2}")
        if bucket_len(budget) + max_new_tokens + 1 <= msl:
            return budget
        clamped = floor_len_bucket(budget)
        if bucket_len(clamped) + max_new_tokens + 1 > msl:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} leaves no length bucket "
                f"for the tweak prompt within small model "
                f"max_seq_len={msl} (smallest bucket rounds past it)")
        return clamped

    def _run_tweak(self, queries, ids, top1_idx, responses, max_new_tokens,
                   gen_tokens):
        slots = [int(top1_idx[i]) for i in ids]
        # The device cache is the source of truth: a slot can be live there
        # but absent from the host text mirror (offline-populated state,
        # restored checkpoint, distributed shard).  Fall back to decoding
        # the cached tokens so the Appendix-A tweak prompt is never built
        # from empty strings.
        cached = []
        for s in slots:
            c = self._text_store.get(s)
            if c is None:
                c = (self._decode_cached_query(s), self._decode_cached(s))
            cached.append(c)
        texts = [tweak_lib.build_tweak_text(queries[i], cq, cr)
                 for i, (cq, cr) in zip(ids, cached)]
        toks, mask = self.tok.encode_batch(
            texts, self._tweak_encode_len(max_new_tokens))
        toks, mask, b = pad_to_buckets(toks, mask)
        out, lengths, ended = self.small.generate_with_lengths(
            {"tokens": jnp.asarray(toks)}, max_new_tokens=max_new_tokens,
            seed=self._next_seed())
        for j, i in enumerate(ids):
            n_gen = int(lengths[j])
            responses[i] = self.tok.decode_ids(
                self._visible_ids(out[j], n_gen, bool(ended[j])))
            self.stats.small_tokens += n_gen
            self.stats.tweak += 1
            gen_tokens[i] = n_gen

    def _insert_entries(self, texts, resp_tokens, resp_texts, embs):
        """Commit entries to the cache in ONE jitted device call.

        texts/resp_texts: host strings; resp_tokens: per-row visible ids;
        embs (n, D) on device.  Pads to the batch bucket so compiles stay
        bounded; the single ``slots`` pull is the only host sync.
        """
        n = len(texts)
        ccfg = self.cache_cfg
        qt, qm = self.tok.encode_batch(texts, ccfg.max_query_tokens)
        rt = np.zeros((n, ccfg.max_response_tokens), np.int32)
        rm = np.zeros((n, ccfg.max_response_tokens), np.float32)
        for j, ids in enumerate(resp_tokens):
            rl = min(len(ids), ccfg.max_response_tokens)
            rt[j, :rl] = ids[:rl]
            rm[j, :rl] = 1.0
        nb = bucket_batch(n)
        pad = lambda a: np.concatenate(
            [a, np.zeros((nb - n,) + a.shape[1:], a.dtype)]) if nb > n else a
        embs = jnp.concatenate(
            [embs, jnp.zeros((nb - n, embs.shape[1]), embs.dtype)]) \
            if nb > n else embs
        self.state, slots = self._insert_batch(
            self.state, embs, jnp.asarray(pad(qt)), jnp.asarray(pad(qm)),
            jnp.asarray(pad(rt)), jnp.asarray(pad(rm)), n)
        slots = np.asarray(slots)  # single device->host sync per batch
        for j in range(n):
            self._text_store[int(slots[j])] = (texts[j], resp_texts[j])
        # IVF maintenance: k-means recluster when enough writes piled up
        # (or the member table overflowed).  No-op for flat caches.
        self.state, _ = index_lib.maybe_reindex(self.state, self.cache_cfg,
                                                seed=self._insert_seq)
        self._insert_seq += 1

    def _run_miss(self, queries, ids, embs, responses, max_new_tokens,
                  gen_tokens):
        texts = [queries[i] for i in ids]
        toks, mask = self.tok.encode_batch(texts, self.max_query_len)
        toks, mask, b = pad_to_buckets(toks, mask)
        out, lengths, ended = self.big.generate_with_lengths(
            {"tokens": jnp.asarray(toks)}, max_new_tokens=max_new_tokens,
            seed=self._next_seed())
        resp_tokens, resp_texts = [], []
        for j, i in enumerate(ids):
            n_gen = int(lengths[j])
            visible = self._visible_ids(out[j], n_gen, bool(ended[j]))
            resp_text = self.tok.decode_ids(visible)
            responses[i] = resp_text
            resp_tokens.append(visible)
            resp_texts.append(resp_text)
            self.stats.big_tokens += n_gen
            self.stats.miss += 1
            gen_tokens[i] = n_gen
        self._insert_entries(texts, resp_tokens, resp_texts,
                             embs[np.asarray(ids)])

    # ------------------------------------------------- offline population
    def populate(self, queries: List[str], responses: List[str]):
        """Bulk-insert known (query, response) pairs (dataset simulation)."""
        if len(queries) != len(responses):
            raise ValueError(f"populate got {len(queries)} queries but "
                             f"{len(responses)} responses")
        if not queries:
            return
        queries = [tweak_lib.preprocess_query(q) for q in queries]
        embs = self.embed_texts(queries)
        rt, rm = self.tok.encode_batch(responses, self.cache_cfg.max_response_tokens,
                                       add_bos=False)
        resp_tokens = [[int(t) for t, m in zip(rt[i], rm[i]) if m > 0]
                       for i in range(len(queries))]
        self._insert_entries(queries, resp_tokens, responses, embs)
