"""TweakLLMEngine — the paper's Figure-1 pipeline, end to end.

Per incoming batch of text queries:
  1. tokenize + embed (MiniLM-class embedder, unit vectors)
  2. semantic-cache lookup (Pallas cosine top-k / sharded variant)
  3. threshold routing -> EXACT | TWEAK | MISS sub-batches (host split —
     the TPU analogue of per-request branching; see DESIGN.md §3)
  4. MISS  -> Big LLM generates; (query, response) inserted into the cache
     TWEAK -> Small LLM prefills the Appendix-A tweak prompt and decodes
     EXACT -> cached response returned verbatim (§6.1 fast path)

Cost accounting mirrors the paper's §5.2.3 analysis: per-token cost ratio
``big_cost_per_token`` : ``small_cost_per_token`` defaults to 25:1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.embedder import encode as embed_encode
from repro.models.model import Model
from repro.serving.batcher import pad_to_buckets
from repro.serving.generate import GenerateConfig, Generator
from repro.tokenizer import HashWordTokenizer

from . import cache as cache_lib
from . import router as router_lib
from . import tweak as tweak_lib


@dataclasses.dataclass
class EngineStats:
    total: int = 0
    miss: int = 0
    tweak: int = 0
    exact: int = 0
    big_tokens: int = 0
    small_tokens: int = 0
    big_cost_per_token: float = 25.0
    small_cost_per_token: float = 1.0

    @property
    def cost(self) -> float:
        return (self.big_tokens * self.big_cost_per_token
                + self.small_tokens * self.small_cost_per_token)

    @property
    def baseline_cost(self) -> float:
        """What the same generated-token volume would cost all-Big."""
        return (self.big_tokens + self.small_tokens) * self.big_cost_per_token

    @property
    def hit_rate(self) -> float:
        return (self.tweak + self.exact) / max(self.total, 1)


class TweakLLMEngine:
    def __init__(self, *, tokenizer: HashWordTokenizer,
                 embedder_params, embedder_cfg,
                 big: Generator, small: Generator,
                 cache_cfg: cache_lib.CacheConfig,
                 router_cfg: router_lib.RouterConfig = router_lib.RouterConfig(),
                 max_query_len: int = 64):
        self.tok = tokenizer
        self.embedder_params = embedder_params
        self.embedder_cfg = embedder_cfg
        self.big = big
        self.small = small
        self.cache_cfg = cache_cfg
        self.router_cfg = router_cfg
        self.max_query_len = max_query_len
        self.state = cache_lib.init_cache(cache_cfg)
        self.stats = EngineStats()
        # host-side mirror of cached texts (display only; tokens are truth)
        self._text_store: Dict[int, Tuple[str, str]] = {}
        self._insert_seq = 0

        self._embed = jax.jit(
            lambda p, t, m: embed_encode(p, t, m, embedder_cfg))
        self._lookup = jax.jit(
            lambda s, q: cache_lib.lookup(s, cache_cfg, q))

    # ------------------------------------------------------------- embed
    def embed_texts(self, texts: List[str]) -> jnp.ndarray:
        toks, mask = self.tok.encode_batch(texts, self.max_query_len)
        toks, mask, b = pad_to_buckets(toks, mask)
        return self._embed(self.embedder_params, jnp.asarray(toks),
                           jnp.asarray(mask))[:b]

    # ------------------------------------------------------------- serve
    def handle_batch(self, queries: List[str], *, max_new_tokens: int = 32,
                     collect_meta: bool = False):
        queries = [tweak_lib.preprocess_query(q) for q in queries]
        n = len(queries)
        embs = self.embed_texts(queries)
        scores, idxs = self._lookup(self.state, embs)
        top1 = np.asarray(scores[:, 0])
        top1_idx = np.asarray(idxs[:, 0])
        decisions = np.asarray(router_lib.route(jnp.asarray(top1), self.router_cfg))

        responses: List[Optional[str]] = [None] * n
        meta = [{"sim": float(top1[i]), "decision": int(decisions[i]),
                 "band": int(np.asarray(router_lib.band_of(jnp.asarray([top1[i]])))[0])}
                for i in range(n)]

        # EXACT: verbatim cached response
        for i in np.nonzero(decisions == router_lib.EXACT)[0]:
            slot = int(top1_idx[i])
            cached = self._text_store.get(slot)
            responses[i] = cached[1] if cached else self._decode_cached(slot)
            self.stats.exact += 1
        # TWEAK: small LLM refines cached response
        tweak_ids = np.nonzero(decisions == router_lib.TWEAK)[0]
        if len(tweak_ids):
            self._run_tweak(queries, tweak_ids, top1_idx, responses,
                            max_new_tokens)
        # MISS: big LLM generates from scratch + cache insert
        miss_ids = np.nonzero(decisions == router_lib.MISS)[0]
        if len(miss_ids):
            self._run_miss(queries, miss_ids, embs, responses, max_new_tokens)

        self.stats.total += n
        if collect_meta:
            return responses, meta
        return responses

    # ------------------------------------------------------------- paths
    def _decode_cached(self, slot: int) -> str:
        toks = np.asarray(self.state["r_tokens"][slot])
        mask = np.asarray(self.state["r_mask"][slot])
        return self.tok.decode_ids([int(t) for t, m in zip(toks, mask) if m > 0])

    def _run_tweak(self, queries, ids, top1_idx, responses, max_new_tokens):
        slots = [int(top1_idx[i]) for i in ids]
        cached = [self._text_store.get(s, ("", "")) for s in slots]
        texts = [tweak_lib.build_tweak_text(queries[i], cq, cr)
                 for i, (cq, cr) in zip(ids, cached)]
        toks, mask = self.tok.encode_batch(
            texts, self.small.model.cfg.max_seq_len - max_new_tokens - 1)
        toks, mask, b = pad_to_buckets(toks, mask)
        out = self.small.generate({"tokens": jnp.asarray(toks)},
                                  max_new_tokens=max_new_tokens)[:b]
        self.state = cache_lib.touch(self.state, self.cache_cfg,
                                     jnp.asarray(slots, jnp.int32))
        for j, i in enumerate(ids):
            responses[i] = self.tok.decode_ids(out[j].tolist())
            self.stats.small_tokens += out.shape[1]
            self.stats.tweak += 1

    def _run_miss(self, queries, ids, embs, responses, max_new_tokens):
        texts = [queries[i] for i in ids]
        toks, mask = self.tok.encode_batch(texts, self.max_query_len)
        toks, mask, b = pad_to_buckets(toks, mask)
        out = self.big.generate({"tokens": jnp.asarray(toks)},
                                max_new_tokens=max_new_tokens)[:b]
        qtoks, qmask = self.tok.encode_batch(texts, self.cache_cfg.max_query_tokens)
        for j, i in enumerate(ids):
            resp_text = self.tok.decode_ids(out[j].tolist())
            responses[i] = resp_text
            rt = np.zeros((self.cache_cfg.max_response_tokens,), np.int32)
            rm = np.zeros((self.cache_cfg.max_response_tokens,), np.float32)
            rl = min(out.shape[1], self.cache_cfg.max_response_tokens)
            rt[:rl] = out[j][:rl]
            rm[:rl] = 1.0
            slot = int(np.asarray(cache_lib._victim_slot(self.state, self.cache_cfg)))
            self.state = cache_lib.insert(
                self.state, self.cache_cfg, embs[i],
                jnp.asarray(qtoks[j]), jnp.asarray(qmask[j]),
                jnp.asarray(rt), jnp.asarray(rm))
            self._text_store[slot] = (texts[j], resp_text)
            self.stats.big_tokens += out.shape[1]
            self.stats.miss += 1

    # ------------------------------------------------- offline population
    def populate(self, queries: List[str], responses: List[str]):
        """Bulk-insert known (query, response) pairs (dataset simulation)."""
        queries = [tweak_lib.preprocess_query(q) for q in queries]
        embs = self.embed_texts(queries)
        qt, qm = self.tok.encode_batch(queries, self.cache_cfg.max_query_tokens)
        rt, rm = self.tok.encode_batch(responses, self.cache_cfg.max_response_tokens,
                                       add_bos=False)
        for i in range(len(queries)):
            slot = int(np.asarray(cache_lib._victim_slot(self.state, self.cache_cfg)))
            self.state = cache_lib.insert(
                self.state, self.cache_cfg, embs[i], jnp.asarray(qt[i]),
                jnp.asarray(qm[i]), jnp.asarray(rt[i]), jnp.asarray(rm[i]))
            self._text_store[slot] = (queries[i], responses[i])
