"""TweakLLMEngine — the paper's Figure-1 pipeline, end to end.

Per incoming batch of text queries:
  1. tokenize + embed (MiniLM-class embedder, unit vectors)
  2. semantic-cache lookup (Pallas cosine top-k / sharded variant)
  3. threshold routing -> EXACT | TWEAK | MISS sub-batches (host split —
     the TPU analogue of per-request branching; see DESIGN.md §3)
  4. MISS  -> Big LLM generates; (query, response) inserted into the cache
     TWEAK -> Small LLM prefills the Appendix-A tweak prompt and decodes
     EXACT -> cached response returned verbatim (§6.1 fast path)

Step 2/3 run as one fused ``lookup_and_touch`` device call (EXACT and
TWEAK hits update LRU/LFU bookkeeping in the same step), and a miss batch
commits to the cache through one jitted ``insert_batch`` call with donated
buffers — O(1) host↔device syncs per serve batch (DESIGN.md §5).

The TWEAK path is prefix-cached (DESIGN.md §9): the byte-identical
Appendix-A instruction prefix is prefilled once per (small model, batch
bucket) and reused as KV by every tweak request, which then prefills
only its variable suffix, length-bucketed by REAL suffix length instead
of padded to the worst-case tweak budget.  Small models whose
architecture can't guarantee byte-identical prefix reuse (recurrent
mixers, sliding windows, enc-dec, naive-softmax attention) fall back to
the full-prompt prefill explicitly.

Cost accounting mirrors the paper's §5.2.3 analysis: per-token cost ratio
``big_cost_per_token`` : ``small_cost_per_token`` defaults to 25:1.
Token counts are REAL generated tokens (up to and including each row's
first EOS), never the padded bucket length, and prompt (input) tokens
are billed at real unpadded lengths alongside generated ones.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.embedder import encode as embed_encode
from repro.serving.batcher import (bucket_batch, bucket_len, floor_len_bucket,
                                   pad_to_buckets)
from repro.serving.generate import Generator
from repro.tokenizer import HashWordTokenizer

from . import cache as cache_lib
from . import index as index_lib
from . import router as router_lib
from . import tweak as tweak_lib


@dataclasses.dataclass
class EngineStats:
    total: int = 0
    miss: int = 0
    tweak: int = 0
    exact: int = 0
    # calibrated-cascade counters (DESIGN.md §13): rows that entered the
    # stage-2 uncertainty band, how many of those committed as TWEAK
    # (recovered hits), and inserts suppressed by cluster admission.
    uncertain: int = 0
    recovered: int = 0
    suppressed_inserts: int = 0
    big_tokens: int = 0             # REAL generated tokens, Big LLM
    small_tokens: int = 0           # REAL generated tokens, Small LLM
    # The paper's §5.2.3 cost analysis bills INPUT tokens too.  Prompt
    # counts are real (unpadded) prefilled lengths, never the padded
    # bucket: the Big LLM's prompt is the bare query, the Small LLM's is
    # the Appendix-A tweak prompt (shared prefix included — the KV may be
    # cached, but a provider still bills the tokens).
    big_prompt_tokens: int = 0
    small_prompt_tokens: int = 0
    # Real query tokens across ALL requests: the prompt volume an
    # uncached all-Big deployment would have ingested (baseline input).
    baseline_prompt_tokens: int = 0
    # speculative-decode counters (DESIGN.md §14): cached-response draft
    # tokens fed to TWEAK verify blocks, how many were accepted (emitted
    # without a plain decode step), and verify-block iterations run.
    proposed: int = 0
    accepted: int = 0
    spec_steps: int = 0
    big_cost_per_token: float = 25.0
    small_cost_per_token: float = 1.0

    @property
    def cost(self) -> float:
        return ((self.big_tokens + self.big_prompt_tokens)
                * self.big_cost_per_token
                + (self.small_tokens + self.small_prompt_tokens)
                * self.small_cost_per_token)

    @property
    def baseline_cost(self) -> float:
        """What the same traffic would cost all-Big: every query's prompt
        plus the same generated-token volume, at the Big rate."""
        return (self.big_tokens + self.small_tokens
                + self.baseline_prompt_tokens) * self.big_cost_per_token

    @property
    def hit_rate(self) -> float:
        return (self.tweak + self.exact) / max(self.total, 1)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verify loop accepted (§14)."""
        return self.accepted / max(self.proposed, 1)

    @classmethod
    def aggregate(cls, parts) -> "EngineStats":
        """Sum counters across replicas (DESIGN.md §12).

        Cost rates must agree — silently averaging them would make the
        aggregate ``cost`` property meaningless.
        """
        parts = list(parts)
        if not parts:
            return cls()
        rates = {(p.big_cost_per_token, p.small_cost_per_token)
                 for p in parts}
        if len(rates) != 1:
            raise ValueError(
                f"replicas disagree on cost rates: {sorted(rates)}")
        big_rate, small_rate = rates.pop()
        out = cls(big_cost_per_token=big_rate,
                  small_cost_per_token=small_rate)
        for f in ("total", "miss", "tweak", "exact", "uncertain",
                  "recovered", "suppressed_inserts", "big_tokens",
                  "small_tokens", "big_prompt_tokens", "small_prompt_tokens",
                  "baseline_prompt_tokens", "proposed", "accepted",
                  "spec_steps"):
            setattr(out, f, sum(getattr(p, f) for p in parts))
        return out


@dataclasses.dataclass
class BatchResult:
    """Per-batch serve result with per-request metadata.

    The continuous-batching scheduler (serving/scheduler.py, DESIGN.md §6)
    consumes this instead of the bare response list: ``meta`` rows carry
    the routing decision, top-1 similarity, similarity band, and the REAL
    generated-token count for each request, and the token deltas let the
    caller attribute cost to a dispatch without diffing ``EngineStats``.
    """
    responses: List[str]
    meta: List[dict]            # per row: sim, decision, band, gen_tokens
    big_tokens: int = 0         # tokens the Big LLM generated for this batch
    small_tokens: int = 0      # tokens the Small LLM generated for this batch
    big_prompt_tokens: int = 0   # real (unpadded) prompt tokens, Big LLM
    small_prompt_tokens: int = 0  # real (unpadded) prompt tokens, Small LLM


class SharedCacheBank:
    """The semantic cache as a first-class shareable object (DESIGN.md §12).

    Owns the device-side cache state, the host text mirror, and the two
    jitted state-mutating entry points — the fused lookup+route+touch and
    the batched miss commit.  One bank serves ONE engine (the PR 1–7
    topology, ``mesh=None``) or N replicas through a :class:`ReplicaGroup`:
    every replica routes lookups and commits misses through the same
    object, so a response cached by replica A is visible to replica B on
    B's very next lookup.

    With a ``mesh``, the embedding bank, token buffers, and IVF member
    tables are row-sharded over ``axis`` (centroids and ring scalars
    replicated) and the entry points come from ``repro.core.distributed``:
    lookups merge per-shard top-k winners, and inserts are
    single-writer-per-shard — the globally rotating ring pointer names the
    owning shard for every slot, so concurrent replica commits serialize
    through the bank with no cross-shard write traffic.
    """

    def __init__(self, cache_cfg: cache_lib.CacheConfig,
                 router_cfg: Optional[router_lib.RouterConfig] = None, *,
                 mesh=None, axis: str = "data", state=None, reranker=None):
        if router_cfg is None:
            router_cfg = router_lib.RouterConfig()
        if router_cfg.band > 0.0 and reranker is None:
            raise ValueError(
                "router band > 0 enables the stage-2 cascade, which needs "
                "reranker=(params, model_cfg) on the bank")
        self.cfg = cache_cfg
        self.router_cfg = router_cfg
        self.mesh = mesh
        self.axis = axis
        # host-side mirror of cached texts (display only; tokens are truth)
        self.text_store: Dict[int, Tuple[str, str]] = {}
        # host-side mirror of cached-response TOKEN ids, the speculation
        # drafts (DESIGN.md §14): the exact ids generation produced (a
        # text round-trip through the tokenizer need not be identity, and
        # draft quality is acceptance rate).  Overwritten on slot reuse
        # alongside text_store.
        self.draft_store: Dict[int, List[int]] = {}
        self.insert_seq = 0
        # per-batch-size default-cost arrays (explicit device_put once per
        # size — the hot loop must not transfer implicitly per dispatch)
        self._default_costs: Dict[int, jnp.ndarray] = {}
        if state is None:
            state = cache_lib.init_cache(cache_cfg)
        if mesh is None:
            self.state = state
            # fused lookup + calibrated route + hit-accounting; cache state
            # donated so the touch happens in place (DESIGN.md §5)
            self._lookup_touch = jax.jit(
                lambda s, q, c: cache_lib.lookup_route_touch(
                    s, cache_cfg, router_cfg, q, c),
                donate_argnums=(0,))
            self._insert = cache_lib.make_insert_batch(cache_cfg)
        else:
            from . import distributed as dist_lib
            if cache_cfg.index == "ivf":
                self.state = dist_lib.shard_ivf_cache_state(
                    state, mesh, cache_cfg, axis)
            else:
                self.state = dist_lib.shard_cache_state(state, mesh, axis)
            self._lookup_touch = dist_lib.make_distributed_lookup_and_touch(
                mesh, cache_cfg, router_cfg, axis)
            self._insert = dist_lib.make_distributed_insert_batch(
                mesh, cache_cfg, axis)
        # stage-2 resolver (shared by local and sharded states: the token
        # gather + touch run in the GSPMD region with replicated indices)
        self._second_stage = None
        if reranker is not None:
            rr_params, rr_cfg = reranker
            self._second_stage = cache_lib.make_second_stage(
                cache_cfg, router_cfg, rr_params, rr_cfg)

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    @property
    def cascading(self) -> bool:
        """Is the stage-2 cascade active (band > 0 + reranker wired)?"""
        return self.router_cfg.band > 0.0 and self._second_stage is not None

    def default_cost(self, batch: int):
        """The (batch,)-shaped default-cost array, device-put once."""
        c = self._default_costs.get(batch)
        if c is None:
            c = jax.device_put(np.full((batch,), self.router_cfg.default_cost,
                                       np.float32))
            self._default_costs[batch] = c
        return c

    def route_batch(self, q_embs, cost=None):
        """Stage-1 fused device call at per-request operating points.

        ``cost`` (B,) float32 on device, or None for the config default.
        Returns the device-array tuple ``(scores, idx, decisions, tau,
        cluster, admit)`` — decisions may contain ``router.UNCERTAIN``
        when the cascade is on; resolve those with :meth:`second_stage`.
        """
        if cost is None:
            cost = self.default_cost(q_embs.shape[0])
        (self.state, scores, idx, dec, tau, cluster,
         admit) = self._lookup_touch(self.state, q_embs, cost)
        return scores, idx, dec, tau, cluster, admit

    def lookup_and_touch(self, q_embs):
        """One fused device call: returns (scores, idx, decisions).

        The fixed-operating-point wrapper around :meth:`route_batch`
        (kept for single-stage callers; at the default config it is
        decision-identical to the legacy two-threshold router).
        """
        scores, idx, dec, *_ = self.route_batch(q_embs)
        return scores, idx, dec

    def second_stage(self, q_tokens, q_mask, scores, idx, decisions, tau,
                     cluster):
        """Resolve UNCERTAIN rows: returns (final_decisions, slot, conf).

        All inputs are device arrays (stage-1 outputs pass through
        unconverted); ``slot`` (B,) is the per-row serving slot — the
        reranker's pick for committed uncertain rows, top-1 otherwise.
        """
        if self._second_stage is None:
            raise ValueError("bank built without a reranker; stage 2 "
                             "unavailable")
        self.state, final, slot, conf = self._second_stage(
            self.state, q_tokens, q_mask, scores, idx, decisions, tau,
            cluster)
        return final, slot, conf

    def insert_batch(self, embs, q_tokens, q_mask, r_tokens, r_mask, count):
        """One jitted commit; returns the device ``slots`` array."""
        self.state, slots = self._insert(self.state, embs, q_tokens, q_mask,
                                         r_tokens, r_mask, count)
        return slots

    def maybe_reindex(self) -> bool:
        """IVF maintenance after a commit; no-op for flat caches.

        Always advances ``insert_seq`` (the reindex seed stream) so
        local and sharded banks rebuild from the same seed sequence.
        """
        rebuilt = False
        if self.cfg.index == "ivf":
            if self.mesh is None:
                self.state, rebuilt = index_lib.maybe_reindex(
                    self.state, self.cfg, seed=self.insert_seq)
            else:
                rebuilt = self._maybe_reindex_sharded()
        self.insert_seq += 1
        return rebuilt

    def _maybe_reindex_sharded(self) -> bool:  # hostsync: ok host-driven maintenance, mirrors index.maybe_reindex
        """Gather -> rebuild -> reshard, the sharded k-means recluster.

        ``build_index`` resets the IVF arrays to a fresh LOCAL layout, so
        pulling the (tiny, capacity-bounded) bank to host, rebuilding, and
        resharding reproduces exactly what a local bank would hold — at a
        maintenance cadence, not per request.
        """
        overflow, pending = jax.device_get(
            (self.state["ivf_overflow"], self.state["ivf_pending"]))
        p = index_lib.resolve(self.cfg)
        if not (bool(overflow) or int(pending) >= p.reindex_every):
            return False
        from . import distributed as dist_lib
        host = jax.device_get(self.state)
        rebuilt = index_lib.build_index(host, self.cfg, seed=self.insert_seq)
        self.state = dist_lib.shard_ivf_cache_state(
            rebuilt, self.mesh, self.cfg, self.axis)
        return True


class TweakLLMEngine:
    def __init__(self, *, tokenizer: HashWordTokenizer,
                 embedder_params, embedder_cfg,
                 big: Generator, small: Generator,
                 cache_cfg: Optional[cache_lib.CacheConfig] = None,
                 router_cfg: Optional[router_lib.RouterConfig] = None,
                 max_query_len: int = 64, use_prefix_cache: bool = True,
                 bank: Optional[SharedCacheBank] = None,
                 replica_id: int = 0, reranker=None):
        if bank is None:
            if cache_cfg is None:
                raise ValueError("pass cache_cfg or a SharedCacheBank")
            bank = SharedCacheBank(cache_cfg, router_cfg, reranker=reranker)
        else:
            if cache_cfg is not None and cache_cfg != bank.cfg:
                raise ValueError("cache_cfg disagrees with the shared bank")
            if router_cfg is not None and router_cfg != bank.router_cfg:
                raise ValueError("router_cfg disagrees with the shared bank")
        self.bank = bank
        self.replica_id = replica_id
        self.tok = tokenizer
        self.embedder_params = embedder_params
        self.embedder_cfg = embedder_cfg
        self.big = big
        self.small = small
        self.cache_cfg = bank.cfg
        self.router_cfg = bank.router_cfg
        self.max_query_len = max_query_len
        self.use_prefix_cache = use_prefix_cache
        self.stats = EngineStats()
        # Shared tweak-instruction prefix KV, one PrefixCache per batch
        # bucket (DESIGN.md §9), invalidated when the small generator's
        # model/sampler config or the prefix tokens change.
        self._prefix_ids: Optional[Tuple[int, ...]] = None
        self._prefix_caches: Dict[int, object] = {}
        self._prefix_sig = None
        self._static_counts: Optional[Tuple[int, int]] = None
        # per-batch seed counter threaded into every Big/Small generate
        # call: distinct serve batches sample from distinct key streams
        # (the seed replayed PRNGKey(0) for every batch)
        self._seed_seq = itertools.count()

        self._embed = jax.jit(
            lambda p, t, m: embed_encode(p, t, m, embedder_cfg))

    # cache state + text mirror live on the bank (shared across replicas);
    # these aliases keep the single-engine API unchanged
    @property
    def state(self):
        return self.bank.state

    @state.setter
    def state(self, value):
        self.bank.state = value

    @property
    def _text_store(self) -> Dict[int, Tuple[str, str]]:
        return self.bank.text_store

    # ------------------------------------------------------------- embed
    def embed_texts(self, texts: List[str]) -> jnp.ndarray:
        return self._embed_with_lengths(texts)[0]

    def _embed_with_lengths(self, texts: List[str]):
        """(embeddings (n, D), real query-token lengths, query tokens/mask).

        Lengths come from the host-side tokenizer mask, not the device;
        the (n, max_query_len) token arrays stay host-side — the stage-2
        cascade device_puts them only when uncertain rows exist."""
        toks, mask = self.tok.encode_batch(texts, self.max_query_len)
        qlens = mask.sum(axis=1).astype(np.int64).tolist()
        ptoks, pmask, b = pad_to_buckets(toks, mask)
        embs = self._embed(self.embedder_params, jnp.asarray(ptoks),
                           jnp.asarray(pmask))[:b]
        return embs, qlens, toks, mask

    # ------------------------------------------------------------- serve
    def handle_batch(self, queries: List[str], *, max_new_tokens: int = 32,
                     collect_meta: bool = False, cost_thresholds=None):
        res = self.handle_batch_result(queries, max_new_tokens=max_new_tokens,
                                       cost_thresholds=cost_thresholds)
        if collect_meta:
            return res.responses, res.meta
        return res.responses

    def _resolve_costs(self, n: int, cost_thresholds) -> List[float]:
        """Per-row cost thresholds: scalar, per-row list (None entries ->
        config default), or None for the all-default batch."""
        dc = self.router_cfg.default_cost
        if cost_thresholds is None:
            return [dc] * n
        if np.isscalar(cost_thresholds):
            return [float(cost_thresholds)] * n  # hostsync: ok caller-provided host scalar
        if len(cost_thresholds) != n:
            raise ValueError(f"{len(cost_thresholds)} cost thresholds for "
                             f"{n} queries")
        return [dc if c is None else float(c) for c in cost_thresholds]  # hostsync: ok caller-provided host scalars

    def handle_batch_result(self, queries: List[str], *,
                            max_new_tokens: int = 32,
                            cost_thresholds=None) -> BatchResult:
        """Serve a batch and return responses plus per-request metadata.

        ``cost_thresholds`` selects each request's operating point on the
        calibrated routing curve (scalar, per-row list with None = config
        default, or None for all-default).
        """
        queries = [tweak_lib.preprocess_query(q) for q in queries]
        n = len(queries)
        if n == 0:
            return BatchResult([], [])
        # fail fast on an unservable max_new_tokens BEFORE any state
        # mutation (lookup touches recency on device; EXACT rows bill
        # stats) so a ValueError cannot leave half-served accounting
        self._tweak_encode_len(max_new_tokens)
        cost_l = self._resolve_costs(n, cost_thresholds)
        embs, qlens, qtoks, qmask = self._embed_with_lengths(queries)
        self.stats.baseline_prompt_tokens += sum(qlens)
        cost_dev = (self.bank.default_cost(n) if cost_thresholds is None
                    else jax.device_put(np.asarray(cost_l, np.float32)))  # hostsync: ok host list H2D, explicit put
        d_scores, d_idx, d_dec, d_tau, d_cluster, d_admit = \
            self.bank.route_batch(embs, cost_dev)
        # THE per-serve-batch device->host sync (DESIGN.md §5): scores,
        # slots, routing decisions, and admission flags pulled in one
        # device_get; the top-1 column is sliced on host (device-side
        # `[:, 0]` would dispatch its index as an H2D transfer) and
        # everything below works on host scalars.  The stage-2 resolve
        # below adds a SECOND sync, but only on batches that actually
        # carry uncertain rows — the certain path stays O(1).
        scores, idxs, decisions, admit = jax.device_get(  # hostsync: ok the one per-batch sync
            (d_scores, d_idx, d_dec, d_admit))
        top1 = scores[:, 0]
        slot_arr = idxs[:, 0]
        stage2_rows = decisions == router_lib.UNCERTAIN
        n_unc = int(stage2_rows.sum())  # hostsync: ok numpy after the batch sync
        if n_unc:
            final, slot, _conf = self.bank.second_stage(
                jax.device_put(qtoks), jax.device_put(qmask),
                d_scores, d_idx, d_dec, d_tau, d_cluster)
            decisions, slot_arr = jax.device_get(  # hostsync: ok stage-2 resolve, fires only when uncertain rows exist
                (final, slot))
            self.stats.uncertain += n_unc
            self.stats.recovered += int(  # hostsync: ok numpy after the stage-2 sync
                (decisions[stage2_rows] == router_lib.TWEAK).sum())
        top1_l = top1.tolist()
        slot_l = slot_arr.tolist()
        dec_l = decisions.tolist()

        responses: List[Optional[str]] = [None] * n
        gen_tokens = [0] * n
        prompt_tokens = [0] * n

        # EXACT: verbatim cached response
        for i in np.nonzero(decisions == router_lib.EXACT)[0]:
            slot = slot_l[i]
            cached = self._text_store.get(slot)
            responses[i] = cached[1] if cached else self._decode_cached(slot)
            self.stats.exact += 1
        # TWEAK: small LLM refines cached response
        tweak_ids = np.nonzero(decisions == router_lib.TWEAK)[0]
        if len(tweak_ids):
            self._run_tweak(queries, tweak_ids, slot_l, responses,
                            max_new_tokens, gen_tokens, prompt_tokens)
        # MISS: big LLM generates from scratch + cache insert (suppressed
        # for rows whose query cluster the admission EMA has shut)
        miss_ids = np.nonzero(decisions == router_lib.MISS)[0]
        if len(miss_ids):
            self._run_miss(queries, miss_ids, embs, responses,
                           max_new_tokens, gen_tokens, prompt_tokens,
                           admit)

        self.stats.total += n
        # band_of mirrored on host with the ACTIVE config's edges: top1 is
        # already here, so no extra device dispatch + sync just for meta
        bands = np.full(n, -1, np.int32)
        for bi, (lo, hi) in enumerate(router_lib.bands_for(self.router_cfg)):
            bands[(top1 >= lo) & (top1 < hi)] = bi
        band_l = bands.tolist()
        meta = [{"sim": top1_l[i], "decision": dec_l[i],
                 "band": band_l[i], "gen_tokens": gen_tokens[i],
                 "cost": cost_l[i],
                 "stage2": bool(stage2_rows[i])}  # hostsync: ok numpy after sync
                for i in range(n)]
        miss_mask = decisions == router_lib.MISS
        return BatchResult(
            responses, meta,
            big_tokens=sum(t for i, t in enumerate(gen_tokens)
                           if miss_mask[i]),
            small_tokens=sum(t for i, t in enumerate(gen_tokens)
                             if not miss_mask[i]),
            big_prompt_tokens=sum(t for i, t in enumerate(prompt_tokens)
                                  if miss_mask[i]),
            small_prompt_tokens=sum(t for i, t in enumerate(prompt_tokens)
                                    if not miss_mask[i]))

    # ------------------------------------------------------------- paths
    def _next_seed(self) -> int:
        return next(self._seed_seq)

    def _decode_cached(self, slot: int) -> str:  # hostsync: ok cold fallback when the host text mirror lacks a slot
        toks, mask = jax.device_get((self.state["r_tokens"][slot],
                                     self.state["r_mask"][slot]))
        return self.tok.decode_ids(
            [t for t, m in zip(toks.tolist(), mask.tolist()) if m > 0])

    def _decode_cached_query(self, slot: int) -> str:  # hostsync: ok cold fallback, see _decode_cached
        """Decode a slot's cached QUERY tokens (BOS stripped)."""
        toks, mask = jax.device_get((self.state["q_tokens"][slot],
                                     self.state["q_mask"][slot]))
        return self.tok.decode_ids(
            [t for t, m in zip(toks.tolist(), mask.tolist())
             if m > 0 and t != self.tok.bos])

    @staticmethod
    def _visible_ids(row: np.ndarray, n_gen: int, ended: bool) -> List[int]:
        """Visible ids of a generated row from its device-reported length.

        ``n_gen`` counts real generated tokens including the terminating
        EOS when ``ended``; the visible response is everything before it.
        The lengths come back from the fused decode loop, so no per-row
        EOS scan is needed here.  ``row`` is already host-resident.
        """
        return row[:n_gen - 1 if ended else n_gen].tolist()

    def _tweak_static_tokens(self, suffix_only: bool = False) -> int:
        if self._static_counts is None:
            self._static_counts = (
                tweak_lib.static_token_count(self.tok),
                tweak_lib.static_token_count(self.tok, suffix_only=True))
        return self._static_counts[1 if suffix_only else 0]

    def _tweak_encode_len(self, max_new_tokens: int) -> int:
        """Prompt-token budget for the tweak path, bucket-rounding-safe.

        The naive budget ``max_seq_len - max_new_tokens - 1`` goes
        non-positive when ``max_new_tokens + 1 >= max_seq_len``, and even a
        positive budget can be rounded back past ``max_seq_len`` by
        ``pad_to_buckets`` (length buckets round UP).  Clamp to the largest
        length bucket that still fits; raise when nothing fits.  The budget
        must also cover the static prompt segments (instruction + cues),
        which cue-preserving truncation never drops — validating that HERE
        keeps the handle_batch fail-fast guarantee: the alternative is a
        ``ValueError`` out of ``_truncate_fields`` mid-serve, after lookup
        already touched recency and EXACT rows billed stats.
        """
        msl = self.small.model.cfg.max_seq_len
        budget = msl - max_new_tokens - 1
        if budget < 1:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} leaves no room for the "
                f"tweak prompt: small model max_seq_len={msl} requires "
                f"max_new_tokens <= {msl - 2}")
        if bucket_len(budget) + max_new_tokens + 1 > msl:
            budget = floor_len_bucket(budget)
            if bucket_len(budget) + max_new_tokens + 1 > msl:
                raise ValueError(
                    f"max_new_tokens={max_new_tokens} leaves no length "
                    f"bucket for the tweak prompt within small model "
                    f"max_seq_len={msl} (smallest bucket rounds past it)")
        statics = self._tweak_static_tokens()
        if budget < statics:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} leaves a {budget}-token "
                f"tweak prompt budget, below the {statics} tokens the "
                f"static Appendix-A segments need — lower max_new_tokens "
                f"or raise the small model's max_seq_len={msl}")
        return budget

    # ------------------------------------------------- tweak prefix cache
    def _tweak_prefix_ids(self) -> Tuple[int, ...]:
        if self._prefix_ids is None:
            self._prefix_ids = tuple(tweak_lib.tweak_prefix_ids(self.tok))
        return self._prefix_ids

    def _prefix_path_available(self) -> bool:
        """Can the TWEAK path prefill over a shared-prefix KV cache?

        Requires the small generator to expose the prefix API (wrapped
        generators may not) and its architecture to support byte-identical
        prefix prefill; recurrent / windowed / enc-dec small models fall
        back to the full prefill explicitly (DESIGN.md §9).
        """
        return (self.use_prefix_cache
                and getattr(self.small, "supports_prefix_prefill", False)
                and callable(getattr(self.small, "build_prefix_cache", None)))

    def _small_prefix_cache(self, batch: int):
        """The tweak-instruction PrefixCache for one batch bucket.

        Rebuilt from scratch whenever the small GENERATOR OBJECT, its
        model config, sampler/generate config, or the prefix token ids
        change — a stale prefix KV would silently corrupt every tweak
        response.  The object identity term catches the config-identical
        swap (same architecture, new checkpoint weights) that config
        comparison alone would miss.
        """
        ids = self._tweak_prefix_ids()
        sig = (id(self.small), self.small.model.cfg,
               getattr(self.small, "cfg", None), ids)
        if sig != self._prefix_sig:
            self._prefix_caches.clear()
            self._prefix_sig = sig
        pc = self._prefix_caches.get(batch)
        if pc is None:
            pc = self.small.build_prefix_cache(ids, batch)
            self._prefix_caches[batch] = pc
        return pc

    def _tweak_suffix_budget(self, max_new_tokens: int,
                             prefix_len: int) -> Optional[int]:
        """Per-row suffix-token budget for prefix-cached tweak prefill.

        Same bucket-rounding discipline as ``_tweak_encode_len``, with the
        prefix length reserved on top: any real suffix length within the
        budget keeps ``prefix + bucket_len(suffix) + max_new_tokens + 1``
        inside the small model's ``max_seq_len``.  Returns None when no
        bucket fits — the caller then falls back to the full prefill path
        (which ``_tweak_encode_len`` has already validated).
        """
        msl = self.small.model.cfg.max_seq_len
        budget = msl - max_new_tokens - 1 - prefix_len
        if budget < 1:
            return None
        if bucket_len(budget) + prefix_len + max_new_tokens + 1 > msl:
            budget = floor_len_bucket(budget)
            if bucket_len(budget) + prefix_len + max_new_tokens + 1 > msl:
                return None
        # the suffix's own static cues are untruncatable — if they don't
        # fit, this path can't serve the request (the full path might)
        if budget < self._tweak_static_tokens(suffix_only=True):
            return None
        return budget

    def _run_tweak(self, queries, ids, slot_l, responses, max_new_tokens,
                   gen_tokens, prompt_tokens):
        slots = [slot_l[i] for i in ids]
        # The device cache is the source of truth: a slot can be live there
        # but absent from the host text mirror (offline-populated state,
        # restored checkpoint, distributed shard).  Fall back to decoding
        # the cached tokens so the Appendix-A tweak prompt is never built
        # from empty strings.
        cached = []
        for s in slots:
            c = self._text_store.get(s)
            if c is None:
                c = (self._decode_cached_query(s), self._decode_cached(s))
            cached.append(c)
        new_qs = [queries[i] for i in ids]
        cqs = [cq for cq, _ in cached]
        crs = [cr for _, cr in cached]
        drafts = self._tweak_drafts(slots, crs, max_new_tokens)

        suffix_budget = None
        if self._prefix_path_available():
            suffix_budget = self._tweak_suffix_budget(
                max_new_tokens, len(self._tweak_prefix_ids()))
        if suffix_budget is None:
            self._run_tweak_full(new_qs, cqs, crs, ids, responses,
                                 max_new_tokens, gen_tokens, prompt_tokens,
                                 drafts)
        else:
            self._run_tweak_prefixed(new_qs, cqs, crs, ids, responses,
                                     max_new_tokens, suffix_budget,
                                     gen_tokens, prompt_tokens, drafts)

    def _tweak_drafts(self, slots, crs, max_new_tokens):
        """Per-row speculation drafts for a TWEAK sub-batch, or None.

        The tweak prompt asks the small model to minimally edit the cached
        response, so the cached response's own token ids (plus the
        terminating EOS) are the natural draft for the verify loop
        (DESIGN.md §14).  Ids come from the bank's draft store (the exact
        generated ids) with a tokenize-the-mirror fallback for slots
        populated outside this process.  Returns ``(ids (B, D), lens
        (B,))`` or None when the small generator is not speculation-ready
        (wrong config/arch/sampler — ``getattr`` so wrapped generators
        degrade gracefully) or the per-call budget is below ``spec_k``.
        """
        if not getattr(self.small, "speculation_ready", False):
            return None
        if self.small.cfg.spec_k > max_new_tokens:
            return None
        eos = self.small.cfg.eos_id
        rows = []
        for s, cr in zip(slots, crs):
            ids = self.bank.draft_store.get(s)
            if ids is None:
                t, m = self.tok.encode_batch(
                    [cr], self.cache_cfg.max_response_tokens, add_bos=False)
                ids = [tt for tt, mm in zip(t[0].tolist(), m[0].tolist())
                       if mm > 0]
            rows.append(list(ids) + [eos])
        width = max(len(r) for r in rows)
        did = np.full((len(rows), width), eos, np.int32)
        for j, r in enumerate(rows):
            did[j, :len(r)] = r
        return did, np.asarray([len(r) for r in rows], np.int32)  # hostsync: ok drafts are host-resident cached-response ids

    def _bill_spec_stats(self):
        """Fold the small generator's last speculative call into stats."""
        st = getattr(self.small, "last_spec_stats", None)
        if st:
            self.stats.proposed += st["proposed"]
            self.stats.accepted += st["accepted"]
            self.stats.spec_steps += st["spec_steps"]

    def _emit_tweak_rows(self, rows, ids, out, lengths, ended, responses,
                         gen_tokens):
        """Decode generated rows back into their batch positions + billing."""
        lengths = lengths.tolist()
        ended = ended.tolist()
        for j, row in enumerate(rows):
            i = ids[row]
            n_gen = lengths[j]
            responses[i] = self.tok.decode_ids(
                self._visible_ids(out[j], n_gen, ended[j]))
            self.stats.small_tokens += n_gen
            self.stats.tweak += 1
            gen_tokens[i] = n_gen

    def _run_tweak_full(self, new_qs, cqs, crs, ids, responses,
                        max_new_tokens, gen_tokens, prompt_tokens,
                        drafts=None):
        """Fallback: prefill the whole Appendix-A prompt (no prefix reuse)."""
        toks, mask = tweak_lib.build_tweak_batch(
            self.tok, new_qs, cqs, crs, self._tweak_encode_len(max_new_tokens))
        real_lens = mask.sum(axis=1).astype(np.int64).tolist()
        toks, mask, b = pad_to_buckets(toks, mask)
        kw = {}
        if drafts is not None:
            # bucket padding added rows: give them empty drafts
            did, dlen = drafts
            pad = toks.shape[0] - did.shape[0]
            if pad:
                did = np.concatenate(
                    [did, np.zeros((pad, did.shape[1]), did.dtype)])
                dlen = np.concatenate([dlen, np.zeros((pad,), dlen.dtype)])
            kw["drafts"] = (did, dlen)
        out, lengths, ended = self.small.generate_with_lengths(
            {"tokens": jnp.asarray(toks)}, max_new_tokens=max_new_tokens,
            seed=self._next_seed(), **kw)
        if drafts is not None:
            self._bill_spec_stats()
        self._emit_tweak_rows(range(len(ids)), ids, out, lengths, ended,
                              responses, gen_tokens)
        for j, i in enumerate(ids):
            prompt_tokens[i] = real_lens[j]
            self.stats.small_prompt_tokens += real_lens[j]

    def _run_tweak_prefixed(self, new_qs, cqs, crs, ids, responses,
                            max_new_tokens, suffix_budget, gen_tokens,
                            prompt_tokens, drafts=None):
        """Hot path: shared-prefix KV reuse + length-bucketed suffixes.

        Each row prefills only its variable suffix over the cached
        instruction-prefix KV, and rows are grouped by ``bucket_len`` of
        their REAL suffix length instead of all padding to the worst-case
        tweak budget — short cached responses stop paying attention FLOPs
        for the full ``_tweak_encode_len`` bucket (DESIGN.md §9).
        """
        prefix_ids = self._tweak_prefix_ids()
        toks, mask = tweak_lib.build_tweak_suffix_batch(
            self.tok, new_qs, cqs, crs, suffix_budget)
        real_lens = mask.sum(axis=1).astype(np.int64).tolist()
        groups: Dict[int, List[int]] = {}
        for row, rl in enumerate(real_lens):
            groups.setdefault(bucket_len(max(rl, 1)), []).append(row)
        for bucket in sorted(groups):
            rows = groups[bucket]
            sub_t = toks[rows][:, :bucket]
            sub_m = mask[rows][:, :bucket]
            sub_t = pad_to_buckets(sub_t, sub_m)[0]
            pc = self._small_prefix_cache(sub_t.shape[0])
            kw = {}
            if drafts is not None:
                did, dlen = drafts
                sub_d, sub_l = did[rows], dlen[rows]
                pad = sub_t.shape[0] - sub_d.shape[0]
                if pad:
                    sub_d = np.concatenate(
                        [sub_d, np.zeros((pad, sub_d.shape[1]), sub_d.dtype)])
                    sub_l = np.concatenate(
                        [sub_l, np.zeros((pad,), sub_l.dtype)])
                kw["drafts"] = (sub_d, sub_l)
            out, lengths, ended = self.small.generate_with_lengths(
                {"tokens": jnp.asarray(sub_t)},
                max_new_tokens=max_new_tokens, seed=self._next_seed(),
                prefix_cache=pc, **kw)
            if drafts is not None:
                self._bill_spec_stats()
            self._emit_tweak_rows(rows, ids, out, lengths, ended,
                                  responses, gen_tokens)
            for row in rows:
                i = ids[row]
                real = len(prefix_ids) + real_lens[row]
                prompt_tokens[i] = real
                self.stats.small_prompt_tokens += real

    def _insert_entries(self, texts, resp_tokens, resp_texts, embs):
        """Commit entries to the cache in ONE jitted device call.

        texts/resp_texts: host strings; resp_tokens: per-row visible ids;
        embs (n, D) on device.  Pads to the batch bucket so compiles stay
        bounded; the single ``slots`` pull is the only host sync.
        """
        n = len(texts)
        ccfg = self.cache_cfg
        qt, qm = self.tok.encode_batch(texts, ccfg.max_query_tokens)
        rt = np.zeros((n, ccfg.max_response_tokens), np.int32)
        rm = np.zeros((n, ccfg.max_response_tokens), np.float32)
        for j, ids in enumerate(resp_tokens):
            rl = min(len(ids), ccfg.max_response_tokens)
            rt[j, :rl] = ids[:rl]
            rm[j, :rl] = 1.0
        nb = bucket_batch(n)
        pad = lambda a: np.concatenate(
            [a, np.zeros((nb - n,) + a.shape[1:], a.dtype)]) if nb > n else a
        embs = jnp.concatenate(
            [embs, jnp.zeros((nb - n, embs.shape[1]), embs.dtype)]) \
            if nb > n else embs
        # the traced `count` scalar is device_put explicitly — passing the
        # bare python int would transfer it implicitly at every dispatch
        slots = self.bank.insert_batch(
            embs, jnp.asarray(pad(qt)), jnp.asarray(pad(qm)),
            jnp.asarray(pad(rt)), jnp.asarray(pad(rm)),
            jax.device_put(np.int32(n)))
        # single device->host sync per insert batch
        slots = jax.device_get(slots).tolist()  # hostsync: ok the one per-insert sync
        for j in range(n):
            self._text_store[slots[j]] = (texts[j], resp_texts[j])
            self.bank.draft_store[slots[j]] = list(resp_tokens[j])
        # IVF maintenance: k-means recluster when enough writes piled up
        # (or the member table overflowed).  No-op for flat caches.
        self.bank.maybe_reindex()

    def _run_miss(self, queries, ids, embs, responses, max_new_tokens,
                  gen_tokens, prompt_tokens, admit=None):
        texts = [queries[i] for i in ids]
        toks, mask = self.tok.encode_batch(texts, self.max_query_len)
        real_lens = mask.sum(axis=1).astype(np.int64).tolist()
        toks, mask, b = pad_to_buckets(toks, mask)
        out, lengths, ended = self.big.generate_with_lengths(
            {"tokens": jnp.asarray(toks)}, max_new_tokens=max_new_tokens,
            seed=self._next_seed())
        lengths = lengths.tolist()
        ended = ended.tolist()
        resp_tokens, resp_texts = [], []
        for j, i in enumerate(ids):
            n_gen = lengths[j]
            visible = self._visible_ids(out[j], n_gen, ended[j])
            resp_text = self.tok.decode_ids(visible)
            responses[i] = resp_text
            resp_tokens.append(visible)
            resp_texts.append(resp_text)
            self.stats.big_tokens += n_gen
            self.stats.big_prompt_tokens += real_lens[j]
            self.stats.miss += 1
            gen_tokens[i] = n_gen
            prompt_tokens[i] = real_lens[j]
        # admission control (DESIGN.md §13): the response is still served,
        # but clusters the hit EMA has shut don't pollute the cache
        keep = list(range(len(ids))) if admit is None else \
            [j for j, i in enumerate(ids) if bool(admit[i])]  # hostsync: ok numpy after the batch sync
        self.stats.suppressed_inserts += len(ids) - len(keep)
        if not keep:
            return
        kept_ids = np.asarray([ids[j] for j in keep])  # hostsync: ok host list of slot ids
        # explicit device_put of the row indices: a host-array gather
        # would move them implicitly (transfer-guard unsafe)
        self._insert_entries([texts[j] for j in keep],
                             [resp_tokens[j] for j in keep],
                             [resp_texts[j] for j in keep],
                             jnp.take(embs, jax.device_put(kept_ids),
                                      axis=0))

    # ------------------------------------------------- offline population
    def populate(self, queries: List[str], responses: List[str]):
        """Bulk-insert known (query, response) pairs (dataset simulation)."""
        if len(queries) != len(responses):
            raise ValueError(f"populate got {len(queries)} queries but "
                             f"{len(responses)} responses")
        if not queries:
            return
        queries = [tweak_lib.preprocess_query(q) for q in queries]
        embs = self.embed_texts(queries)
        rt, rm = self.tok.encode_batch(responses, self.cache_cfg.max_response_tokens,
                                       add_bos=False)
        rt_l, rm_l = rt.tolist(), rm.tolist()
        resp_tokens = [[t for t, m in zip(rt_l[i], rm_l[i]) if m > 0]
                       for i in range(len(queries))]
        self._insert_entries(queries, resp_tokens, responses, embs)


class ReplicaGroup:
    """N engine replicas over shared (or deliberately private) cache banks.

    The replica topology (DESIGN.md §12): model weights are per-replica
    handles (replicated params, or TP-sharded via launch/sharding.py param
    specs — the Generator objects may even be shared when the caller wants
    one set of compiled functions), while the cache bank is ONE
    :class:`SharedCacheBank` serving every replica.  ``shared=False``
    builds a private bank per replica instead — the degraded baseline the
    replica bench compares against (hit rate then converges per replica
    stream, not per aggregate stream).
    """

    def __init__(self, engines: List[TweakLLMEngine]):
        if not engines:
            raise ValueError("ReplicaGroup needs at least one engine")
        self.engines = list(engines)

    @classmethod
    def build(cls, n: int, *, tokenizer, embedder_params, embedder_cfg,
              big, small, cache_cfg: cache_lib.CacheConfig,
              router_cfg: Optional[router_lib.RouterConfig] = None,
              shared: bool = True, mesh=None, axis: str = "data",
              reranker=None, **engine_kw) -> "ReplicaGroup":
        """Builds ``n`` replicas.  ``big``/``small`` are Generators shared
        by every replica, or callables ``replica_id -> Generator`` for
        per-replica handles (distinct KV pools)."""
        bank = (SharedCacheBank(cache_cfg, router_cfg, mesh=mesh, axis=axis,
                                reranker=reranker)
                if shared else None)
        engines = []
        for rid in range(n):
            engines.append(TweakLLMEngine(
                tokenizer=tokenizer, embedder_params=embedder_params,
                embedder_cfg=embedder_cfg,
                big=big(rid) if callable(big) else big,
                small=small(rid) if callable(small) else small,
                bank=bank if shared else SharedCacheBank(
                    cache_cfg, router_cfg, mesh=mesh, axis=axis,
                    reranker=reranker),
                replica_id=rid, **engine_kw))
        return cls(engines)

    def __len__(self) -> int:
        return len(self.engines)

    def __getitem__(self, rid: int) -> TweakLLMEngine:
        return self.engines[rid]

    @property
    def shared(self) -> bool:
        return all(e.bank is self.engines[0].bank for e in self.engines)

    @property
    def bank(self) -> SharedCacheBank:
        if not self.shared:
            raise ValueError("replicas hold private banks; no single bank")
        return self.engines[0].bank

    @property
    def stats(self) -> EngineStats:
        """Aggregate serve counters across every replica."""
        return EngineStats.aggregate(e.stats for e in self.engines)

    def leaked_kv_pages(self) -> List[int]:
        """Per-replica leaked (live minus pinned) KV pages, paged pools
        only — every entry must be 0 once all work is harvested."""
        from repro.serving.continuous import leaked_pages
        return [leaked_pages(e.big, e.small) for e in self.engines]
