# TweakLLM core: semantic cache + threshold router + tweak engine.
from . import cache, router, tweak
from .cache import CacheConfig, init_cache, insert, lookup, fetch
from .router import RouterConfig, route, band_of, MISS, TWEAK, EXACT
from .engine import TweakLLMEngine, EngineStats
from .baseline import GPTCacheBaseline, BaselineConfig
