# TweakLLM core: semantic cache + threshold router + tweak engine.
from . import cache, index, router, tweak
from .cache import (CacheConfig, init_cache, insert, insert_batch,
                    make_insert_batch, lookup, lookup_and_touch,
                    lookup_route_touch, make_second_stage, fetch)
from .index import build_index, maybe_reindex
from .router import (RouterConfig, route, route_cascade, threshold_for,
                     band_of, bands_for, MISS, TWEAK, EXACT, UNCERTAIN)
from .engine import (TweakLLMEngine, EngineStats, BatchResult,
                     SharedCacheBank, ReplicaGroup)
from .baseline import GPTCacheBaseline, BaselineConfig
