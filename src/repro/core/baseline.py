"""GPTCache-style baseline (the paper's foil, §4.2.1 / Fig 2).

Single-layer semantic cache: embed -> ANN top-k -> cross-encoder re-rank ->
return the cached response VERBATIM when the best candidate clears the
threshold.  No tweaking.  Used to reproduce the precision/recall curves.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.embedder import encode as embed_encode
from repro.models.reranker import score_pairs
from repro.serving.batcher import pad_to_buckets
from repro.tokenizer import HashWordTokenizer

from . import cache as cache_lib


@dataclasses.dataclass(frozen=True)
class BaselineConfig:
    similarity_threshold: float = 0.7
    rerank: str = "cross_encoder"  # cross_encoder | none
    topk: int = 4


class GPTCacheBaseline:
    def __init__(self, *, tokenizer: HashWordTokenizer, embedder_params,
                 embedder_cfg, reranker_params=None, reranker_cfg=None,
                 cache_cfg: cache_lib.CacheConfig, cfg: BaselineConfig,
                 max_query_len: int = 64):
        self.tok = tokenizer
        self.embedder_params = embedder_params
        self.embedder_cfg = embedder_cfg
        self.reranker_params = reranker_params
        self.reranker_cfg = reranker_cfg
        self.cache_cfg = cache_cfg
        self.cfg = cfg
        self.max_query_len = max_query_len
        self.state = cache_lib.init_cache(cache_cfg)
        self._texts = {}

        self._embed = jax.jit(lambda p, t, m: embed_encode(p, t, m, embedder_cfg))
        self._lookup = jax.jit(lambda s, q: cache_lib.lookup(s, cache_cfg, q))
        if reranker_params is not None:
            self._rerank = jax.jit(
                lambda p, ta, ma, tb, mb: score_pairs(p, ta, ma, tb, mb, reranker_cfg))

    def _embed_texts(self, texts: List[str]) -> jnp.ndarray:
        toks, mask = self.tok.encode_batch(texts, self.max_query_len)
        toks, mask, b = pad_to_buckets(toks, mask)
        return self._embed(self.embedder_params, jnp.asarray(toks),
                           jnp.asarray(mask))[:b]

    def put(self, query: str, response: str):
        emb = self._embed_texts([query])[0]
        qt, qm = self.tok.encode_batch([query], self.cache_cfg.max_query_tokens)
        rt, rm = self.tok.encode_batch([response], self.cache_cfg.max_response_tokens)
        slot = int(np.asarray(cache_lib._victim_slot(self.state, self.cache_cfg)))
        self.state = cache_lib.insert(self.state, self.cache_cfg, emb,
                                      jnp.asarray(qt[0]), jnp.asarray(qm[0]),
                                      jnp.asarray(rt[0]), jnp.asarray(rm[0]))
        self._texts[slot] = (query, response)

    def get(self, query: str) -> Tuple[Optional[str], Optional[str], float]:
        """Returns (cached_query, cached_response, score) or (None, None, s)."""
        emb = self._embed_texts([query])
        scores, idxs = self._lookup(self.state, emb)
        scores, idxs = np.asarray(scores[0]), np.asarray(idxs[0])
        live = [(s, i) for s, i in zip(scores, idxs) if i >= 0 and np.isfinite(s)]
        if not live or live[0][0] < self.cfg.similarity_threshold:
            return None, None, float(scores[0]) if np.isfinite(scores[0]) else -1.0
        if self.cfg.rerank == "cross_encoder" and self.reranker_params is not None:
            cands = [self._texts[int(i)][0] for _, i in live]
            ta, ma = self.tok.encode_batch([query] * len(cands), self.max_query_len)
            tb, mb = self.tok.encode_batch(cands, self.max_query_len)
            ta, ma, b = pad_to_buckets(ta, ma)
            tb, mb, _ = pad_to_buckets(tb, mb)
            rr = np.asarray(self._rerank(self.reranker_params, jnp.asarray(ta),
                                         jnp.asarray(ma), jnp.asarray(tb),
                                         jnp.asarray(mb)))[:b]
            best = int(np.argmax(rr))
        else:
            best = 0
        slot = int(live[best][1])
        cq, cr = self._texts[slot]
        return cq, cr, float(live[best][0])
