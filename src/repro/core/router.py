"""Calibrated routing cascade — the decision layer of TweakLLM (§3.1).

The paper routes on two fixed cosine thresholds.  This module generalises
that into a staged, calibrated decision pipeline (ROADMAP #3):

* **Operating curve** — a per-request ``cost_threshold ∈ [0, 1]`` (0 =
  cheapest, serve from cache aggressively; 1 = highest quality, regenerate
  aggressively) selects the operating point on a piecewise-linear
  score→decision calibration curve: :func:`threshold_for` maps cost to the
  TWEAK/MISS boundary ``tau``.  The default curve is derived from
  ``tweak_threshold`` with a knot pinned AT ``default_cost``, so the
  legacy two-threshold router is exactly the ``cost = default_cost``
  operating point (bit-identical decisions — the byte-identity contract
  the regression tests pin).
* **Stage 1** (:func:`route_cascade`, fused into the cache lookup):
  threshold the top-1 similarity at ``tau`` like the paper, but rows
  inside the ``band``-wide uncertainty window around ``tau`` come back
  as the provisional :data:`UNCERTAIN` decision instead of committing.
  ``band = 0`` (the default) disables the cascade entirely.
* **Stage 2** (:func:`stage2_combine`, a second jitted pass only when
  uncertain rows exist): multi-probe agreement over the already-retrieved
  ``cosine_topk`` shortlist plus a cross-encoder reranker pass
  (``models/reranker.py``) decide TWEAK-vs-MISS, and the argmax of the
  blended per-candidate evidence re-selects the serving candidate —
  recovering misroutes where the best tweak source is not the top-1
  cosine neighbour.
* **Admission control** (:func:`admission_update`, IVF caches): a
  per-cluster hit EMA rides on the IVF centroid assignments; clusters
  that persistently miss are suppressed from insertion (SCALM-style
  "is this even worth caching").  ``admit_floor = 0`` disables it.

Also reports the paper's cosine-similarity bands (0.7-0.8, 0.8-0.9,
0.9-1.0) used throughout the evaluation figures — derived from the
active config's ``tweak_threshold`` (paper bands at the default 0.7).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

MISS, TWEAK, EXACT = 0, 1, 2
UNCERTAIN = 3          # provisional stage-1 decision; never leaves the bank
BANDS = ((0.7, 0.8), (0.8, 0.9), (0.9, 1.01))


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    tweak_threshold: float = 0.7   # paper Table 1 initial threshold
    exact_threshold: float = 0.9999
    # --- calibrated operating curve (cost -> TWEAK/MISS boundary tau) ---
    # () = derive knots from tweak_threshold: (0, default_cost, 1) ->
    # (tweak_threshold - cal_span, tweak_threshold, 1.0).  Custom curves
    # must keep cal_costs strictly increasing within [0, 1].
    default_cost: float = 0.5
    cal_costs: tuple = ()
    cal_taus: tuple = ()
    cal_span: float = 0.2
    # --- stage-2 uncertainty cascade (width of the |top1 - tau| window;
    # 0 disables stage 2 and reproduces the single-stage router) ---
    band: float = 0.0
    probe_temp: float = 0.05       # sharpness of the multi-probe agreement
    w_agree: float = 0.4           # weight of top-k agreement in stage 2
    w_rerank: float = 0.6          # weight of the cross-encoder evidence
    commit_at: float = 0.5         # normalized confidence needed for TWEAK
    # --- per-cluster admission control (IVF caches; floor 0 disables) ---
    admit_alpha: float = 0.05      # hit-EMA step per observation
    admit_floor: float = 0.0       # suppress inserts when cluster EMA < floor
    admit_min: int = 16            # observations before a cluster can be shut

    def __post_init__(self):
        if len(self.cal_costs) != len(self.cal_taus):
            raise ValueError(
                f"calibration knots disagree: {len(self.cal_costs)} costs "
                f"vs {len(self.cal_taus)} taus")
        if self.cal_costs and len(self.cal_costs) < 2:
            raise ValueError("calibration needs >= 2 knots")
        if not 0.0 <= self.default_cost <= 1.0:
            raise ValueError(f"default_cost {self.default_cost} not in [0,1]")


def calibration(cfg: RouterConfig):
    """The (cal_costs, cal_taus) knot arrays, derived when not given."""
    if cfg.cal_costs:
        return (jnp.asarray(cfg.cal_costs, jnp.float32),
                jnp.asarray(cfg.cal_taus, jnp.float32))
    t = float(cfg.tweak_threshold)  # hostsync: ok config scalar, never traced
    dc = min(max(float(cfg.default_cost), 1e-3), 1.0 - 1e-3)  # hostsync: ok config scalar
    return (jnp.asarray((0.0, dc, 1.0), jnp.float32),
            jnp.asarray((t - cfg.cal_span, t, 1.0), jnp.float32))


def threshold_for(cost, cfg: RouterConfig):
    """Per-request TWEAK/MISS boundary tau from cost thresholds (B,).

    With the derived calibration, ``cost == default_cost`` is pinned to
    ``tweak_threshold`` EXACTLY (not through interp float arithmetic) —
    the legacy router is that single operating point, bit for bit.
    """
    cost = jnp.asarray(cost, jnp.float32)
    xs, ys = calibration(cfg)
    tau = jnp.interp(cost, xs, ys)
    if not cfg.cal_costs:
        tau = jnp.where(cost == cfg.default_cost, cfg.tweak_threshold, tau)
    return tau


def route(scores, cfg: RouterConfig):
    """scores: (B,) top-1 cosine similarity -> decisions (B,) int32.

    The legacy single-stage router: the fixed operating point at
    ``cost = default_cost`` with no uncertainty band.
    """
    d = jnp.zeros(scores.shape, jnp.int32)
    d = jnp.where(scores >= cfg.tweak_threshold, TWEAK, d)
    d = jnp.where(scores >= cfg.exact_threshold, EXACT, d)
    return d


def route_cascade(top1, tau, cfg: RouterConfig):
    """Stage-1 decisions at per-row operating points.

    top1 (B,) top-1 similarity, tau (B,) from :func:`threshold_for`.
    EXACT keeps absolute precedence (verbatim hits never cascade); rows
    within ``band/2`` of tau come back :data:`UNCERTAIN` for stage 2.
    ``band == 0`` is statically elided — decisions are then bitwise the
    two-threshold :func:`route` at ``tau``.
    """
    d = jnp.where(top1 >= tau, TWEAK, MISS)
    d = jnp.where(top1 >= cfg.exact_threshold, EXACT, d)
    if cfg.band > 0.0:
        unc = (jnp.abs(top1 - tau) < 0.5 * cfg.band) \
            & (top1 < cfg.exact_threshold)
        d = jnp.where(unc, UNCERTAIN, d)
    return d.astype(jnp.int32)


def stage2_combine(scores, rerank_logits, live, tau, cfg: RouterConfig):
    """Second-stage evidence combine over the (B, K) shortlist.

    ``scores`` are the cosine top-k, ``rerank_logits`` the cross-encoder
    logits over the same candidates, ``live`` the valid-candidate mask
    (padded/-1 shortlist rows excluded), ``tau`` (B,) the operating point.

    * multi-probe agreement: mean over live candidates of
      ``sigmoid((s_j - tau) / probe_temp)`` — how much of the shortlist
      clears the boundary, not just the argmax;
    * reranker evidence: ``sigmoid(max_j logit_j)`` — the best joint-read
      duplicate probability.

    Returns ``(commit (B,) bool, best (B,) int32 shortlist position,
    conf (B,) float32)``; ``best`` maximises the BLENDED per-candidate
    evidence ``w_agree * sigmoid((s_j - tau)/probe_temp) + w_rerank *
    sigmoid(logit_j)`` and may differ from position 0 — that re-selection
    is the misroute recovery.  (Reranker-only argmax re-selects too
    eagerly: on the frontier protocol it flips ~40% of already-correct
    in-band top-1s, the cosine term anchors them.)  Rows with no live
    candidate get conf 0 and never commit.
    """
    nlive = jnp.maximum(jnp.sum(live, axis=1), 1)
    probe = jax.nn.sigmoid((scores - tau[:, None]) / cfg.probe_temp)
    agree = jnp.sum(jnp.where(live, probe, 0.0), axis=1) / nlive
    rr = jnp.where(live, rerank_logits, -jnp.inf)
    evidence = jax.nn.sigmoid(jnp.max(rr, axis=1))
    conf = cfg.w_agree * agree + cfg.w_rerank * evidence
    commit = conf >= cfg.commit_at * (cfg.w_agree + cfg.w_rerank)
    cand = cfg.w_agree * probe + cfg.w_rerank * jax.nn.sigmoid(rr)
    best = jnp.argmax(jnp.where(live, cand, -jnp.inf), axis=1)
    return commit, best.astype(jnp.int32), conf.astype(jnp.float32)


# ------------------------------------------------------------- admission

def admission_admit(adm_ema, adm_count, cluster, cfg: RouterConfig):
    """Per-row admit flag from the PRE-update cluster EMA.

    Rows outside any cluster (``cluster < 0``: flat caches, cold index)
    always admit; a cluster is shut only after ``admit_min`` observations
    put its hit EMA below ``admit_floor``.
    """
    c = jnp.clip(cluster, 0, adm_ema.shape[0] - 1)
    shut = (adm_count[c] >= cfg.admit_min) & (adm_ema[c] < cfg.admit_floor)
    return (cluster < 0) | ~shut


def admission_update(adm_ema, adm_count, cluster, hit, obs,
                     cfg: RouterConfig):
    """Order-independent batched EMA update of the per-cluster hit rate.

    ``cluster``/``hit``/``obs`` are (B,); rows with ``obs`` False (or no
    cluster) contribute nothing.  A batch with ``n_c`` observations of
    cluster c applies the closed form of n_c sequential EMA steps against
    the batch's mean hit rate:

        ema_c <- (1-a)^n_c * ema_c + (1 - (1-a)^n_c) * (hits_c / n_c)

    so the result does not depend on row order within the batch (the
    sharded and local paths must agree bit for bit).
    """
    nclusters = adm_ema.shape[0]
    w = jnp.where(obs & (cluster >= 0), cluster, nclusters)  # OOB -> dropped
    n_c = jnp.zeros((nclusters,), jnp.float32).at[w].add(1.0, mode="drop")
    h_c = jnp.zeros((nclusters,), jnp.float32).at[w].add(
        hit.astype(jnp.float32), mode="drop")
    decay = jnp.power(1.0 - cfg.admit_alpha, n_c)
    mean = h_c / jnp.maximum(n_c, 1.0)
    ema = jnp.where(n_c > 0, decay * adm_ema + (1.0 - decay) * mean, adm_ema)
    count = adm_count + n_c.astype(adm_count.dtype)
    return ema, count


# ------------------------------------------------------------- band stats

def band_edges(cfg: RouterConfig = None):
    """The similarity-band edges for the ACTIVE config.

    The paper's bands (0.7/0.8/0.9/1.0) are the thirds of the hit range
    ``[tweak_threshold, 1]``; deriving them from the config keeps band
    stats attributed correctly when the threshold moves (previously they
    were hardcoded and silently misattributed TWEAK/MISS traffic).  The
    top edge stays 1.01 so sim == 1.0 lands in the last band.
    """
    lo = 0.7 if cfg is None else float(cfg.tweak_threshold)  # hostsync: ok config scalar
    width = max((1.0 - lo) / 3.0, 0.0)
    e = [round(lo + i * width, 9) for i in range(3)]
    return (*e, max(1.01, lo))


def bands_for(cfg: RouterConfig = None):
    """((lo, hi), ...) band intervals for the active config."""
    e = band_edges(cfg)
    return tuple((e[i], e[i + 1]) for i in range(3))


def band_of(scores, cfg: RouterConfig = None):
    """Similarity band index per query: -1 below the tweak threshold,
    else 0/1/2 (config-derived edges; paper bands at the default)."""
    b = jnp.full(scores.shape, -1, jnp.int32)
    for i, (lo, hi) in enumerate(bands_for(cfg)):
        b = jnp.where((scores >= lo) & (scores < hi), i, b)
    return b
