"""Threshold-based routing — the decision layer of TweakLLM (§3.1).

Routes each query by its top-1 cache similarity:
  sim >= exact_threshold  -> EXACT  (return cached response verbatim, §6.1)
  sim >= tweak_threshold  -> TWEAK  (Small LLM refines the cached response)
  otherwise               -> MISS   (Big LLM generates; result is cached)

Also reports the paper's cosine-similarity bands (0.7-0.8, 0.8-0.9,
0.9-1.0) used throughout the evaluation figures.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

MISS, TWEAK, EXACT = 0, 1, 2
BANDS = ((0.7, 0.8), (0.8, 0.9), (0.9, 1.01))


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    tweak_threshold: float = 0.7   # paper Table 1 initial threshold
    exact_threshold: float = 0.9999


def route(scores, cfg: RouterConfig):
    """scores: (B,) top-1 cosine similarity -> decisions (B,) int32."""
    d = jnp.zeros(scores.shape, jnp.int32)
    d = jnp.where(scores >= cfg.tweak_threshold, TWEAK, d)
    d = jnp.where(scores >= cfg.exact_threshold, EXACT, d)
    return d


def band_of(scores):
    """Similarity band index per query: -1 below 0.7, else 0/1/2."""
    b = jnp.full(scores.shape, -1, jnp.int32)
    for i, (lo, hi) in enumerate(BANDS):
        b = jnp.where((scores >= lo) & (scores < hi), i, b)
    return b
