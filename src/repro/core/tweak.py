"""Tweak-prompt construction (paper Appendix A).

Builds the Small LLM's input: instructions + cached prompt + cached
response + current prompt, token-level, with fixed-shape padding so
batched tweak prefills jit cleanly.

The prompt layout is defined ONCE, as ``TWEAK_SEGMENTS`` — an ordered
list of static (byte-identical across every tweak request) and field
(per-request) segments.  The host text path (``build_tweak_text``), the
token paths (``build_tweak_batch`` / ``build_tweak_batch_tokens``) and
the prefill prefix/suffix split (``tweak_prefix_text`` /
``build_tweak_suffix_batch``) are all derived from it, so the prefix
split the KV prefix-cache reuses (DESIGN.md §9) cannot drift from the
text oracle.

Layout choice: the only variable-free run of tokens is the leading
instruction block, so every field segment lives in the suffix — the
suffix is ``[cached_q | cached_r | new_q]`` (with its interleaved static
cues), and the whole instruction prefix is shared KV across every TWEAK
request of a model.

Truncation: ``tokenizer.encode_batch``'s tail truncation used to cut the
trailing ``adapted response :`` cue off over-long prompts — the one
piece of the prompt that tells the Small LLM to start answering.  The
segment-aware encoders instead shave tokens from the *cached response*
field first (then cached query, then the new query); static segments are
never dropped.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.tokenizer import HashWordTokenizer

# Condensed Appendix-A instruction (token budget matters at our scales; the
# full prompt text is reproduced in the paper — semantics preserved).
TWEAK_INSTRUCTION = (
    "you are part of a caching architecture . tailor the cached response to "
    "the current user prompt for relevance accuracy precision and clarity . "
    "do not reference the cached question . reflect the nuances and intent "
    "of the new prompt .")

# The paper appends this to every user query (Table 1, query preprocessing).
QUERY_SUFFIX = " answer briefly"

STATIC = "static"
# Field segments, in the order they appear and the order truncation
# consumes them (see _truncate_fields).
CACHED_QUERY = "cached_query"
CACHED_RESPONSE = "cached_response"
NEW_QUERY = "new_query"

# THE prompt layout.  Segment 0 is static by construction — it is the
# shared prefix whose KV state the serving engine computes once and
# reuses across every TWEAK request (DESIGN.md §9).
TWEAK_SEGMENTS: Tuple[Tuple[str, str], ...] = (
    (STATIC, TWEAK_INSTRUCTION + " cached prompt :"),
    (CACHED_QUERY, ""),
    (STATIC, ". cached response :"),
    (CACHED_RESPONSE, ""),
    (STATIC, ". user's current prompt :"),
    (NEW_QUERY, ""),
    (STATIC, ". adapted response :"),
)

# Truncation priority: cheapest-to-lose first.  The cached response is
# the longest and most redundant field (the Small LLM is rewriting it,
# a trimmed tail still carries the gist); the new query is trimmed last.
TRUNCATE_ORDER = (CACHED_RESPONSE, CACHED_QUERY, NEW_QUERY)


def preprocess_query(text: str) -> str:
    return text.strip() + QUERY_SUFFIX


def tweak_segments(new_query: str, cached_query: str,
                   cached_response: str) -> List[Tuple[str, str]]:
    """The canonical segment list with this request's field values filled."""
    vals = {CACHED_QUERY: cached_query, CACHED_RESPONSE: cached_response,
            NEW_QUERY: new_query}
    return [(kind, vals.get(kind, text)) for kind, text in TWEAK_SEGMENTS]


def tweak_prefix_text() -> str:
    """The static shared prefix — everything before the first field."""
    return TWEAK_SEGMENTS[0][1]


def tweak_prefix_ids(tokenizer: HashWordTokenizer) -> List[int]:
    """Token ids of the shared prefix (BOS included — it opens the prompt)."""
    return tokenizer.encode(tweak_prefix_text(), add_bos=True)


def build_tweak_text(new_query: str, cached_query: str,
                     cached_response: str) -> str:
    return " ".join(text for _, text in
                    tweak_segments(new_query, cached_query, cached_response))


def static_token_count(tokenizer: HashWordTokenizer, *,
                       suffix_only: bool = False) -> int:
    """Tokens the static segments alone occupy — the truncation floor.

    A prompt budget below this cannot produce a well-formed tweak prompt
    (``_truncate_fields`` never drops statics); serving layers validate
    against it up front so the failure surfaces BEFORE any state mutates.
    ``suffix_only`` counts just the post-prefix statics (no BOS).
    """
    segs = TWEAK_SEGMENTS[1:] if suffix_only else TWEAK_SEGMENTS
    n = 0
    first = not suffix_only
    for kind, text in segs:
        if kind != STATIC:
            continue
        n += len(tokenizer.encode(text, add_bos=first))
        first = False
    return n


# ------------------------------------------------------------ token paths

def _truncate_fields(seg_ids: List[Tuple[str, List[int]]],
                     max_len: int) -> List[Tuple[str, List[int]]]:
    """Shave the overflow from field segments, never from statics.

    Fields are trimmed (from their tail) in TRUNCATE_ORDER, so the
    trailing ``adapted response :`` cue always survives.  Raises when the
    static segments alone exceed ``max_len`` — no truncation can produce
    a well-formed prompt then, and silently dropping the cue is exactly
    the bug this replaces.
    """
    overflow = sum(len(ids) for _, ids in seg_ids) - max_len
    if overflow <= 0:
        return seg_ids
    budget = {k: len(ids) for k, ids in seg_ids if k != STATIC}
    for field in TRUNCATE_ORDER:
        if overflow <= 0:
            break
        take = min(budget.get(field, 0), overflow)
        budget[field] -= take
        overflow -= take
    if overflow > 0:
        static_total = sum(len(ids) for k, ids in seg_ids if k == STATIC)
        raise ValueError(
            f"tweak prompt budget {max_len} cannot fit the static prompt "
            f"segments ({static_total} tokens) — raise the budget or lower "
            f"max_new_tokens")
    return [(k, ids if k == STATIC else ids[:budget[k]])
            for k, ids in seg_ids]


def _encode_segments(tokenizer: HashWordTokenizer, segments,
                     add_bos: bool) -> List[Tuple[str, List[int]]]:
    out = []
    for i, (kind, text) in enumerate(segments):
        ids = tokenizer.encode(text, add_bos=add_bos and i == 0)
        out.append((kind, ids))
    return out


def encode_tweak_row(tokenizer: HashWordTokenizer, new_query: str,
                     cached_query: str, cached_response: str, max_len: int,
                     *, drop_prefix: bool = False) -> List[int]:
    """One tweak prompt (or its suffix) as ids, cue-preserving truncation.

    ``drop_prefix=True`` yields only the variable suffix (everything past
    the shared static prefix, no BOS) — the prefill input when the prefix
    KV comes from the prefix cache; prefix ids + suffix ids concatenate
    to exactly the full row.
    """
    segments = tweak_segments(new_query, cached_query, cached_response)
    if drop_prefix:
        segments = segments[1:]
    seg_ids = _encode_segments(tokenizer, segments, add_bos=not drop_prefix)
    seg_ids = _truncate_fields(seg_ids, max_len)
    return [t for _, ids in seg_ids for t in ids]


def _rows_to_batch(rows: Sequence[List[int]], max_len: int,
                   pad_id: int) -> Tuple[np.ndarray, np.ndarray]:
    toks = np.full((len(rows), max_len), pad_id, np.int32)
    mask = np.zeros((len(rows), max_len), np.float32)
    for i, ids in enumerate(rows):
        toks[i, :len(ids)] = ids
        mask[i, :len(ids)] = 1.0
    return toks, mask


def build_tweak_batch(tokenizer: HashWordTokenizer, new_queries: List[str],
                      cached_queries: List[str], cached_responses: List[str],
                      max_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """Full tweak prompts, (B, max_len) fixed shape, cue-preserving."""
    rows = [encode_tweak_row(tokenizer, n, c, r, max_len)
            for n, c, r in zip(new_queries, cached_queries, cached_responses)]
    return _rows_to_batch(rows, max_len, tokenizer.pad)


def build_tweak_suffix_batch(tokenizer: HashWordTokenizer,
                             new_queries: List[str],
                             cached_queries: List[str],
                             cached_responses: List[str],
                             max_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """Variable suffixes only (no BOS): the prefix-cached prefill input."""
    rows = [encode_tweak_row(tokenizer, n, c, r, max_len, drop_prefix=True)
            for n, c, r in zip(new_queries, cached_queries, cached_responses)]
    return _rows_to_batch(rows, max_len, tokenizer.pad)


def encode_static_segments(tokenizer: HashWordTokenizer) -> Tuple[np.ndarray, ...]:
    """Ids of each static segment, in layout order (BOS on the first).

    The companion of ``build_tweak_batch_tokens``: pre-encode once, reuse
    for every jitted batch assembly.
    """
    out = []
    first = True
    for kind, text in TWEAK_SEGMENTS:
        if kind != STATIC:
            continue
        out.append(np.asarray(tokenizer.encode(text, add_bos=first),
                              np.int32))
        first = False
    return tuple(out)


def build_tweak_batch_tokens(static_ids, new_q, new_q_mask, cached_q,
                             cached_q_mask, cached_r, cached_r_mask):
    """Fully-jittable token-level assembly (no text round-trip).

    ``static_ids``: per-static-segment id vectors from
    ``encode_static_segments``; field inputs are fixed-shape (B, L_*)
    token/mask arrays.  Output is the fixed-shape concatenation of every
    segment in ``TWEAK_SEGMENTS`` order — the same layout the text oracle
    produces, by construction.  Padding stays in place (attention masks
    handle it).
    """
    import jax.numpy as jnp
    fields = {CACHED_QUERY: (cached_q, cached_q_mask),
              CACHED_RESPONSE: (cached_r, cached_r_mask),
              NEW_QUERY: (new_q, new_q_mask)}
    b = new_q.shape[0]
    toks, masks = [], []
    static_iter = iter(static_ids)
    for kind, _ in TWEAK_SEGMENTS:
        if kind == STATIC:
            ids = jnp.asarray(next(static_iter), jnp.int32)
            toks.append(jnp.broadcast_to(ids[None, :], (b, ids.shape[0])))
            masks.append(jnp.ones((b, ids.shape[0]), jnp.float32))
        else:
            t, m = fields[kind]
            toks.append(t)
            masks.append(m)
    return jnp.concatenate(toks, axis=1), jnp.concatenate(masks, axis=1)
