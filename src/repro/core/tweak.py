"""Tweak-prompt construction (paper Appendix A).

Builds the Small LLM's input: instructions + current prompt + cached prompt
+ cached response, token-level, with fixed-shape padding so batched tweak
prefills jit cleanly.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.tokenizer import HashWordTokenizer

# Condensed Appendix-A instruction (token budget matters at our scales; the
# full prompt text is reproduced in the paper — semantics preserved).
TWEAK_INSTRUCTION = (
    "you are part of a caching architecture . tailor the cached response to "
    "the current user prompt for relevance accuracy precision and clarity . "
    "do not reference the cached question . reflect the nuances and intent "
    "of the new prompt .")

# The paper appends this to every user query (Table 1, query preprocessing).
QUERY_SUFFIX = " answer briefly"


def preprocess_query(text: str) -> str:
    return text.strip() + QUERY_SUFFIX


def build_tweak_text(new_query: str, cached_query: str, cached_response: str) -> str:
    return (f"{TWEAK_INSTRUCTION} user's current prompt : {new_query} . "
            f"cached prompt : {cached_query} . cached response : "
            f"{cached_response} . adapted response :")


def build_tweak_batch(tokenizer: HashWordTokenizer, new_queries: List[str],
                      cached_queries: List[str], cached_responses: List[str],
                      max_len: int) -> Tuple[np.ndarray, np.ndarray]:
    texts = [build_tweak_text(n, c, r) for n, c, r in
             zip(new_queries, cached_queries, cached_responses)]
    return tokenizer.encode_batch(texts, max_len)


def build_tweak_batch_tokens(instr_tokens, new_q, new_q_mask, cached_q,
                             cached_q_mask, cached_r, cached_r_mask):
    """Fully-jittable token-level assembly (no text round-trip).

    All inputs are fixed-shape (B, L_*) arrays; output is their fixed-shape
    concatenation [instr | cached_q | cached_r | new_q] with combined mask.
    Padding stays in place (attention masks handle it).
    """
    import jax.numpy as jnp
    b = new_q.shape[0]
    instr = jnp.broadcast_to(instr_tokens[None, :], (b, instr_tokens.shape[0]))
    instr_mask = jnp.ones(instr.shape, jnp.float32)
    tokens = jnp.concatenate([instr, cached_q, cached_r, new_q], axis=1)
    mask = jnp.concatenate([instr_mask, cached_q_mask, cached_r_mask,
                            new_q_mask], axis=1)
    return tokens, mask
