"""Sharded semantic-cache lookup: shard_map over the mesh 'data' axis.

The cache's (C, D) embedding bank is row-sharded across data devices (the
TPU-native replacement for Milvus's IVF partitions — see DESIGN.md §3).
Each device scans its local shard with the cosine-top-k kernel, then the
tiny (B, k) per-shard winners are all-gathered and merged to a global
top-k.  Communication: B * k * 8 bytes per shard — microscopic next to the
HBM-bound local scan, so the lookup scales linearly in device count.

Insertion routes an entry to shard ``slot // local_capacity`` (globally
rotating pointer), keeping shards balanced.

The clustered (IVF) index composes with this (DESIGN.md §7): centroids
are replicated, and the member table is row-sharded WITH the bank — each
shard keeps its own (nclusters, bucket) table whose entries are LOCAL
slot ids, so the probe gathers never cross shards.  The table array is
(n_shards * nclusters, bucket) with shard s owning row block s.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.cosine_topk.ops import cosine_topk, cosine_topk_gather
from . import cache as cache_lib
from . import index as index_lib
from . import router as router_lib


def shard_cache_state(state, mesh: Mesh, axis: str = "data"):
    """Places cache buffers row-sharded over ``axis`` (others replicated).

    IVF states must go through :func:`shard_ivf_cache_state` instead —
    the member table needs a layout conversion, not just placement.
    """
    row_sharded = {"emb", "q_tokens", "q_mask", "r_tokens", "r_mask", "valid",
                   "last_used", "hits", "ivf_assign", "ivf_pos"}
    out = {}
    for k, v in state.items():
        spec = P(axis) if k in row_sharded else P()
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def shard_ivf_cache_state(state, mesh: Mesh, cfg: cache_lib.CacheConfig,  # hostsync: ok host-side regroup after init/rebuild, not the hot loop
                          axis: str = "data"):
    """Converts a local-layout IVF cache state to the sharded layout.

    Host-side regroup (called after init/build_index, not in the hot
    loop): each shard's member rows are rebuilt from ``(valid, assign)``
    restricted to its bank rows, with entries rewritten to LOCAL slot
    ids.  Centroids and the pending/overflow scalars replicate; the
    (n_shards * nclusters, bucket) table and the assign/pos back-pointers
    shard over ``axis`` alongside the bank.
    """
    n_shards = mesh.shape[axis]
    assert cfg.capacity % n_shards == 0, (cfg.capacity, n_shards)
    local_c = cfg.capacity // n_shards
    p = index_lib.resolve(cfg)
    # an overflowed table can carry MORE than `bucket` valid rows per
    # cluster (the overflow overwrite leaves duplicates in `assign`);
    # regrouping such a state would have to drop rows and silently break
    # the flat-scan equivalence — demand a rebuild instead
    if bool(state["ivf_overflow"]):
        raise ValueError("IVF member table overflowed; run "
                         "index.build_index(state, cfg) before sharding")
    valid = np.asarray(state["valid"])
    assign = np.asarray(state["ivf_assign"])
    members = np.full((n_shards * p.nclusters, p.bucket), -1, np.int32)
    count = np.zeros((n_shards * p.nclusters,), np.int32)
    pos = np.full((cfg.capacity,), -1, np.int32)
    for r in np.nonzero(valid & (assign >= 0))[0]:
        row = (r // local_c) * p.nclusters + assign[r]
        assert count[row] < p.bucket, \
            (row, "per-shard member row overflow despite table slack")
        members[row, count[row]] = r % local_c
        pos[r] = count[row]
        count[row] += 1
    out = dict(state)
    out["ivf_pos"] = jnp.asarray(pos)
    # drop the stale local-layout table before placement (no point
    # replicating arrays that are replaced right after)
    del out["ivf_members"], out["ivf_count"]
    out = shard_cache_state(out, mesh, axis)
    sh = NamedSharding(mesh, P(axis))
    out["ivf_members"] = jax.device_put(jnp.asarray(members), sh)
    out["ivf_count"] = jax.device_put(jnp.asarray(count), sh)
    return out


def _merge_shard_topk(s, gi, axis: str, n_shards: int, k: int):
    """All-gather the (B, k) per-shard winners and merge to a global top-k."""
    all_s = jax.lax.all_gather(s, axis)                # (n_shards, B, k)
    all_i = jax.lax.all_gather(gi, axis)
    b = s.shape[0]
    flat_s = jnp.moveaxis(all_s, 0, 1).reshape(b, n_shards * k)
    flat_i = jnp.moveaxis(all_i, 0, 1).reshape(b, n_shards * k)
    top_s, sel = jax.lax.top_k(flat_s, k)
    top_i = jnp.take_along_axis(flat_i, sel, axis=1)
    return top_s, top_i


def _flat_shard_lookup(mesh: Mesh, cfg: cache_lib.CacheConfig, axis: str):
    """shard_map'd flat per-shard scan + merge: ``(emb, valid, q) -> (s, i)``."""
    n_shards = mesh.shape[axis]
    assert cfg.capacity % n_shards == 0, (cfg.capacity, n_shards)
    local_c = cfg.capacity // n_shards
    k = cfg.topk

    def local_lookup(emb, valid, q):
        # emb: (local_c, D); q: (B, D) replicated
        s, i = cosine_topk(q, emb, valid, k=k, impl=cfg.lookup_impl,
                           block_n=min(cfg.block_n, local_c))
        shard = jax.lax.axis_index(axis)
        gi = jnp.where(i >= 0, i + shard * local_c, -1)
        return _merge_shard_topk(s, gi, axis, n_shards, k)

    return shard_map(
        local_lookup, mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False)


def _ivf_shard_lookup(mesh: Mesh, cfg: cache_lib.CacheConfig, axis: str):
    """shard_map'd IVF probe + merge over the 7 IVF state arrays + queries."""
    n_shards = mesh.shape[axis]
    assert cfg.capacity % n_shards == 0, (cfg.capacity, n_shards)
    local_c = cfg.capacity // n_shards
    p = index_lib.resolve(cfg)
    k = min(cfg.topk, local_c)

    def local_lookup(emb, valid, members, count, assign, pos, centroids, q):
        # members (nclusters, bucket): this shard's table, LOCAL slot ids
        cand, live = index_lib.candidates(members, count, valid, assign,
                                          pos, centroids, q, p.nprobe)
        s, i = cosine_topk_gather(q, emb, cand, live, k=k,
                                  impl=cfg.lookup_impl,
                                  block_m=min(cfg.block_n, cand.shape[1]))
        shard = jax.lax.axis_index(axis)
        gi = jnp.where(i >= 0, i + shard * local_c, -1)
        top_s, top_i = _merge_shard_topk(s, gi, axis, n_shards, k)
        return top_s, jnp.where(jnp.isfinite(top_s), top_i, -1)

    return shard_map(
        local_lookup, mesh=mesh,
        in_specs=(P(axis),) * 6 + (P(), P()),
        out_specs=(P(), P()),
        check_rep=False)


def _sharded_lookup_call(sm, state, q_embs, ivf: bool):
    """Applies a shard-mapped lookup to the state dict's arrays."""
    if ivf:
        return sm(state["emb"], state["valid"], state["ivf_members"],
                  state["ivf_count"], state["ivf_assign"], state["ivf_pos"],
                  state["ivf_centroids"], q_embs)
    return sm(state["emb"], state["valid"], q_embs)


def make_distributed_lookup(mesh: Mesh, cfg: cache_lib.CacheConfig,
                            axis: str = "data"):
    """Builds a jitted (state, q_embs) -> (scores, idx) sharded lookup."""
    sm = _flat_shard_lookup(mesh, cfg, axis)

    @jax.jit
    def lookup(state, q_embs):
        return _sharded_lookup_call(sm, state, q_embs, ivf=False)

    return lookup


def make_distributed_ivf_lookup(mesh: Mesh, cfg: cache_lib.CacheConfig,
                                axis: str = "data"):
    """Sharded two-stage IVF lookup (state from shard_ivf_cache_state).

    Every shard routes the (replicated) queries through the (replicated)
    centroids — same top-``nprobe`` everywhere — then probes its LOCAL
    member rows and scans only its own bank slots with the gather kernel.
    The (B, k) per-shard winners merge exactly like the flat sharded
    lookup; per-shard scan cost is ``nprobe * bucket`` rows instead of
    ``local_capacity``.
    """
    assert cfg.index == "ivf", "use make_distributed_lookup for flat caches"
    sm = _ivf_shard_lookup(mesh, cfg, axis)

    @jax.jit
    def lookup(state, q_embs):
        return _sharded_lookup_call(sm, state, q_embs, ivf=True)

    return lookup


def make_distributed_lookup_and_touch(mesh: Mesh, cfg: cache_lib.CacheConfig,
                                      router_cfg, axis: str = "data"):
    """Sharded analogue of :func:`repro.core.cache.lookup_route_touch`.

    One jitted device call per serve batch, exactly like the local fused
    path (DESIGN.md §5): the shard-mapped scan (flat or IVF per
    ``cfg.index``) merges per-shard winners to a replicated global top-k,
    and everything downstream — the calibrated cascade routing, the
    hit-accounting scatter, and the admission EMA — is the SAME
    ``cache.route_touch_core`` the local path runs, applied AFTER the
    all_gather merge on replicated (B, k) winners.  That ordering is what
    keeps sharded and local routing decision-identical: the cascade only
    ever sees the merged global shortlist, never per-shard partial top-k
    (stage 2 likewise runs post-merge, see ``SharedCacheBank``).  The
    touch scatters land on the row-sharded arrays with replicated indices
    — GSPMD routes each update to the owning shard — while the admission
    arrays replicate (identical update everywhere).  State is donated for
    in-place update.
    """
    ivf = cfg.index == "ivf"
    sm = (_ivf_shard_lookup if ivf else _flat_shard_lookup)(mesh, cfg, axis)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def lookup_touch(state, q_embs, cost):
        scores, idx = _sharded_lookup_call(sm, state, q_embs, ivf=ivf)
        new, decisions, tau, cluster, admit = cache_lib.route_touch_core(
            state, cfg, router_cfg, q_embs, scores, idx, cost)
        return new, scores, idx, decisions, tau, cluster, admit

    return lookup_touch


def make_distributed_insert(mesh: Mesh, cfg: cache_lib.CacheConfig,
                            axis: str = "data"):
    """Jitted ring-buffer insert against the sharded state (FIFO policy)."""
    # the single-entry path has no sharded IVF maintenance — refuse loudly
    # rather than silently filing nothing in the member table
    assert cfg.index != "ivf", \
        "use make_distributed_insert_batch for IVF caches"

    @jax.jit
    def insert(state, emb, q_tokens, q_mask, r_tokens, r_mask):
        return cache_lib.insert(state, cfg, emb, q_tokens, q_mask,
                                r_tokens, r_mask)

    return insert


def make_distributed_insert_batch(mesh: Mesh, cfg: cache_lib.CacheConfig,
                                  axis: str = "data"):
    """Batched sharded FIFO insert, shard-routed by global slot.

    The globally rotating ring pointer assigns entry i the slot
    ``(ptr + i) % capacity``; shard ``slot // local_capacity`` owns it —
    the same row partitioning the sharded lookup scans.  Each shard
    receives the (replicated, fixed-shape) entry batch, keeps only its own
    rows, and scatters them locally: no cross-shard traffic at all, and
    one dispatch for the whole batch.

    Returns a jitted ``(state, embs, qt, qm, rt, rm, count) ->
    (new_state, slots)`` with the same semantics as
    :func:`repro.core.cache.insert_batch` (padding rows >= count ignored,
    slots[i] = -1 for padding).
    """
    assert cfg.policy == "fifo", "sharded insert_batch is FIFO-only"
    n_shards = mesh.shape[axis]
    assert cfg.capacity % n_shards == 0, (cfg.capacity, n_shards)
    local_c = cfg.capacity // n_shards
    ivf = cfg.index == "ivf"

    def local_insert(emb_buf, qt_buf, qm_buf, rt_buf, rm_buf, valid,
                     last_used, hits, ptr, clock, size,
                     embs, qt, qm, rt, rm, count, *ivf_bufs):
        shard = jax.lax.axis_index(axis)
        row = jnp.arange(embs.shape[0], dtype=jnp.int32)
        gslot, keep, active = cache_lib._fifo_batch_plan(
            ptr, row, count, cfg.capacity)
        mine = keep & (gslot // local_c == shard)
        lslot = (gslot % local_c).astype(jnp.int32)
        w = jnp.where(mine, lslot, local_c)            # OOB -> dropped
        embs = jax.vmap(cache_lib._normalize)(embs)
        upd = lambda buf, val: buf.at[w].set(val.astype(buf.dtype),
                                             mode="drop")
        out = (upd(emb_buf, embs), upd(qt_buf, qt), upd(qm_buf, qm),
               upd(rt_buf, rt), upd(rm_buf, rm),
               valid.at[w].set(True, mode="drop"),
               last_used.at[w].set(clock + row, mode="drop"),
               hits.at[w].set(0, mode="drop"),
               ptr + count, clock + count,
               jnp.minimum(size + count, cfg.capacity),
               jnp.where(active, gslot, -1))
        if not ivf:
            return out
        # file this shard's rows in its LOCAL member table; only the
        # owning shard appends, so divergent fallback choices can't race
        state_ivf = dict(zip(index_lib.IVF_KEYS, ivf_bufs))
        pending_in = state_ivf["ivf_pending"]
        cn = index_lib.nearest_clusters(state_ivf["ivf_centroids"], embs)

        def step(carry, x):
            c_near, ls, on = x
            return index_lib.file_row(carry, c_near, ls, on), None

        state_ivf, _ = jax.lax.scan(step, state_ivf, (cn, lslot, mine))
        # pending/overflow are replicated scalars: count ALL kept rows
        # (identical everywhere) and pmax the local overflow flags
        state_ivf["ivf_pending"] = \
            pending_in + jnp.sum(keep.astype(jnp.int32))
        state_ivf["ivf_overflow"] = jax.lax.pmax(
            state_ivf["ivf_overflow"].astype(jnp.int32), axis) > 0
        return out + tuple(state_ivf[k] for k in index_lib.IVF_KEYS)

    n_ivf = len(index_lib.IVF_KEYS) if ivf else 0
    # centroids + pending + overflow replicate; table + back-ptrs shard
    ivf_in = (P(), P(axis), P(axis), P(axis), P(axis), P(), P())[:n_ivf]
    sm = shard_map(
        local_insert, mesh=mesh,
        in_specs=(P(axis),) * 8 + (P(),) * 3 + (P(),) * 6 + ivf_in,
        out_specs=(P(axis),) * 8 + (P(),) * 4 + ivf_in,
        check_rep=False)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def insert_batch(state, embs, q_tokens, q_mask, r_tokens, r_mask,
                     count):
        count = jnp.minimum(jnp.asarray(count, jnp.int32), embs.shape[0])
        res = sm(
            state["emb"], state["q_tokens"], state["q_mask"],
            state["r_tokens"], state["r_mask"], state["valid"],
            state["last_used"], state["hits"],
            state["ptr"], state["clock"], state["size"],
            embs, q_tokens, q_mask, r_tokens, r_mask, count,
            *((state[k] for k in index_lib.IVF_KEYS) if ivf else ()))
        (emb, qt, qm, rt, rm, valid, last_used, hits,
         ptr, clock, size, slots) = res[:12]
        new = dict(state)
        new.update(emb=emb, q_tokens=qt, q_mask=qm, r_tokens=rt, r_mask=rm,
                   valid=valid, last_used=last_used, hits=hits,
                   ptr=ptr, clock=clock, size=size)
        new.update(zip(index_lib.IVF_KEYS, res[12:]))
        return new, slots

    return insert_batch
