"""Sharded semantic-cache lookup: shard_map over the mesh 'data' axis.

The cache's (C, D) embedding bank is row-sharded across data devices (the
TPU-native replacement for Milvus's IVF partitions — see DESIGN.md §3).
Each device scans its local shard with the cosine-top-k kernel, then the
tiny (B, k) per-shard winners are all-gathered and merged to a global
top-k.  Communication: B * k * 8 bytes per shard — microscopic next to the
HBM-bound local scan, so the lookup scales linearly in device count.

Insertion routes an entry to shard ``slot // local_capacity`` (globally
rotating pointer), keeping shards balanced.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.cosine_topk.ops import cosine_topk
from . import cache as cache_lib


def shard_cache_state(state, mesh: Mesh, axis: str = "data"):
    """Places cache buffers row-sharded over ``axis`` (others replicated)."""
    row_sharded = {"emb", "q_tokens", "q_mask", "r_tokens", "r_mask", "valid",
                   "last_used", "hits"}
    out = {}
    for k, v in state.items():
        spec = P(axis) if k in row_sharded else P()
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def make_distributed_lookup(mesh: Mesh, cfg: cache_lib.CacheConfig,
                            axis: str = "data"):
    """Builds a jitted (state, q_embs) -> (scores, idx) sharded lookup."""
    n_shards = mesh.shape[axis]
    assert cfg.capacity % n_shards == 0, (cfg.capacity, n_shards)
    local_c = cfg.capacity // n_shards
    k = cfg.topk

    def local_lookup(emb, valid, q):
        # emb: (local_c, D); q: (B, D) replicated
        s, i = cosine_topk(q, emb, valid, k=k, impl=cfg.lookup_impl,
                           block_n=min(cfg.block_n, local_c))
        shard = jax.lax.axis_index(axis)
        gi = jnp.where(i >= 0, i + shard * local_c, -1)
        # all-gather the (B,k) winners from every shard and merge
        all_s = jax.lax.all_gather(s, axis)            # (n_shards, B, k)
        all_i = jax.lax.all_gather(gi, axis)
        b = q.shape[0]
        flat_s = jnp.moveaxis(all_s, 0, 1).reshape(b, n_shards * k)
        flat_i = jnp.moveaxis(all_i, 0, 1).reshape(b, n_shards * k)
        top_s, pos = jax.lax.top_k(flat_s, k)
        top_i = jnp.take_along_axis(flat_i, pos, axis=1)
        return top_s, top_i

    sm = shard_map(
        local_lookup, mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_rep=False)

    @jax.jit
    def lookup(state, q_embs):
        return sm(state["emb"], state["valid"], q_embs)

    return lookup


def make_distributed_insert(mesh: Mesh, cfg: cache_lib.CacheConfig,
                            axis: str = "data"):
    """Jitted ring-buffer insert against the sharded state (FIFO policy)."""

    @jax.jit
    def insert(state, emb, q_tokens, q_mask, r_tokens, r_mask):
        return cache_lib.insert(state, cfg, emb, q_tokens, q_mask,
                                r_tokens, r_mask)

    return insert


def make_distributed_insert_batch(mesh: Mesh, cfg: cache_lib.CacheConfig,
                                  axis: str = "data"):
    """Batched sharded FIFO insert, shard-routed by global slot.

    The globally rotating ring pointer assigns entry i the slot
    ``(ptr + i) % capacity``; shard ``slot // local_capacity`` owns it —
    the same row partitioning the sharded lookup scans.  Each shard
    receives the (replicated, fixed-shape) entry batch, keeps only its own
    rows, and scatters them locally: no cross-shard traffic at all, and
    one dispatch for the whole batch.

    Returns a jitted ``(state, embs, qt, qm, rt, rm, count) ->
    (new_state, slots)`` with the same semantics as
    :func:`repro.core.cache.insert_batch` (padding rows >= count ignored,
    slots[i] = -1 for padding).
    """
    assert cfg.policy == "fifo", "sharded insert_batch is FIFO-only"
    n_shards = mesh.shape[axis]
    assert cfg.capacity % n_shards == 0, (cfg.capacity, n_shards)
    local_c = cfg.capacity // n_shards

    def local_insert(emb_buf, qt_buf, qm_buf, rt_buf, rm_buf, valid,
                     last_used, hits, ptr, clock, size,
                     embs, qt, qm, rt, rm, count):
        shard = jax.lax.axis_index(axis)
        row = jnp.arange(embs.shape[0], dtype=jnp.int32)
        gslot, keep, active = cache_lib._fifo_batch_plan(
            ptr, row, count, cfg.capacity)
        mine = keep & (gslot // local_c == shard)
        w = jnp.where(mine, gslot % local_c, local_c)  # OOB -> dropped
        embs = jax.vmap(cache_lib._normalize)(embs)
        upd = lambda buf, val: buf.at[w].set(val.astype(buf.dtype),
                                             mode="drop")
        out = (upd(emb_buf, embs), upd(qt_buf, qt), upd(qm_buf, qm),
               upd(rt_buf, rt), upd(rm_buf, rm),
               valid.at[w].set(True, mode="drop"),
               last_used.at[w].set(clock + row, mode="drop"),
               hits.at[w].set(0, mode="drop"),
               ptr + count, clock + count,
               jnp.minimum(size + count, cfg.capacity),
               jnp.where(active, gslot, -1))
        return out

    sm = shard_map(
        local_insert, mesh=mesh,
        in_specs=(P(axis),) * 8 + (P(),) * 3 + (P(),) * 6,
        out_specs=(P(axis),) * 8 + (P(),) * 4,
        check_rep=False)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def insert_batch(state, embs, q_tokens, q_mask, r_tokens, r_mask,
                     count):
        count = jnp.minimum(jnp.asarray(count, jnp.int32), embs.shape[0])
        (emb, qt, qm, rt, rm, valid, last_used, hits,
         ptr, clock, size, slots) = sm(
            state["emb"], state["q_tokens"], state["q_mask"],
            state["r_tokens"], state["r_mask"], state["valid"],
            state["last_used"], state["hits"],
            state["ptr"], state["clock"], state["size"],
            embs, q_tokens, q_mask, r_tokens, r_mask, count)
        new = dict(state)
        new.update(emb=emb, q_tokens=qt, q_mask=qm, r_tokens=rt, r_mask=rm,
                   valid=valid, last_used=last_used, hits=hits,
                   ptr=ptr, clock=clock, size=size)
        return new, slots

    return insert_batch
