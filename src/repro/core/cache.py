"""Functional semantic vector cache — the TweakLLM vector DB.

Fixed-capacity, fully JAX (fixed shapes, jit-safe): unit-norm embeddings,
token buffers for cached query/response texts, validity mask, and an
insertion policy.  The paper ships append-only (== ring/FIFO here, which is
append-only until capacity); LRU and LFU are implemented as the
§6.2 "cache eviction policies" extension.

Lookup dispatches to the Pallas ``cosine_topk`` kernel (TPU target) or its
XLA reference; ``repro.core.distributed`` wraps it in shard_map for the
sharded production cache.

Write path (DESIGN.md §5): ``insert`` is the one-entry reference;
``insert_batch`` commits a whole miss batch in a single jitted step (fixed
shapes + a traced ``count``, so one compile serves every batch bucket) and
``lookup_and_touch`` fuses lookup, routing, and hit accounting so a serve
batch costs one host↔device round-trip instead of one per entry.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.cosine_topk.ops import cosine_topk

from . import index as index_lib
from . import router as router_lib

POLICIES = ("fifo", "lru", "lfu")
INDEXES = ("flat", "ivf")

# admission-control state (IVF caches): per-cluster hit EMA + observation
# count.  Deliberately NOT part of index.IVF_KEYS — the arrays replicate
# in the sharded layout (updated identically everywhere from replicated
# routing results), so the shard-routed insert specs never see them.
ADM_KEYS = ("adm_ema", "adm_count")


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    capacity: int = 4096
    dim: int = 384
    max_query_tokens: int = 64
    max_response_tokens: int = 256
    policy: str = "fifo"
    topk: int = 4
    lookup_impl: str = "xla"  # xla | pallas
    block_n: int = 1024
    # clustered (IVF) index — DESIGN.md §7.  0 = auto-resolve from capacity
    # (see index.resolve): nclusters ~ capacity/128 (capped 2048), bucket
    # = ceil(capacity/nclusters) with 2x slack.
    index: str = "flat"       # flat | ivf
    nclusters: int = 0
    nprobe: int = 8
    ivf_bucket: int = 0
    reindex_every: int = 0    # writes between k-means rebuilds (0 = auto)
    kmeans_iters: int = 10


def init_cache(cfg: CacheConfig):
    c = cfg.capacity
    state = {
        "emb": jnp.zeros((c, cfg.dim), jnp.float32),
        "q_tokens": jnp.zeros((c, cfg.max_query_tokens), jnp.int32),
        "q_mask": jnp.zeros((c, cfg.max_query_tokens), jnp.float32),
        "r_tokens": jnp.zeros((c, cfg.max_response_tokens), jnp.int32),
        "r_mask": jnp.zeros((c, cfg.max_response_tokens), jnp.float32),
        "valid": jnp.zeros((c,), bool),
        "ptr": jnp.zeros((), jnp.int32),          # ring pointer (fifo)
        "last_used": jnp.zeros((c,), jnp.int32),  # lru clock
        "hits": jnp.zeros((c,), jnp.int32),       # lfu counter
        "clock": jnp.zeros((), jnp.int32),
        "size": jnp.zeros((), jnp.int32),
    }
    if cfg.index == "ivf":
        state.update(index_lib.init_ivf(cfg))
        state.update(init_admission(cfg))
    return state


def init_admission(cfg: CacheConfig):
    """Fresh per-cluster admission state: optimistic (every cluster admits
    until ``admit_min`` observations say otherwise)."""
    p = index_lib.resolve(cfg)
    return {
        "adm_ema": jnp.ones((p.nclusters,), jnp.float32),
        "adm_count": jnp.zeros((p.nclusters,), jnp.int32),
    }


def _victim_slot(state, cfg: CacheConfig):
    full = state["size"] >= cfg.capacity
    if cfg.policy == "fifo":
        return state["ptr"] % cfg.capacity
    score = jnp.where(state["valid"],
                      state["last_used"] if cfg.policy == "lru" else state["hits"],
                      -1)
    evict = jnp.argmin(jnp.where(state["valid"], score, jnp.iinfo(jnp.int32).max))
    return jnp.where(full, evict.astype(jnp.int32), state["ptr"] % cfg.capacity)


def _normalize(emb):
    return emb / jnp.maximum(jnp.linalg.norm(emb), 1e-8)


def _fifo_batch_plan(ptr, row, count, capacity: int):
    """Slot plan for a FIFO batch: (slots, keep, active).

    Entry i lands at ring slot ``(ptr + i) % capacity``; when the batch
    laps the ring the later duplicate must win, so row i is dropped when
    row ``i + capacity`` is also active.  Shared by the local and sharded
    insert_batch so the semantics cannot drift.
    """
    active = row < count
    slots = (ptr + row) % capacity
    keep = active & (row + capacity >= count)
    return slots, keep, active


def insert(state, cfg: CacheConfig, emb, q_tokens, q_mask, r_tokens, r_mask):
    """Insert ONE entry (emb (D,), tokens already padded to cfg lengths)."""
    slot = _victim_slot(state, cfg)
    emb = _normalize(emb)
    upd = lambda buf, val: buf.at[slot].set(val.astype(buf.dtype))
    new = dict(state)
    new["emb"] = upd(state["emb"], emb)
    new["q_tokens"] = upd(state["q_tokens"], q_tokens)
    new["q_mask"] = upd(state["q_mask"], q_mask)
    new["r_tokens"] = upd(state["r_tokens"], r_tokens)
    new["r_mask"] = upd(state["r_mask"], r_mask)
    new["valid"] = state["valid"].at[slot].set(True)
    new["last_used"] = state["last_used"].at[slot].set(state["clock"])
    new["hits"] = state["hits"].at[slot].set(0)
    new["ptr"] = state["ptr"] + 1
    new["clock"] = state["clock"] + 1
    new["size"] = jnp.minimum(state["size"] + 1, cfg.capacity)
    if cfg.index == "ivf":
        new.update(index_lib.append_one(
            {k: new[k] for k in index_lib.IVF_KEYS}, emb,
            slot.astype(jnp.int32), jnp.asarray(True)))
    return new


def insert_batch(state, cfg: CacheConfig, embs, q_tokens, q_mask,
                 r_tokens, r_mask, count=None):
    """Insert up to B entries in one fused device step.

    embs (B, D); q_tokens/q_mask (B, max_query_tokens); r_tokens/r_mask
    (B, max_response_tokens).  Rows at index >= ``count`` are padding and
    are ignored — ``count`` is a traced scalar, so one compiled artifact
    serves every batch bucket of the same padded shape B.

    State-equivalent to B sequential :func:`insert` calls for all three
    policies.  Returns ``(new_state, slots)`` where ``slots`` (B,) int32
    holds the ring/victim slot each active row landed in (-1 for padding).

    FIFO places rows at consecutive ring slots, so victim selection is a
    single vectorized scatter.  LRU/LFU victims depend on every preceding
    insert in the batch, so those run as an on-device ``lax.scan`` — still
    a single dispatch, no per-entry host sync.
    """
    b = embs.shape[0]
    # clamp so ptr/clock/size never advance past the rows actually written
    count = jnp.minimum(jnp.asarray(b if count is None else count, jnp.int32), b)
    embs = jax.vmap(_normalize)(embs)
    row = jnp.arange(b, dtype=jnp.int32)
    active = row < count

    if cfg.policy == "fifo":
        # scatter target `capacity` is out-of-bounds; mode="drop" discards it
        slots, keep, active = _fifo_batch_plan(state["ptr"], row, count,
                                               cfg.capacity)
        w = jnp.where(keep, slots, cfg.capacity)
        upd = lambda buf, val: buf.at[w].set(val.astype(buf.dtype), mode="drop")
        new = dict(state)
        new["emb"] = upd(state["emb"], embs)
        new["q_tokens"] = upd(state["q_tokens"], q_tokens)
        new["q_mask"] = upd(state["q_mask"], q_mask)
        new["r_tokens"] = upd(state["r_tokens"], r_tokens)
        new["r_mask"] = upd(state["r_mask"], r_mask)
        new["valid"] = state["valid"].at[w].set(True, mode="drop")
        new["last_used"] = state["last_used"].at[w].set(
            state["clock"] + row, mode="drop")
        new["hits"] = state["hits"].at[w].set(0, mode="drop")
        new["ptr"] = state["ptr"] + count
        new["clock"] = state["clock"] + count
        new["size"] = jnp.minimum(state["size"] + count, cfg.capacity)
        if cfg.index == "ivf":
            # lapped duplicates (keep=False) were dropped from the buffers,
            # so they must not be filed in the member table either
            new = index_lib.update_batch(new, cfg, embs,
                                         jnp.where(keep, slots, -1))
        return new, jnp.where(active, slots, -1)

    # nearest-centroid routing hoisted to one (B, nclusters) GEMM; only
    # the table filing itself needs to stay sequential in the scan
    cn = index_lib.nearest_clusters(state["ivf_centroids"], embs) \
        if cfg.index == "ivf" else jnp.zeros((b,), jnp.int32)

    def step(carry, x):
        emb_i, qt_i, qm_i, rt_i, rm_i, on, cn_i = x
        slot = _victim_slot(carry, cfg)
        w = jnp.where(on, slot, cfg.capacity)  # OOB -> dropped when padding
        upd = lambda buf, val: buf.at[w].set(val.astype(buf.dtype), mode="drop")
        new = dict(carry)
        new["emb"] = upd(carry["emb"], emb_i)
        new["q_tokens"] = upd(carry["q_tokens"], qt_i)
        new["q_mask"] = upd(carry["q_mask"], qm_i)
        new["r_tokens"] = upd(carry["r_tokens"], rt_i)
        new["r_mask"] = upd(carry["r_mask"], rm_i)
        new["valid"] = carry["valid"].at[w].set(True, mode="drop")
        new["last_used"] = carry["last_used"].at[w].set(carry["clock"],
                                                        mode="drop")
        new["hits"] = carry["hits"].at[w].set(0, mode="drop")
        inc = on.astype(jnp.int32)
        new["ptr"] = carry["ptr"] + inc
        new["clock"] = carry["clock"] + inc
        new["size"] = jnp.minimum(carry["size"] + inc, cfg.capacity)
        if cfg.index == "ivf":
            new.update(index_lib.file_row(
                {k: new[k] for k in index_lib.IVF_KEYS}, cn_i,
                slot.astype(jnp.int32), on))
        return new, jnp.where(on, slot, -1)

    return jax.lax.scan(
        step, dict(state),
        (embs, q_tokens, q_mask, r_tokens, r_mask, active, cn))


def make_insert_batch(cfg: CacheConfig, donate: bool = True):
    """Jit-compiled ``(state, embs, qt, qm, rt, rm, count) -> (state, slots)``.

    Cache buffers are donated so the update happens in place on device —
    the caller must drop its reference to the input state.
    """
    fn = lambda state, embs, qt, qm, rt, rm, count: insert_batch(
        state, cfg, embs, qt, qm, rt, rm, count)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def lookup(state, cfg: CacheConfig, q_embs):
    """q_embs (B, D) unit vectors -> (scores (B,k), indices (B,k)).

    ``cfg.index`` picks the scan: "flat" brute-forces the whole bank,
    "ivf" probes the top-``nprobe`` clusters of the member table
    (DESIGN.md §7; identical results at ``nprobe == nclusters``).
    """
    if cfg.index == "ivf":
        return index_lib.lookup(state, cfg, q_embs)
    k = min(cfg.topk, cfg.capacity)
    return cosine_topk(q_embs, state["emb"], state["valid"], k=k,
                       impl=cfg.lookup_impl, block_n=min(cfg.block_n, cfg.capacity))


def touch(state, cfg: CacheConfig, indices):
    """Record cache hits for LRU/LFU accounting.  indices: (B,) top-1 hits.

    A -1 index (empty/all-invalid cache, or a padded row) must be a
    no-op: raw negative indices WRAP in jax scatters, so an unguarded
    ``.at[-1]`` would silently touch the LAST slot and corrupt LRU/LFU
    ordering.  Route them out of bounds and drop, like lookup_and_touch.
    """
    indices = jnp.asarray(indices)
    w = jnp.where(indices >= 0, indices, cfg.capacity)
    new = dict(state)
    new["last_used"] = state["last_used"].at[w].set(state["clock"], mode="drop")
    new["hits"] = state["hits"].at[w].add(1, mode="drop")
    new["clock"] = state["clock"] + 1
    return new


def lookup_and_touch(state, cfg: CacheConfig,
                     router_cfg: "router_lib.RouterConfig", q_embs):
    """Fused lookup + routing + hit accounting (one device round-trip).

    Every row routed EXACT or TWEAK touches its top-1 entry (updating
    ``last_used``/``hits`` exactly like :func:`touch` on the hit subset),
    so LRU/LFU see every hit — including the EXACT fast path.

    Returns ``(new_state, scores (B,k), indices (B,k), decisions (B,))``.
    """
    scores, idx = lookup(state, cfg, q_embs)
    decisions = router_lib.route(scores[:, 0], router_cfg)
    top1 = idx[:, 0]
    hit = (decisions != router_lib.MISS) & (top1 >= 0)
    w = jnp.where(hit, top1, cfg.capacity)  # OOB -> dropped for misses
    new = dict(state)
    new["last_used"] = state["last_used"].at[w].set(state["clock"], mode="drop")
    new["hits"] = state["hits"].at[w].add(1, mode="drop")
    new["clock"] = state["clock"] + 1
    return new, scores, idx, decisions


def route_touch_core(state, cfg: CacheConfig, router_cfg, q_embs, scores,
                     idx, cost):
    """Post-lookup stage-1 core, shared by the local and sharded fused
    paths (so their routing/accounting semantics cannot drift).

    Routes the merged top-k through the calibrated cascade
    (``router.route_cascade`` at the per-row operating points), touches
    only rows COMMITTED as hits (UNCERTAIN rows wait for stage 2), and —
    for IVF caches — reads the per-cluster admission flag and folds the
    batch's certain outcomes into the admission EMA.

    Returns ``(new_state, decisions, tau, cluster, admit)``.
    """
    tau = router_lib.threshold_for(cost, router_cfg)
    decisions = router_lib.route_cascade(scores[:, 0], tau, router_cfg)
    top1 = idx[:, 0]
    hit = ((decisions == router_lib.TWEAK)
           | (decisions == router_lib.EXACT)) & (top1 >= 0)
    w = jnp.where(hit, top1, cfg.capacity)  # OOB -> dropped for misses
    new = dict(state)
    new["last_used"] = state["last_used"].at[w].set(state["clock"],
                                                    mode="drop")
    new["hits"] = state["hits"].at[w].add(1, mode="drop")
    new["clock"] = state["clock"] + 1
    b = scores.shape[0]
    if cfg.index == "ivf":
        # centroids are replicated in the sharded layout, so the cluster
        # ids (and everything downstream) agree between sharded and local
        # routing.  A cold index (zero centroids) files everything under
        # cluster 0 — harmless: the EMA starts optimistic.
        cluster = index_lib.nearest_clusters(state["ivf_centroids"], q_embs)
        admit = router_lib.admission_admit(
            state["adm_ema"], state["adm_count"], cluster, router_cfg)
        certain = decisions != router_lib.UNCERTAIN
        ema, cnt = router_lib.admission_update(
            state["adm_ema"], state["adm_count"], cluster, hit, certain,
            router_cfg)
        new["adm_ema"], new["adm_count"] = ema, cnt
    else:
        cluster = jnp.full((b,), -1, jnp.int32)
        admit = jnp.ones((b,), bool)
    return new, decisions, tau, cluster, admit


def lookup_route_touch(state, cfg: CacheConfig, router_cfg, q_embs, cost):
    """Fused stage-1 of the calibrated cascade (one device round-trip).

    Like :func:`lookup_and_touch`, plus: per-request ``cost`` (B,) picks
    each row's operating point, rows near the boundary come back
    ``router.UNCERTAIN`` (untouched — stage 2 commits them), and IVF
    caches surface the query's cluster id and admission flag.

    Returns ``(new_state, scores (B,k), indices (B,k), decisions (B,),
    tau (B,), cluster (B,), admit (B,) bool)``.
    """
    scores, idx = lookup(state, cfg, q_embs)
    new, decisions, tau, cluster, admit = route_touch_core(
        state, cfg, router_cfg, q_embs, scores, idx, cost)
    return new, scores, idx, decisions, tau, cluster, admit


def make_second_stage(cfg: CacheConfig, router_cfg, rr_params, rr_cfg,
                      donate: bool = True):
    """Builds the jitted stage-2 resolver for UNCERTAIN rows.

    ``(state, q_tokens, q_mask, scores, idx, decisions, tau, cluster) ->
    (new_state, final_decisions, slot (B,), conf (B,))``

    Gathers the shortlist candidates' cached query tokens, scores them
    with the cross-encoder reranker against the live query, and combines
    reranker evidence with multi-probe top-k agreement
    (``router.stage2_combine``) to commit TWEAK or MISS.  The serving
    ``slot`` for committed rows is the RERANKER argmax candidate, not
    necessarily the top-1 cosine neighbour — the misroute recovery.
    Committed rows are touched here (stage 1 skipped them; the clock
    ticks once more for the batch) and uncertain outcomes fold into the
    admission EMA.  Works unchanged on sharded states: the token gather
    and touch scatters run in the GSPMD region with replicated indices.
    """
    from repro.models import reranker as rr_lib

    def second_stage(state, q_tokens, q_mask, scores, idx, decisions, tau,
                     cluster):
        live = idx >= 0
        safe = jnp.clip(idx, 0, cfg.capacity - 1)
        cand_t = jnp.take(state["q_tokens"], safe, axis=0)   # (B, K, S)
        cand_m = jnp.take(state["q_mask"], safe, axis=0) \
            * live[..., None].astype(state["q_mask"].dtype)
        rr = rr_lib.score_shortlist(rr_params, q_tokens, q_mask,
                                    cand_t, cand_m, rr_cfg)
        commit, best, conf = router_lib.stage2_combine(
            scores, rr, live, tau, router_cfg)
        unc = decisions == router_lib.UNCERTAIN
        final = jnp.where(
            unc, jnp.where(commit, router_lib.TWEAK, router_lib.MISS),
            decisions).astype(jnp.int32)
        chosen = jnp.take_along_axis(idx, best[:, None], axis=1)[:, 0]
        slot = jnp.where(unc & commit, chosen, idx[:, 0])
        touch = unc & commit & (slot >= 0)
        w = jnp.where(touch, slot, cfg.capacity)
        new = dict(state)
        new["last_used"] = state["last_used"].at[w].set(state["clock"],
                                                        mode="drop")
        new["hits"] = state["hits"].at[w].add(1, mode="drop")
        new["clock"] = state["clock"] + 1
        if cfg.index == "ivf":
            ema, cnt = router_lib.admission_update(
                state["adm_ema"], state["adm_count"], cluster, commit, unc,
                router_cfg)
            new["adm_ema"], new["adm_count"] = ema, cnt
        return new, final, slot, conf

    return jax.jit(second_stage, donate_argnums=(0,) if donate else ())


def fetch(state, indices):
    """Gather cached (q_tokens, q_mask, r_tokens, r_mask) rows for indices (B,)."""
    g = lambda buf: jnp.take(buf, indices, axis=0)
    return g(state["q_tokens"]), g(state["q_mask"]), g(state["r_tokens"]), g(state["r_mask"])
