"""Functional semantic vector cache — the TweakLLM vector DB.

Fixed-capacity, fully JAX (fixed shapes, jit-safe): unit-norm embeddings,
token buffers for cached query/response texts, validity mask, and an
insertion policy.  The paper ships append-only (== ring/FIFO here, which is
append-only until capacity); LRU and LFU are implemented as the
§6.2 "cache eviction policies" extension.

Lookup dispatches to the Pallas ``cosine_topk`` kernel (TPU target) or its
XLA reference; ``repro.core.distributed`` wraps it in shard_map for the
sharded production cache.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.cosine_topk.ops import cosine_topk

POLICIES = ("fifo", "lru", "lfu")


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    capacity: int = 4096
    dim: int = 384
    max_query_tokens: int = 64
    max_response_tokens: int = 256
    policy: str = "fifo"
    topk: int = 4
    lookup_impl: str = "xla"  # xla | pallas
    block_n: int = 1024


def init_cache(cfg: CacheConfig):
    c = cfg.capacity
    return {
        "emb": jnp.zeros((c, cfg.dim), jnp.float32),
        "q_tokens": jnp.zeros((c, cfg.max_query_tokens), jnp.int32),
        "q_mask": jnp.zeros((c, cfg.max_query_tokens), jnp.float32),
        "r_tokens": jnp.zeros((c, cfg.max_response_tokens), jnp.int32),
        "r_mask": jnp.zeros((c, cfg.max_response_tokens), jnp.float32),
        "valid": jnp.zeros((c,), bool),
        "ptr": jnp.zeros((), jnp.int32),          # ring pointer (fifo)
        "last_used": jnp.zeros((c,), jnp.int32),  # lru clock
        "hits": jnp.zeros((c,), jnp.int32),       # lfu counter
        "clock": jnp.zeros((), jnp.int32),
        "size": jnp.zeros((), jnp.int32),
    }


def _victim_slot(state, cfg: CacheConfig):
    full = state["size"] >= cfg.capacity
    if cfg.policy == "fifo":
        return state["ptr"] % cfg.capacity
    score = jnp.where(state["valid"],
                      state["last_used"] if cfg.policy == "lru" else state["hits"],
                      -1)
    evict = jnp.argmin(jnp.where(state["valid"], score, jnp.iinfo(jnp.int32).max))
    return jnp.where(full, evict.astype(jnp.int32), state["ptr"] % cfg.capacity)


def insert(state, cfg: CacheConfig, emb, q_tokens, q_mask, r_tokens, r_mask):
    """Insert ONE entry (emb (D,), tokens already padded to cfg lengths)."""
    slot = _victim_slot(state, cfg)
    emb = emb / jnp.maximum(jnp.linalg.norm(emb), 1e-8)
    upd = lambda buf, val: buf.at[slot].set(val.astype(buf.dtype))
    new = dict(state)
    new["emb"] = upd(state["emb"], emb)
    new["q_tokens"] = upd(state["q_tokens"], q_tokens)
    new["q_mask"] = upd(state["q_mask"], q_mask)
    new["r_tokens"] = upd(state["r_tokens"], r_tokens)
    new["r_mask"] = upd(state["r_mask"], r_mask)
    new["valid"] = state["valid"].at[slot].set(True)
    new["last_used"] = state["last_used"].at[slot].set(state["clock"])
    new["hits"] = state["hits"].at[slot].set(0)
    new["ptr"] = state["ptr"] + 1
    new["clock"] = state["clock"] + 1
    new["size"] = jnp.minimum(state["size"] + 1, cfg.capacity)
    return new


def lookup(state, cfg: CacheConfig, q_embs):
    """q_embs (B, D) unit vectors -> (scores (B,k), indices (B,k))."""
    k = min(cfg.topk, cfg.capacity)
    return cosine_topk(q_embs, state["emb"], state["valid"], k=k,
                       impl=cfg.lookup_impl, block_n=min(cfg.block_n, cfg.capacity))


def touch(state, cfg: CacheConfig, indices):
    """Record cache hits for LRU/LFU accounting.  indices: (B,) top-1 hits."""
    new = dict(state)
    new["last_used"] = state["last_used"].at[indices].set(state["clock"])
    new["hits"] = state["hits"].at[indices].add(1)
    new["clock"] = state["clock"] + 1
    return new


def fetch(state, indices):
    """Gather cached (q_tokens, q_mask, r_tokens, r_mask) rows for indices (B,)."""
    g = lambda buf: jnp.take(buf, indices, axis=0)
    return g(state["q_tokens"]), g(state["q_mask"]), g(state["r_tokens"]), g(state["r_mask"])
