"""Architecture registry: ``--arch <id>`` resolves here.

Each module exports CONFIG (exact assigned spec), SMOKE_CONFIG (reduced
same-family variant for CPU tests) and SKIP_SHAPES (shape -> reason).
"""
from __future__ import annotations

import importlib
from typing import Dict

_MODULES = {
    "whisper-tiny": "whisper_tiny",
    "qwen2.5-3b": "qwen2_5_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "internvl2-26b": "internvl2_26b",
    "arctic-480b": "arctic_480b",
    "mamba2-130m": "mamba2_130m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "nemotron-4-340b": "nemotron_4_340b",
    # paper's own model pair (not part of the assigned 10)
    "llama-3.1-8b": "llama31_8b",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "llama-3.1-8b")


def get_arch(arch_id: str):
    """Returns the config module for an architecture id."""
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str, smoke: bool = False):
    mod = get_arch(arch_id)
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def skip_reason(arch_id: str, shape: str):
    return get_arch(arch_id).SKIP_SHAPES.get(shape)
