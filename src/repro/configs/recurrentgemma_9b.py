"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 1:2. [arXiv:2402.19427]

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.
Pattern (RG-LRU, RG-LRU, local-attn): 12 periods + (RG, RG) remainder.
Local window 2048 + recurrent state -> sub-quadratic -> runs long_500k.
"""
from repro.models.config import ModelConfig, RGLRU, LOCAL_ATTN

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN), sliding_window=2048,
    rnn_width=4096, mlp_type="swiglu", norm_type="rmsnorm",
    max_seq_len=524_288 + 8, dtype="bfloat16", remat=True, train_microbatches=4,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=5, d_model=128, num_heads=4, num_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512, sliding_window=16, rnn_width=128,
    max_seq_len=128, dtype="float32", remat=False)

SKIP_SHAPES = {}
