"""arctic-480b [moe] — 128 experts top-2 + parallel dense residual.
[hf:Snowflake/snowflake-arctic-base]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Arctic's dense-MoE hybrid: a small dense FFN runs in parallel (residual)
with the MoE per layer.  Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, MOE

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000, block_pattern=(MOE,),
    num_experts=128, experts_per_token=2, moe_d_ff=4864,
    moe_dense_residual=True, capacity_factor=2.0,
    mlp_type="swiglu", norm_type="rmsnorm",
    max_seq_len=32768 + 8, dtype="bfloat16", remat=True, train_microbatches=8,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512, num_experts=4, experts_per_token=2, moe_d_ff=96,
    max_seq_len=128, dtype="float32", remat=False)

SKIP_SHAPES = {"long_500k": "full-attention MoE"}
