"""deepseek-coder-33b [dense] — llama-arch GQA. [arXiv:2401.14196]

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
56 heads don't divide the 16-way model axis -> embed-dim TP fallback
(see launch/sharding.py).  Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=19200, vocab_size=32256, block_pattern=(ATTN,),
    mlp_type="swiglu", norm_type="rmsnorm", rope_theta=100_000.0,
    max_seq_len=32768 + 8, dtype="bfloat16", remat=True, train_microbatches=16,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, max_seq_len=128, dtype="float32", remat=False)

SKIP_SHAPES = {"long_500k": "full-attention dense"}
