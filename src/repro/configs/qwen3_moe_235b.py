"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family]

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per-expert) vocab=151936,
MoE 128e top-8, no shared/dense expert.  Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, MOE

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936, block_pattern=(MOE,),
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
    capacity_factor=1.25, mlp_type="swiglu", norm_type="rmsnorm",
    rope_theta=1_000_000.0, max_seq_len=32768 + 8,
    dtype="bfloat16", remat=True, train_microbatches=8,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512, num_experts=4, experts_per_token=2, moe_d_ff=96,
    max_seq_len=128, dtype="float32", remat=False)

SKIP_SHAPES = {"long_500k": "full-attention MoE"}
