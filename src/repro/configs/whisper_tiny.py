"""whisper-tiny [audio] — enc-dec, conv frontend stubbed. [arXiv:2212.04356]

4L d_model=384 6H (GQA kv=6) d_ff=1536 vocab=51865.  The mel-spectrogram +
conv feature extractor is the allowed stub: input_specs supplies 1500
post-conv frame embeddings.  Decoder is full-attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, enc_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, block_pattern=(ATTN,),
    mlp_type="gelu", norm_type="layernorm", qkv_bias=True,
    enc_frames=1500, frontend="audio_stub", frontend_dim=384,
    max_seq_len=524_288 + 8, dtype="bfloat16", tie_embeddings=True,
    remat=True, train_microbatches=4,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, enc_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, enc_frames=16, frontend_dim=128,
    max_seq_len=128, dtype="float32", remat=False, train_microbatches=1)

SKIP_SHAPES = {"long_500k": "full-attention enc-dec decoder"}
