"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP. [arXiv:2402.16819]

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
Largest dense config: remat + microbatching are mandatory for train_4k.
Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000, block_pattern=(ATTN,),
    mlp_type="squared_relu", norm_type="layernorm",
    max_seq_len=32768 + 8, dtype="bfloat16", remat=True, train_microbatches=16,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=192, num_heads=8, num_kv_heads=2, head_dim=24,
    d_ff=768, vocab_size=512, max_seq_len=128, dtype="float32", remat=False)

SKIP_SHAPES = {"long_500k": "full-attention dense"}
