"""qwen2.5-3b [dense] — GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B family]

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936.
Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936, block_pattern=(ATTN,),
    qkv_bias=True, mlp_type="swiglu", norm_type="rmsnorm",
    rope_theta=1_000_000.0, max_seq_len=32768 + 8,
    dtype="bfloat16", remat=True, train_microbatches=4,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
    vocab_size=512, max_seq_len=128, dtype="float32", remat=False)

SKIP_SHAPES = {"long_500k": "full-attention dense"}
