"""internvl2-26b [vlm] — InternViT frontend (stub) + InternLM2 decoder.
[arXiv:2404.16821]

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.  The InternViT-6B
vision encoder + MLP projector are the allowed stub: input_specs supplies
256 patch embeddings (dim 3200, InternViT hidden) which the built-in
projector maps into the decoder.  Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553, block_pattern=(ATTN,),
    mlp_type="swiglu", norm_type="rmsnorm",
    frontend="vision_stub", num_prefix_tokens=256, frontend_dim=3200,
    max_seq_len=32768 + 264, dtype="bfloat16", remat=True, train_microbatches=8,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, num_prefix_tokens=8, frontend_dim=64,
    max_seq_len=160, dtype="float32", remat=False)

SKIP_SHAPES = {"long_500k": "full-attention dense decoder"}
