"""llama-3.1-8b [dense] — the paper's Small LLM (Table 1). [Meta AI 2024]

Not one of the 10 assigned architectures; included because TweakLLM's own
configuration pairs it (as the tweaker) with a frontier Big LLM.
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="llama-3.1-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, block_pattern=(ATTN,),
    mlp_type="swiglu", norm_type="rmsnorm", rope_theta=500_000.0,
    max_seq_len=32768 + 8, dtype="bfloat16", remat=True,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, max_seq_len=128, dtype="float32", remat=False)

SKIP_SHAPES = {"long_500k": "full-attention dense"}
