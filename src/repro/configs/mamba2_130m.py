"""mamba2-130m [ssm] — SSD, attention-free. [arXiv:2405.21060]

24L d_model=768 d_ff=0 vocab=50280, ssm_state=128, head_dim 64, expand 2.
O(1) decode state -> runs long_500k natively.
"""
from repro.models.config import ModelConfig, MAMBA2

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=1, num_kv_heads=1, d_ff=0,
    vocab_size=50280, block_pattern=(MAMBA2,),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    norm_type="rmsnorm", max_seq_len=524_288 + 8,
    dtype="bfloat16", tie_embeddings=True, train_microbatches=2,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=128, vocab_size=512, ssm_state=16, ssm_head_dim=32,
    ssm_chunk=16, max_seq_len=128, dtype="float32")

SKIP_SHAPES = {}
