"""h2o-danube-1.8b [dense] — llama+mistral mix with SWA. [arXiv:2401.16818]

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, sliding window 4096.
Windowed KV cache -> sub-quadratic decode -> runs long_500k.
"""
from repro.models.config import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=80,
    d_ff=6912, vocab_size=32000, block_pattern=(ATTN,),
    sliding_window=4096, mlp_type="swiglu", norm_type="rmsnorm",
    max_seq_len=524_288 + 8, dtype="bfloat16", remat=True, train_microbatches=4,
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, sliding_window=16, max_seq_len=128,
    dtype="float32", remat=False)

SKIP_SHAPES = {}
