"""Serving launcher: a TweakLLM deployment on synthetic chat traffic.

Builds the full stack (embedder + big + small + sharded-capable cache +
router), replays a Zipfian arrival trace through the continuous-batching
scheduler (DESIGN.md §6: queue -> coalesce -> dedup -> dispatch), and
reports the paper's §5.2.3 economics — hit-rate split, token volumes,
cost vs all-Big baseline — plus the scheduler's coalescing stats.

  PYTHONPATH=src python -m repro.launch.serve --queries 200 --profile lmsys
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core import CacheConfig, RouterConfig, TweakLLMEngine
from repro.data import WorkloadGenerator
from repro.models import ModelConfig, build_model
from repro.models.embedder import tiny_embedder_config, init_embedder
from repro.serving import (GenerateConfig, Generator, SamplerConfig,
                           Scheduler, SchedulerConfig, SimClock,
                           poisson_trace, replay_trace)
from repro.tokenizer import HashWordTokenizer
from repro.training.embedder_train import train_embedder


def build_engine(*, vocab: int = 8192, threshold: float = 0.7,
                 capacity: int = 4096, train_embedder_steps: int = 60,
                 policy: str = "fifo", lookup_impl: str = "xla",
                 index: str = "flat", nclusters: int = 0, nprobe: int = 8,
                 seed: int = 0):
    tok = HashWordTokenizer(vocab)
    ecfg = tiny_embedder_config(vocab)
    eparams = init_embedder(jax.random.PRNGKey(seed), ecfg)
    if train_embedder_steps:
        eparams, _ = train_embedder(eparams, ecfg, tok,
                                    steps=train_embedder_steps, batch=16)
    big_cfg = ModelConfig(name="big", num_layers=4, d_model=128, num_heads=8,
                          num_kv_heads=4, d_ff=256, vocab_size=vocab,
                          max_seq_len=1024, dtype="float32")
    # The small (tweak) model uses fixed-block flash attention so the
    # engine's shared-prefix KV reuse applies on every TWEAK hit
    # (DESIGN.md §9) — naive/auto softmax would disqualify it from the
    # byte-identical prefix-prefill contract.
    small_cfg = big_cfg.replace(name="small", num_layers=2, d_model=64,
                                num_heads=4, num_kv_heads=2, d_ff=128,
                                attention_impl="xla_flash",
                                flash_block_q=32, flash_block_k=32)
    big_m, small_m = build_model(big_cfg), build_model(small_cfg)
    gen_cfg = GenerateConfig(max_new_tokens=16,
                             sampler=SamplerConfig(vocab_size=vocab))
    big = Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gen_cfg)  # seed: ok demo CLI, fixed init for reproducibility
    small = Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gen_cfg)  # seed: ok demo CLI, fixed init for reproducibility
    return TweakLLMEngine(
        tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
        big=big, small=small,
        cache_cfg=CacheConfig(capacity=capacity, dim=ecfg.d_model,
                              policy=policy, lookup_impl=lookup_impl,
                              index=index, nclusters=nclusters,
                              nprobe=nprobe),
        router_cfg=RouterConfig(tweak_threshold=threshold))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8,
                    help="scheduler max_batch (unique queries per dispatch)")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="simulated arrival rate (requests/s)")
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="scheduler coalescing deadline (simulated s)")
    ap.add_argument("--profile", default="lmsys", choices=["lmsys", "wildchat"])
    ap.add_argument("--threshold", type=float, default=0.7)
    ap.add_argument("--policy", default="fifo", choices=["fifo", "lru", "lfu"])
    ap.add_argument("--index", default="flat", choices=["flat", "ivf"],
                    help="cache lookup index (ivf = clustered, DESIGN.md §7)")
    ap.add_argument("--embedder-steps", type=int, default=60)
    args = ap.parse_args()

    print("building TweakLLM stack (training embedder contrastively)...")
    eng = build_engine(threshold=args.threshold, policy=args.policy,
                       index=args.index,
                       train_embedder_steps=args.embedder_steps)
    wl = WorkloadGenerator(profile=args.profile, seed=0)  # seed: ok demo CLI, reproducible trace
    texts = [q.text for q in wl.sample(args.queries)]
    trace = poisson_trace(texts, args.rate, seed=0)  # seed: ok demo CLI, reproducible trace
    sched = Scheduler(
        eng, SchedulerConfig(max_wait=args.max_wait, max_batch=args.batch,
                             max_new_tokens=8),
        clock=SimClock())
    t0 = time.time()
    done = replay_trace(sched, trace)
    dt = time.time() - t0
    # shedding (QueueFull) is a designed outcome under overload, not a bug
    assert len(done) == len(texts) - sched.stats.rejected

    s, ss = eng.stats, sched.stats
    print(f"\n== TweakLLM serving report ({args.profile} profile) ==")
    print(f"requests: {ss.completed}  ({dt/max(ss.completed,1)*1e3:.1f} "
          f"ms/request wall on CPU)")
    print(f"scheduler: batches={ss.batches} mean_batch={ss.mean_batch:.1f} "
          f"dedup_joined={ss.joined} rejected={ss.rejected}")
    print(f"routing: miss={s.miss} tweak={s.tweak} exact={s.exact} "
          f"hit_rate={s.hit_rate:.2%} (+{ss.joined} joined in flight)")
    print(f"tokens:  big={s.big_tokens} small={s.small_tokens}")
    print(f"cost:    {s.cost:,.0f} vs all-big {s.baseline_cost:,.0f} "
          f"-> {s.cost/max(s.baseline_cost,1):.2%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
