"""Serving launcher: a TweakLLM deployment on synthetic chat traffic.

Builds the full stack (embedder + big + small + sharded-capable cache +
router), replays a Zipfian arrival trace through the continuous-batching
scheduler (DESIGN.md §6: queue -> coalesce -> dedup -> dispatch), and
reports the paper's §5.2.3 economics — hit-rate split, token volumes,
cost vs all-Big baseline — plus the scheduler's coalescing stats.

  PYTHONPATH=src python -m repro.launch.serve --queries 200 --profile lmsys
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core import (CacheConfig, ReplicaGroup, RouterConfig,
                        TweakLLMEngine)
from repro.data import WorkloadGenerator
from repro.launch.mesh import make_cache_mesh
from repro.models import ModelConfig, build_model
from repro.models.embedder import tiny_embedder_config, init_embedder
from repro.models.reranker import tiny_reranker_config, init_reranker
from repro.serving import (GenerateConfig, Generator, ReplicaScheduler,
                           SamplerConfig, Scheduler, SchedulerConfig,
                           SimClock, poisson_trace, replay_trace)
from repro.tokenizer import HashWordTokenizer
from repro.training.embedder_train import train_embedder
from repro.training.reranker_train import train_reranker


def build_stack(*, vocab: int = 8192, capacity: int = 4096,
                train_embedder_steps: int = 60, policy: str = "fifo",
                lookup_impl: str = "xla", index: str = "flat",
                nclusters: int = 0, nprobe: int = 8, threshold: float = 0.7,
                band: float = 0.0, train_reranker_steps: int = 120,
                admit_floor: float = 0.0, seed: int = 0):
    """Shared model stack + configs for one engine or a replica group.

    ``band > 0`` turns on the router cascade (DESIGN.md §13): the stack
    then also builds + trains the cross-encoder reranker the second
    stage scores shortlists with, returned under the ``reranker`` key
    that ``TweakLLMEngine`` / ``ReplicaGroup.build`` accept.
    """
    tok = HashWordTokenizer(vocab)
    ecfg = tiny_embedder_config(vocab)
    eparams = init_embedder(jax.random.PRNGKey(seed), ecfg)
    if train_embedder_steps:
        eparams, _ = train_embedder(eparams, ecfg, tok,
                                    steps=train_embedder_steps, batch=16)
    big_cfg = ModelConfig(name="big", num_layers=4, d_model=128, num_heads=8,
                          num_kv_heads=4, d_ff=256, vocab_size=vocab,
                          max_seq_len=1024, dtype="float32")
    # The small (tweak) model uses fixed-block flash attention so the
    # engine's shared-prefix KV reuse applies on every TWEAK hit
    # (DESIGN.md §9) — naive/auto softmax would disqualify it from the
    # byte-identical prefix-prefill contract.
    small_cfg = big_cfg.replace(name="small", num_layers=2, d_model=64,
                                num_heads=4, num_kv_heads=2, d_ff=128,
                                attention_impl="xla_flash",
                                flash_block_q=32, flash_block_k=32)
    big_m, small_m = build_model(big_cfg), build_model(small_cfg)
    gen_cfg = GenerateConfig(max_new_tokens=16,
                             sampler=SamplerConfig(vocab_size=vocab))
    big = Generator(big_m, big_m.init(jax.random.PRNGKey(1)), gen_cfg)  # seed: ok demo CLI, fixed init for reproducibility
    small = Generator(small_m, small_m.init(jax.random.PRNGKey(2)), gen_cfg)  # seed: ok demo CLI, fixed init for reproducibility
    cache_cfg = CacheConfig(capacity=capacity, dim=ecfg.d_model,
                            policy=policy, lookup_impl=lookup_impl,
                            index=index, nclusters=nclusters, nprobe=nprobe)
    stack = dict(tokenizer=tok, embedder_params=eparams, embedder_cfg=ecfg,
                 big=big, small=small, cache_cfg=cache_cfg,
                 router_cfg=RouterConfig(tweak_threshold=threshold,
                                         band=band, admit_floor=admit_floor))
    if band > 0.0:
        rr_cfg = tiny_reranker_config(vocab)
        rr_params = init_reranker(jax.random.PRNGKey(seed + 3), rr_cfg)
        if train_reranker_steps:
            rr_params, _ = train_reranker(rr_params, rr_cfg, tok,
                                          steps=train_reranker_steps)
        stack["reranker"] = (rr_params, rr_cfg)
    return stack


def build_engine(**kw):
    return TweakLLMEngine(**build_stack(**kw))


def build_replica_group(n: int, *, shared: bool = True,
                        cache_shards: int = 0, **kw) -> ReplicaGroup:
    """``n`` replicas over one shared bank (model weights replicated —
    the Generators are shared handles, so compiled functions are too).
    ``cache_shards > 1`` row-shards the bank over that many devices."""
    stack = build_stack(**kw)
    mesh = make_cache_mesh(cache_shards) if cache_shards > 1 else None
    return ReplicaGroup.build(n, shared=shared, mesh=mesh, **stack)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8,
                    help="scheduler max_batch (unique queries per dispatch)")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="simulated arrival rate (requests/s)")
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="scheduler coalescing deadline (simulated s)")
    ap.add_argument("--profile", default="lmsys", choices=["lmsys", "wildchat"])
    ap.add_argument("--threshold", type=float, default=0.7)
    ap.add_argument("--cost-threshold", type=float, default=None,
                    help="routing operating point in [0,1] applied to every "
                         "request (DESIGN.md §13); default: the router's "
                         "calibrated default cost")
    ap.add_argument("--band", type=float, default=0.0,
                    help="uncertainty band width around the TWEAK/MISS "
                         "boundary; > 0 enables the reranker second stage")
    ap.add_argument("--reranker-steps", type=int, default=120,
                    help="training steps for the cascade reranker "
                         "(only used when --band > 0)")
    ap.add_argument("--admit-floor", type=float, default=0.0,
                    help="suppress cache inserts for IVF clusters whose "
                         "hit EMA falls below this (0 = admit everything)")
    ap.add_argument("--policy", default="fifo", choices=["fifo", "lru", "lfu"])
    ap.add_argument("--index", default="flat", choices=["flat", "ivf"],
                    help="cache lookup index (ivf = clustered, DESIGN.md §7)")
    ap.add_argument("--embedder-steps", type=int, default=60)
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas over ONE shared cache bank "
                         "(DESIGN.md §12)")
    ap.add_argument("--cache-shards", type=int, default=0,
                    help="row-shard the shared bank over this many devices "
                         "(needs forced host devices on CPU; 0 = local)")
    ap.add_argument("--private-caches", action="store_true",
                    help="give each replica a private bank (the degraded "
                         "baseline the replica bench compares against)")
    args = ap.parse_args()

    print("building TweakLLM stack (training embedder contrastively)...")
    kw = dict(threshold=args.threshold, policy=args.policy, index=args.index,
              train_embedder_steps=args.embedder_steps, band=args.band,
              train_reranker_steps=args.reranker_steps,
              admit_floor=args.admit_floor)
    scfg = SchedulerConfig(max_wait=args.max_wait, max_batch=args.batch,
                           max_new_tokens=8,
                           cost_threshold=args.cost_threshold)
    if args.replicas > 1 or args.cache_shards > 1:
        group = build_replica_group(args.replicas,
                                    shared=not args.private_caches,
                                    cache_shards=args.cache_shards, **kw)
        sched = ReplicaScheduler(group.engines, scfg, clock=SimClock())
        stats_src = group
    else:
        eng = build_engine(**kw)
        sched = Scheduler(eng, scfg, clock=SimClock())
        stats_src = eng
    wl = WorkloadGenerator(profile=args.profile, seed=0)  # seed: ok demo CLI, reproducible trace
    texts = [q.text for q in wl.sample(args.queries)]
    trace = poisson_trace(texts, args.rate, seed=0)  # seed: ok demo CLI, reproducible trace
    t0 = time.time()
    done = replay_trace(sched, trace)
    dt = time.time() - t0
    # shedding (QueueFull) is a designed outcome under overload, not a bug
    assert len(done) == len(texts) - sched.stats.rejected

    s, ss = stats_src.stats, sched.stats
    print(f"\n== TweakLLM serving report ({args.profile} profile) ==")
    print(f"requests: {ss.completed}  ({dt/max(ss.completed,1)*1e3:.1f} "
          f"ms/request wall on CPU)")
    print(f"scheduler: batches={ss.batches} mean_batch={ss.mean_batch:.1f} "
          f"dedup_joined={ss.joined} rejected={ss.rejected}")
    if args.replicas > 1:
        lanes = " ".join(
            f"r{i}:{lane.dispatched}d/{lane.batches}b+{lane.stolen_in}st"
            for i, lane in enumerate(sched.lanes))
        print(f"replicas: {args.replicas} "
              f"({'shared' if not args.private_caches else 'private'} bank, "
              f"shards={max(args.cache_shards, 1)}) {lanes} "
              f"stolen={ss.stolen}")
    print(f"routing: miss={s.miss} tweak={s.tweak} exact={s.exact} "
          f"hit_rate={s.hit_rate:.2%} (+{ss.joined} joined in flight)")
    if args.band > 0 or args.admit_floor > 0:
        print(f"cascade: uncertain={s.uncertain} recovered={s.recovered} "
              f"suppressed_inserts={s.suppressed_inserts} "
              f"(band={args.band} cost="
              f"{args.cost_threshold if args.cost_threshold is not None else 'default'})")
    print(f"tokens:  big={s.big_tokens} small={s.small_tokens}")
    print(f"cost:    {s.cost:,.0f} vs all-big {s.baseline_cost:,.0f} "
          f"-> {s.cost/max(s.baseline_cost,1):.2%} of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
