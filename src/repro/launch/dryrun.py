"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

For each combination this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the right step (train_step / prefill_step / serve_step) with
     FSDPxTP in_shardings against ShapeDtypeStruct inputs (no allocation),
  3. compiles, records memory_analysis() + cost_analysis() + collective
     bytes parsed from the post-SPMD HLO,
  4. writes experiments/dryrun/<arch>__<shape>__<mesh>.json.

Running as a script forces a 512-device host platform via XLA_FLAGS —
:func:`_force_host_device_count` runs first thing in :func:`main`, which
still precedes the first jax device init because jax initializes its
backend lazily (the device count locks at first use, not at import).
IMPORTING this module never touches the environment, so test helpers
(``collective_bytes``, ``_shape_bytes``) are safe to use anywhere.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""
import argparse
import os


def _force_host_device_count(n: int = 512) -> None:
    """Fake an ``n``-device host platform (call BEFORE first jax use)."""
    flag = f"--xla_force_host_platform_device_count={n}"
    flags = os.environ.get("XLA_FLAGS", "")
    if flag not in flags:
        os.environ["XLA_FLAGS"] = (flags + " " + flag).strip()
import json
import re
import time
import traceback
from typing import Any, Dict

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, skip_reason
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (INPUT_SHAPES, abstract_params, decode_inputs,
                                 make_step_fn, prefill_inputs, train_inputs)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, loop_trip_count: int = 1) -> Dict[str, int]:
    """Per-device collective bytes from post-SPMD HLO.

    XLA HLO lists a while-loop body ONCE regardless of trip count, so
    collectives inside loop bodies (the scan-over-layers!) are scaled by
    ``loop_trip_count`` (= pattern periods for the layer scan).  Bodies are
    identified via the ``body=%name`` operands of while ops.
    """
    bodies = set(re.findall(r"body=%?([\w.\-]+)", hlo_text))
    out: Dict[str, int] = {}
    cur: str = ""
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():
            m = re.match(r"%?([\w.\-]+)\s*(?:\(|=)", line.replace("ENTRY ", ""))
            cur = m.group(1) if m else ""
        m = _COLL_RE.search(line)
        if m:
            mult = loop_trip_count if cur in bodies else 1
            kind = m.group(2)
            out[kind] = out.get(kind, 0) + _shape_bytes(m.group(1)) * mult
    return out


def _mem_analysis(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "host_argument_size_in_bytes",
              "host_output_size_in_bytes", "host_temp_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" in k.lower())}


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            microbatches: int = 0, donate: bool = True,
            zero1: bool = False, grad_sync_once: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    microbatches = microbatches or cfg.train_microbatches
    # microbatches must divide the per-device batch slice
    _, _batch, _kind = INPUT_SHAPES[shape_name]
    if _kind == "train":
        per_dev = max(_batch // (16 * (2 if multi_pod else 1)), 1)
        microbatches = max(1, min(microbatches, per_dev))
        while per_dev % microbatches:
            microbatches -= 1
    seq, batch, kind = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "kind": kind,
        "seq_len": seq, "global_batch": batch,
        "params_exact": cfg.param_count(),
        "params_active": cfg.param_count(active_only=True),
    }
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    t0 = time.time()
    params_abs = abstract_params(cfg)
    p_mode = "serve" if (kind == "decode"
                         or ((zero1 or grad_sync_once) and kind == "train")) \
        else "train"
    p_specs = shd.param_specs(mesh, params_abs, mode=p_mode)

    with jax.set_mesh(mesh):
        if kind == "train":
            from repro.training import init_opt_state
            opt_abs = jax.eval_shape(init_opt_state, params_abs)
            inputs = train_inputs(cfg, seq, batch)
            b_specs = shd.batch_spec(mesh, cfg)
            if grad_sync_once:
                from repro.launch.zero_trainer import make_zero_train_step
                from repro.models.model import build_model
                from repro.training import AdamWConfig
                step = make_zero_train_step(build_model(cfg), AdamWConfig(),
                                            mesh, microbatches=microbatches)
                o_specs = shd.opt_state_specs(mesh, opt_abs, p_specs)
            else:
                step = make_step_fn(cfg, "train", microbatches=microbatches)
                o_base = shd.param_specs(mesh, params_abs) if zero1 else p_specs
                o_specs = shd.opt_state_specs(mesh, opt_abs, o_base)
            jitted = jax.jit(
                step,
                in_shardings=(p_specs, o_specs, b_specs),
                out_shardings=(p_specs, o_specs, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_abs, opt_abs, inputs)
        elif kind == "prefill":
            step = make_step_fn(cfg, "prefill")
            b_specs = {k: v for k, v in shd.batch_spec(mesh, cfg).items()}
            inputs = prefill_inputs(cfg, seq, batch)
            b_specs = {k: b_specs[k] for k in inputs}
            cache_abs = jax.eval_shape(step, params_abs, inputs)[1]
            c_specs = shd.cache_specs(mesh, cache_abs, batch)
            jitted = jax.jit(step, in_shardings=(p_specs, b_specs),
                             out_shardings=(None, c_specs))
            lowered = jitted.lower(params_abs, inputs)
        else:  # decode
            step = make_step_fn(cfg, "decode")
            inputs = decode_inputs(cfg, seq, batch)
            c_specs = shd.cache_specs(mesh, inputs["caches"], batch)
            t_spec = shd.token_spec(mesh, batch)
            jitted = jax.jit(
                step, in_shardings=(p_specs, t_spec, c_specs),
                out_shardings=(None, c_specs),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_abs, inputs["token"], inputs["caches"])
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    rec["memory"] = _mem_analysis(compiled)
    if kind == "train" and cfg.remat and cfg.dtype == "bfloat16":
        # Known XLA:CPU artifact (see EXPERIMENTS.md §Dry-run): the bwd loop's
        # elementwise reads of remat-saved bf16 residuals are emulated via
        # f32, and XLA hoists the convert across the whole stacked buffer —
        # an f32 shadow copy (2x the bf16 stack) that native-bf16 TPUs don't
        # allocate.  Reported so the roofline can quote adjusted memory.
        dshard = 16  # data axis
        b_local = max(batch // (dshard * (2 if multi_pod else 1)), 1)
        stack = cfg.pattern_periods * b_local * seq * cfg.d_model
        rec["memory"]["cpu_f32_shadow_bytes_est"] = int(stack * 4)
    rec["cost"] = _cost_analysis(compiled)
    try:
        hlo = compiled.as_text()
        trips = max(cfg.pattern_periods, cfg.num_layers if cfg.enc_layers else 1,
                    1) * max(microbatches, 1)
        rec["collectives"] = collective_bytes(hlo, loop_trip_count=trips)
        rec["collectives_body_once"] = collective_bytes(hlo, loop_trip_count=1)
        rec["loop_trip_count"] = trips
        rec["hlo_lines"] = hlo.count("\n")
    except Exception as e:  # pragma: no cover
        rec["collectives"] = {"error": str(e)}
    rec["status"] = "ok"
    return rec


def out_path(arch: str, shape: str, mesh_name: str, out_dir: str = None) -> str:
    d = out_dir or OUT_DIR
    os.makedirs(d, exist_ok=True)
    safe = arch.replace("/", "_")
    return os.path.join(d, f"{safe}__{shape}__{mesh_name}.json")


def main():
    # must precede the first jax device use in this process (the lazy
    # backend init locks the device count)
    _force_host_device_count()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = use each config's train_microbatches")
    ap.add_argument("--out-dir", default=None,
                    help="write records here (hillclimb variants) instead of "
                         "experiments/dryrun")
    ap.add_argument("--zero1", action="store_true",
                    help="ZeRO-1: params TP-resident, optimizer FSDP-sharded "
                         "(kills per-microbatch weight re-gathers)")
    ap.add_argument("--grad-sync-once", action="store_true",
                    help="shard_map local grad accumulation, one psum/step")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                path = out_path(arch, shape, mesh_name, args.out_dir)
                if args.skip_existing and os.path.exists(path):
                    print(f"[cached] {arch} {shape} {mesh_name}")
                    continue
                print(f"[dryrun] {arch} {shape} {mesh_name} ...", flush=True)
                try:
                    rec = run_one(arch, shape, multi,
                                  microbatches=args.microbatches,
                                  zero1=args.zero1,
                                  grad_sync_once=args.grad_sync_once)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "fail", "error": str(e),
                           "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "fail"
                msg = rec.get("reason", rec.get("error", ""))
                extra = ""
                if st == "ok":
                    mem = rec.get("memory", {})
                    tot = sum(mem.get(k, 0) for k in
                              ("argument_size_in_bytes", "temp_size_in_bytes",
                               "output_size_in_bytes"))
                    extra = (f" flops/dev={rec['cost'].get('flops', 0):.3e}"
                             f" mem/dev={tot/2**30:.2f}GiB"
                             f" lower={rec['lower_s']}s compile={rec['compile_s']}s")
                print(f"[{st}] {arch} {shape} {mesh_name} {msg}{extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
