"""Assigned input shapes + ShapeDtypeStruct stand-ins for the dry-run.

``input_specs(cfg, shape_name)`` returns (step_kind, abstract inputs): no
device allocation ever happens — everything is jax.ShapeDtypeStruct /
jax.eval_shape, per the multi-pod dry-run contract.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import build_model

INPUT_SHAPES = {
    # name: (seq_len, global_batch, kind)
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_inputs(cfg: ModelConfig, seq: int, batch: int) -> Dict[str, Any]:
    out = {
        "tokens": _sds((batch, seq), jnp.int32),
        "targets": _sds((batch, seq), jnp.int32),
        "mask": _sds((batch, seq), jnp.float32),
    }
    if cfg.family in ("audio", "encdec"):
        out["frames"] = _sds((batch, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision_stub":
        out["prefix_embeds"] = _sds((batch, cfg.num_prefix_tokens, cfg.frontend_dim),
                                    jnp.dtype(cfg.dtype))
    return out


def prefill_inputs(cfg: ModelConfig, seq: int, batch: int) -> Dict[str, Any]:
    out = {"tokens": _sds((batch, seq), jnp.int32)}
    if cfg.family in ("audio", "encdec"):
        out["frames"] = _sds((batch, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.frontend == "vision_stub":
        out["prefix_embeds"] = _sds((batch, cfg.num_prefix_tokens, cfg.frontend_dim),
                                    jnp.dtype(cfg.dtype))
    return out


def decode_capacity(seq: int) -> int:
    """Cache capacity: seq + decode slack, padded so the 16-way model axis
    divides the sequence dim (otherwise KV caches lose their seq sharding)."""
    return ((seq + 8 + 255) // 256) * 256


def decode_inputs(cfg: ModelConfig, seq: int, batch: int) -> Dict[str, Any]:
    """token + abstract KV/state caches sized for a `seq`-long context."""
    model = build_model(cfg)
    caches = jax.eval_shape(lambda: model.init_caches(batch, decode_capacity(seq)))
    return {"token": _sds((batch,), jnp.int32), "caches": caches}


def abstract_params(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))  # seed: ok abstract shapes only, key never materialized


def make_step_fn(cfg: ModelConfig, kind: str, *, with_optimizer: bool = True,
                 microbatches: int = 1):
    """Returns (fn, input_builder) for lowering."""
    model = build_model(cfg)
    if kind == "train":
        if with_optimizer:
            from repro.training import AdamWConfig, make_train_step
            step = make_train_step(model, AdamWConfig(),
                                   microbatches=microbatches)
            return step
        def loss_step(params, batch):
            loss, metrics = model.loss(params, batch)
            return loss
        return loss_step
    if kind == "prefill":
        def prefill_step(params, batch):
            # capacity: full prompt (incl. multimodal prefix) + decode slack,
            # padded for model-axis divisibility of the cache seq dim
            cap = decode_capacity(batch["tokens"].shape[1] + cfg.num_prefix_tokens)
            return model.prefill(params, batch, cap)
        return prefill_step
    if kind == "decode":
        def serve_step(params, token, caches):
            return model.decode_step(params, token, caches)
        return serve_step
    raise ValueError(kind)
