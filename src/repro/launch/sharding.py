"""Sharding rules: param/optimizer/cache/batch PartitionSpecs per mesh.

2D FSDP x TP scheme (DESIGN.md §4): weight matrices shard over both 'data'
(FSDP) and 'model' (TP) axes; attention shards heads over 'model' when the
head count divides the axis, otherwise falls back to embed-dim (row
parallel) sharding — divisibility-checked per tensor, so whisper's 6 heads
and deepseek's 56 heads both lower cleanly on a 16-way model axis.

KV caches shard batch over ('pod','data') and the *sequence* dim over
'model' (kv-head counts never divide 16): the flash-decoding style layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.config import ModelConfig


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fits(mesh: Mesh, shape, spec) -> bool:
    for dim, axis in zip(shape, spec):
        if axis is None:
            continue
        if dim % _axis_size(mesh, axis) != 0:
            return False
    return True


def best_spec(mesh: Mesh, shape, candidates, uneven_dims=()) -> P:
    """First candidate whose named axes divide the dims; else replicated.

    Dims listed in ``uneven_dims`` may shard unevenly (GSPMD pads): used for
    head counts that don't divide the 16-way model axis (56, 6 heads) where
    padded head-sharding (<=14% waste) beats row-parallel fallback's
    per-layer activation resharding (§Perf H3 iteration 2).
    """
    for cand in candidates:
        cand = tuple(cand) + (None,) * (len(shape) - len(cand))
        ok = True
        for i, (dim, axis) in enumerate(zip(shape, cand)):
            if axis is None or i in uneven_dims:
                continue
            if dim % _axis_size(mesh, axis) != 0:
                ok = False
                break
        if ok:
            return P(*cand)
    return P()


def _param_spec(mesh: Mesh, pathstr: str, shape, mode: str = "train") -> P:
    mdl, dat = "model", "data"
    name = pathstr.split("/")[-1]
    scanned = pathstr.startswith("scan/") or "_scan/" in pathstr or \
        pathstr.startswith("enc_scan/") or pathstr.startswith("dec_scan/")
    core = shape[1:] if scanned else shape

    def wrap(spec: P) -> P:
        return P(None, *spec) if scanned else spec

    if name in ("embed",):
        return wrap(best_spec(mesh, core, [(mdl, dat), (mdl, None), (None, mdl)]))
    if name == "lm_head":
        return wrap(best_spec(mesh, core, [(dat, mdl), (None, mdl)]))
    if name == "frontend_proj":
        return wrap(best_spec(mesh, core, [(None, mdl)]))
    if name in ("w_q", "w_k", "w_v"):  # (d, H, dh)
        # NOTE §Perf H3-iter2 (refuted): uneven head sharding (56 heads
        # padded to 64 over the 16-way axis) is rejected by pjit for input
        # shardings — argument dims must divide the axis.  Head-parallel is
        # only possible when H % axis == 0; otherwise row-parallel.
        return wrap(best_spec(mesh, core, [
            (dat, mdl, None), ((dat, mdl), None, None), (mdl, None, None)]))
    if name == "w_o" and len(core) == 3:  # (H, dh, d)
        return wrap(best_spec(mesh, core, [
            (mdl, None, dat), (None, None, (dat, mdl)), (None, None, mdl)]))
    if name in ("b_q", "b_k", "b_v"):  # (H, dh)
        return wrap(best_spec(mesh, core, [(mdl, None)]))
    if name in ("w_gate", "w_up"):
        if len(core) == 3:  # MoE experts (E, d, f)
            # serve: 2D expert parallelism — experts over 'data', d over
            # 'model'; weights stay RESIDENT and the few decode tokens
            # all-to-all to their experts (H2 iter 2: arctic decode
            # all-gather 94 -> 2 GiB/token).  train/prefill: EP over 'data'
            # makes GSPMD replicate the (huge) token activations instead —
            # measured 35x collective blowup — so experts keep expert-dim
            # over 'model' + FSDP over d there.
            cands = ([(dat, mdl, None), (mdl, dat, None)] if mode == "serve"
                     else [(mdl, dat, None)]) + [(mdl, None, None)]
            return wrap(best_spec(mesh, core, cands))
        return wrap(best_spec(mesh, core, [(dat, mdl), (None, mdl), (mdl, None)]))
    if name == "w_down":
        if len(core) == 3:  # MoE (E, f, d)
            cands = ([(dat, None, mdl), (mdl, None, dat)] if mode == "serve"
                     else [(mdl, None, dat)]) + [(mdl, None, None)]
            return wrap(best_spec(mesh, core, cands))
        return wrap(best_spec(mesh, core, [(mdl, dat), (mdl, None), (None, dat)]))
    if name == "router":  # (d, E)
        return wrap(best_spec(mesh, core, [(dat, mdl), (None, mdl)]))
    if name == "w_in":  # mamba (d, big)
        return wrap(best_spec(mesh, core, [(dat, mdl), (mdl, None)]))
    if name in ("w_y", "w_x"):  # rglru (d, w)
        return wrap(best_spec(mesh, core, [(dat, mdl), (None, mdl), (mdl, None)]))
    if name in ("w_a", "w_i"):  # rglru (w, w)
        return wrap(best_spec(mesh, core, [(dat, mdl), (None, mdl)]))
    if name == "w_out" or (name == "w_o" and len(core) == 2):  # (inner, d)
        return wrap(best_spec(mesh, core, [(mdl, dat), (mdl, None), (None, dat)]))
    if name == "score_head":
        return wrap(P())
    # norms, conv kernels, gates, scalars: replicated
    return wrap(P())


def _strip_axis(spec: P, axis: str) -> P:
    out = []
    for entry in spec:
        if entry == axis:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a != axis)
            out.append(kept[0] if len(kept) == 1 else (kept or None))
        else:
            out.append(entry)
    return P(*out)


def param_specs(mesh: Mesh, params, mode: str = "train") -> "jax.tree":
    """mode='train': 2D FSDP x TP.  mode='serve': TP-only when the model
    fits (params/TP <= 12 GiB/dev) — decode re-gathers FSDP-sharded weights
    on EVERY token, which dominates the serving roofline (§Perf H2); models
    too big for TP-only (nemotron-4-340b) keep FSDP and stay
    collective-bound by necessity.
    """
    def one(path, leaf):
        pathstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
        return _param_spec(mesh, pathstr, leaf.shape, mode)
    specs = jax.tree_util.tree_map_with_path(one, params)
    if mode == "serve":
        # Expert weights (rank>=3 excluding the scan dim) are EP-resident
        # already; only the dense/attention weights pay a per-token FSDP
        # gather.  Strip 'data' from those when the TP-only residency fits.
        def is_expert(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            pathstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                               for p in path)
            scanned = pathstr.startswith("scan/")
            return (name in ("w_gate", "w_up", "w_down")
                    and leaf.ndim >= (4 if scanned else 3))

        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        nonexp = sum(l.size * jnp.dtype(l.dtype).itemsize
                     for p, l in flat if not is_expert(p, l))
        if nonexp / mesh.shape["model"] <= 12 * 2 ** 30:
            def strip(path, s, leaf):
                return s if is_expert(path, leaf) else _strip_axis(s, "data")
            specs = jax.tree_util.tree_map_with_path(
                strip, specs, params,
                is_leaf=lambda x: isinstance(x, P))
    return specs


def opt_state_specs(mesh: Mesh, opt_state, p_specs):
    return {
        "m": p_specs,
        "v": p_specs,
        "step": P(),
    }


def batch_spec(mesh: Mesh, cfg: ModelConfig):
    """Specs for a training/prefill batch dict."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = ba if len(ba) > 1 else (ba[0] if ba else None)
    out = {
        "tokens": P(bspec, None),
        "targets": P(bspec, None),
        "mask": P(bspec, None),
    }
    if cfg.family in ("audio", "encdec"):
        out["frames"] = P(bspec, None, None)
    if cfg.frontend == "vision_stub":
        out["prefix_embeds"] = P(bspec, None, None)
    return out


def _cache_entry_spec(mesh: Mesh, entry, batch_size: int, scanned: bool,
                      batch_axis):
    """Spec tree for one layer's cache entry (KV dict or state dict)."""
    mdl = "model"

    def leaf_spec(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        shape = leaf.shape[1:] if scanned else leaf.shape
        if name in ("k", "v"):
            # (B, T, Hk, dh): batch over data axes, sequence over model
            cand = [(batch_axis, mdl, None, None), (batch_axis, None, None, None),
                    (None, mdl, None, None)]
            spec = best_spec(mesh, shape, cand)
        elif name == "slot_pos":
            spec = best_spec(mesh, shape, [(batch_axis, mdl), (batch_axis, None),
                                           (None, mdl)])
        elif name == "ssm":  # (B, H, P, N)
            spec = best_spec(mesh, shape, [(batch_axis, mdl, None, None),
                                           (batch_axis, None, None, None)])
        elif name == "h":  # rglru (B, W)
            spec = best_spec(mesh, shape, [(batch_axis, mdl), (batch_axis, None),
                                           (None, mdl)])
        elif name == "conv":  # (B, w-1, C)
            spec = best_spec(mesh, shape, [(batch_axis, None, mdl),
                                           (batch_axis, None, None)])
        elif name == "pos":
            spec = P()
        else:
            spec = P()
        return P(None, *spec) if scanned else spec

    return jax.tree_util.tree_map_with_path(leaf_spec, entry)


def cache_specs(mesh: Mesh, caches, batch_size: int):
    """Spec tree matching transformer.init_caches / encdec.init_decode_caches."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_axis = ba if len(ba) > 1 else (ba[0] if ba else None)
    if batch_size == 1:
        batch_axis = None  # can't shard batch 1; sequence/model sharding carries

    out = {}
    if "scan" in caches:  # decoder-only layout
        out["scan"] = tuple(
            _cache_entry_spec(mesh, e, batch_size, True, batch_axis)
            for e in caches["scan"])
        out["rem"] = tuple(
            _cache_entry_spec(mesh, e, batch_size, False, batch_axis)
            for e in caches["rem"])
        out["pos"] = P()
        return out
    # enc-dec layout
    out["self"] = _cache_entry_spec(mesh, caches["self"], batch_size, True,
                                    batch_axis)
    mdl = "model"
    ck = caches["cross_k"].shape[1:]
    out["cross_k"] = P(None, *best_spec(
        mesh, ck, [(batch_axis, mdl, None, None), (batch_axis, None, None, None),
                   (None, mdl, None, None)]))
    out["cross_v"] = out["cross_k"]
    out["pos"] = P()
    return out


def token_spec(mesh: Mesh, batch_size: int):
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if batch_size == 1 or not ba:
        return P(None)
    return P(ba if len(ba) > 1 else ba[0])
