"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module constant — importing this module must never touch
jax device state (smoke tests run on 1 CPU device; only dryrun.py forces
512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_cache_mesh(n_shards: int, model: int = 1):
    """Mesh over an explicit device count, for cache row-sharding.

    Serving replicas share ONE row-sharded bank (DESIGN.md §12) and the
    shard count is a deployment choice, so this takes it explicitly
    instead of consuming every device like ``make_host_mesh``.
    """
    import numpy as np
    from jax.sharding import Mesh
    need = n_shards * model
    devices = jax.devices()
    if need > len(devices):
        raise ValueError(f"({n_shards}, {model}) mesh needs {need} devices, "
                         f"have {len(devices)} (set XLA_FLAGS="
                         f"--xla_force_host_platform_device_count=N on CPU)")
    return Mesh(np.asarray(devices[:need]).reshape(n_shards, model),
                ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
