"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module constant — importing this module must never touch
jax device state (smoke tests run on 1 CPU device; only dryrun.py forces
512 host devices).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the batch dimension shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
