"""Training launcher.

CPU-scale end-to-end driver (real data pipeline, optimizer, checkpointing)
with --arch selecting any registry config (smoke variant by default on CPU;
full configs are for the production mesh).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --steps 50 \
      --batch 8 --seq 128 [--full] [--mesh host]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data import token_stream_batches
from repro.models.model import build_model
from repro.tokenizer import HashWordTokenizer
from repro.training import AdamWConfig, init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="use the full (production) config instead of smoke")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    if cfg.family in ("audio", "encdec", "vlm"):
        print(f"note: {args.arch} takes stub multimodal inputs; training on "
              "text-token stream with random frontend embeddings")
    model = build_model(cfg)
    tok = HashWordTokenizer(cfg.vocab_size)
    params = model.init(jax.random.PRNGKey(0))  # seed: ok CLI smoke trainer, deterministic init
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M seq={args.seq} "
          f"batch={args.batch}")

    opt_cfg = AdamWConfig(lr=args.lr)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches,
                                      total_steps=args.steps))
    opt = init_opt_state(params)
    stream = token_stream_batches(tok, args.batch, args.seq)

    rng = np.random.default_rng(0)
    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        if cfg.family in ("audio", "encdec"):
            batch["frames"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.enc_frames, cfg.d_model)),
                jnp.dtype(cfg.dtype))
        if cfg.frontend == "vision_stub":
            batch["prefix_embeds"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.num_prefix_tokens,
                                     cfg.frontend_dim)), jnp.dtype(cfg.dtype))
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        if first is None:
            first = loss
        last = loss
        if step % args.log_every == 0 or step == args.steps - 1:
            tps = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d} loss {loss:.4f} tok/s {tps:,.0f}")
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps, params,
                               {"arch": args.arch})
        print("checkpoint:", path)
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
