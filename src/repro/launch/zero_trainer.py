"""shard_map gradient-accumulation trainer — one grad sync per step.

Under plain pjit, microbatched gradient accumulation re-syncs gradients
across the data axis on EVERY microbatch (the reduction lives inside the
scan body; XLA cannot hoist it).  This trainer makes the data/pod axes
manual via shard_map: each data shard accumulates LOCAL gradients over its
microbatches, and a single psum per step synchronises them — collective
volume drops from microbatches x params to 1 x params (§Perf H3 iter 3,
[beyond-paper]).

The 'model' axis stays auto, so tensor-parallel sharding inside the model
is still GSPMD-managed.  Params/opt-state are TP-sharded and replicated
across data (ZeRO-0 layout w.r.t. data; the memory lever here is
microbatching, which already removed the activation mountain).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.model import Model
from repro.training import AdamWConfig, adamw_update, cosine_schedule


def make_zero_train_step(model: Model, opt_cfg: AdamWConfig, mesh: Mesh, *,
                         microbatches: int, warmup: int = 100,
                         total_steps: int = 10_000):
    """Returns (step_fn, in_shardings-compatible spec builders)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def loss_fn(params, batch):
        loss, _ = model.loss(params, batch)
        return loss

    grad_fn = jax.value_and_grad(loss_fn)

    def local_step(params, opt_state, batch):
        # batch leaves arrive with the LOCAL shard of the batch dim.
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(split, batch)

        def acc(carry, micro):
            g_acc, l_acc = carry
            l, g = grad_fn(params, micro)
            return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, loss), _ = jax.lax.scan(acc, (g0, jnp.zeros(())), mb)
        # THE one synchronisation point per step:
        g = jax.tree.map(
            lambda t: jax.lax.pmean(t, data_axes[0]) if len(data_axes) == 1
            else jax.lax.pmean(jax.lax.pmean(t, data_axes[0]), data_axes[1]), g)
        g = jax.tree.map(lambda t: t / microbatches, g)
        loss = jax.lax.pmean(loss / microbatches, data_axes[0])
        lr_scale = cosine_schedule(opt_state["step"], warmup=warmup,
                                   total=total_steps)
        params, opt_state = adamw_update(params, g, opt_state, opt_cfg,
                                         lr_scale=lr_scale)
        return params, opt_state, {"loss": loss}

    def batch_specs(batch):
        bspec = data_axes if len(data_axes) > 1 else data_axes[0]
        return jax.tree.map(lambda _: P(bspec), batch)

    def wrap(params, opt_state, batch):
        # jax>=0.8: axis_names = the MANUAL axes; everything else stays auto
        # (GSPMD keeps managing the 'model'/TP dimension inside).
        sm = jax.shard_map(
            local_step, mesh=mesh,
            in_specs=(P(), P(), batch_specs(batch)),
            out_specs=(P(), P(), P()),
            axis_names=frozenset(data_axes),
            check_vma=False)
        return sm(params, opt_state, batch)

    return wrap
