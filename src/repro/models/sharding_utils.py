"""Mesh-aware sharding constraints that degrade to no-ops off-mesh.

``constrain(x, *axes)`` applies with_sharding_constraint only for axes that
exist in the ambient (abstract) mesh AND divide the corresponding dim —
so model code runs unchanged on a single CPU device (tests), on the host
mesh (examples) and on the 512-device production mesh (dry-run).

The BATCH sentinel expands to ('pod','data') / 'data' as available.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

BATCH = "__batch__"


def _mesh_axes():
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return {}
    if am is None:
        return {}
    try:
        axes = dict(zip(am.axis_names, am.axis_sizes))
        # Inside shard_map, manual axes must not appear in sharding
        # constraints — keep only Auto axes.
        types = getattr(am, "axis_types", None)
        if types is not None:
            axes = {n: s for (n, s), t in zip(axes.items(), types)
                    if "auto" in str(t).lower()}
        return axes
    except Exception:
        return {}


def constrain(x, *spec):
    axes = _mesh_axes()
    if not axes:
        return x
    resolved = []
    for dim, a in zip(x.shape, spec):
        if a == BATCH:
            a = tuple(n for n in ("pod", "data") if n in axes) or None
            if isinstance(a, tuple) and len(a) == 1:
                a = a[0]
        if a is None:
            resolved.append(None)
            continue
        names = a if isinstance(a, tuple) else (a,)
        size = 1
        ok = True
        for n in names:
            if n not in axes:
                ok = False
                break
            size *= axes[n]
        if not ok or dim % size != 0:
            resolved.append(None)
        else:
            resolved.append(a)
    resolved += [None] * (x.ndim - len(resolved))
    try:
        return jax.lax.with_sharding_constraint(x, P(*resolved))
    except Exception:
        return x
