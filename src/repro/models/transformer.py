"""Decoder-only LM assembled from ``ModelConfig``.

Layer stacks run as a ``lax.scan`` over *pattern periods* (so heterogeneous
stacks like RecurrentGemma's RG-RG-ATTN period still scan); the remainder
``num_layers % len(pattern)`` layers are applied unscanned.  Three entry
points:

  forward(params, tokens)            -> logits            (training)
  prefill(params, tokens, capacity)  -> (logits, caches)  (inference, full seq)
  decode_step(params, token, caches) -> (logits, caches)  (one token)

Caches are pytrees mirroring the scan structure: ``caches['scan'][j]`` holds
the stacked (leading dim = periods) per-layer state for pattern position j,
``caches['rem'][i]`` the remainder layers'.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import moe as moe_lib
from . import rglru as rglru_lib
from . import ssm as ssm_lib
from . import sharding_utils as shu
from .config import ATTN, LOCAL_ATTN, MAMBA2, MOE, RGLRU, ModelConfig
from .layers import apply_mlp, apply_norm, init_mlp, init_norm, truncated_normal


# ----------------------------------------------------------------- init

def _init_block(key, kind: str, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in (ATTN, LOCAL_ATTN):
        return {
            "norm1": init_norm(d, cfg.norm_type),
            "attn": attn_lib.init_attention(ks[0], cfg),
            "norm2": init_norm(d, cfg.norm_type),
            "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_type, jnp.dtype(cfg.dtype)),
        }
    if kind == MOE:
        return {
            "norm1": init_norm(d, cfg.norm_type),
            "attn": attn_lib.init_attention(ks[0], cfg),
            "norm2": init_norm(d, cfg.norm_type),
            "moe": moe_lib.init_moe(ks[1], cfg),
        }
    if kind == MAMBA2:
        return {
            "norm1": init_norm(d, cfg.norm_type),
            "mixer": ssm_lib.init_mamba2(ks[0], cfg),
        }
    if kind == RGLRU:
        return {
            "norm1": init_norm(d, cfg.norm_type),
            "rec": rglru_lib.init_rglru(ks[0], cfg),
            "norm2": init_norm(d, cfg.norm_type),
            "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_type, jnp.dtype(cfg.dtype)),
        }
    raise ValueError(kind)


def init_lm(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4 + len(cfg.block_pattern) + len(cfg.pattern_remainder))
    dt = jnp.dtype(cfg.dtype)
    params: Dict[str, Any] = {
        "embed": truncated_normal(ks[0], (cfg.padded_vocab, cfg.d_model), 0.02, dt),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = truncated_normal(
            ks[1], (cfg.d_model, cfg.padded_vocab), cfg.d_model ** -0.5, dt)
    if cfg.frontend != "none":
        params["frontend_proj"] = truncated_normal(
            ks[2], (cfg.frontend_dim, cfg.d_model), cfg.frontend_dim ** -0.5, dt)
    # Scanned stacks: one stacked tree per pattern position.
    periods = cfg.pattern_periods
    scan_params = []
    for j, kind in enumerate(cfg.block_pattern):
        kj = jax.random.split(ks[3 + j], periods)
        stacked = jax.vmap(lambda k, kind=kind: _init_block(k, kind, cfg))(kj)
        scan_params.append(stacked)
    params["scan"] = tuple(scan_params)
    rem = []
    for i, kind in enumerate(cfg.pattern_remainder):
        rem.append(_init_block(ks[3 + len(cfg.block_pattern) + i], kind, cfg))
    params["rem"] = tuple(rem)
    return params


# ----------------------------------------------------------------- blocks

def _block_train(kind: str, p, x, positions, cfg: ModelConfig):
    """Full-seq block without cache emission.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    if kind in (ATTN, LOCAL_ATTN, MOE):
        window = cfg.sliding_window if (kind == LOCAL_ATTN or cfg.sliding_window > 0) else 0
        a, _ = attn_lib.self_attention(p["attn"], h, positions, cfg, causal=True, window=window)
        x = x + a
        h2 = apply_norm(p["norm2"], x, cfg.norm_type)
        if kind == MOE:
            m, aux = moe_lib.apply_moe(p["moe"], h2, cfg)
        else:
            m = apply_mlp(p["mlp"], h2, cfg.mlp_type)
        return x + m, aux
    if kind == MAMBA2:
        y, _ = ssm_lib.mamba2_forward(p["mixer"], h, cfg)
        return x + y, aux
    if kind == RGLRU:
        y, _ = rglru_lib.rglru_forward(p["rec"], h, cfg)
        x = x + y
        h2 = apply_norm(p["norm2"], x, cfg.norm_type)
        return x + apply_mlp(p["mlp"], h2, cfg.mlp_type), aux
    raise ValueError(kind)


def _cache_capacity(kind: str, cfg: ModelConfig, capacity: int) -> int:
    if kind == LOCAL_ATTN or (cfg.sliding_window > 0 and kind in (ATTN, MOE)):
        return min(capacity, cfg.sliding_window)
    return capacity


def _init_block_cache(kind: str, batch: int, capacity: int, cfg: ModelConfig):
    if kind in (ATTN, LOCAL_ATTN, MOE):
        return attn_lib.init_kv_cache(batch, _cache_capacity(kind, cfg, capacity), cfg)
    if kind == MAMBA2:
        return ssm_lib.init_mamba2_state(batch, cfg)
    if kind == RGLRU:
        st = rglru_lib.init_rglru_state(batch, cfg)
        return st
    raise ValueError(kind)


def _block_prefill(kind: str, p, x, positions, cache, cfg: ModelConfig,
                   prefix=None):
    """Full-seq block, emits updated cache.  Returns (x, aux, cache).

    ``prefix`` is this layer's shared-prefix KV cache (DESIGN.md §9):
    attention runs the suffix queries over ``[prefix | suffix]`` and the
    emitted cache holds both, byte-identical to a full prefill of the
    concatenated sequence.  Only global-attention blocks support it —
    the Model facade gates which architectures get here (windowed /
    SSM / RG-LRU stacks fall back to full prefill explicitly).
    """
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    if kind in (ATTN, LOCAL_ATTN, MOE):
        window = cfg.sliding_window if (kind == LOCAL_ATTN or cfg.sliding_window > 0) else 0
        if prefix is not None and window > 0:
            raise NotImplementedError(
                "prefix-cached prefill is global-attention only; windowed "
                "stacks must fall back to full prefill")
        a, kv = attn_lib.self_attention(
            p["attn"], h, positions, cfg, causal=True, window=window,
            prefix=prefix)
        if prefix is not None:
            k, v, k_pos = kv          # [prefix | suffix], cache-ready
        else:
            k, v = kv
            k_pos = positions
        s = k.shape[1]
        cap = cache["k"].shape[1]
        if s <= cap:
            cache = attn_lib.fill_kv_cache(cache, k, v, k_pos)
        else:
            # windowed cache smaller than the prefill: keep last `cap` tokens
            # laid out in ring order slot = pos % cap.
            start = s - cap
            slot_of = (start + (jnp.arange(cap) - start) % cap)  # token index per slot
            cache = dict(cache)
            cache["k"] = jnp.take(k, slot_of, axis=1).astype(cache["k"].dtype)
            cache["v"] = jnp.take(v, slot_of, axis=1).astype(cache["v"].dtype)
            cache["slot_pos"] = jnp.take(positions, slot_of, axis=1).astype(jnp.int32)
            cache["pos"] = jnp.asarray(s, jnp.int32)
        x = x + a
        h2 = apply_norm(p["norm2"], x, cfg.norm_type)
        if kind == MOE:
            m, aux = moe_lib.apply_moe(p["moe"], h2, cfg)
        else:
            m = apply_mlp(p["mlp"], h2, cfg.mlp_type)
        return x + m, aux, cache
    if prefix is not None:
        # Recurrent mixers would need state-carry prefill (resume the
        # scan from the prefix's final state); until that exists the
        # Model facade reports supports_prefix_prefill=False for them
        # and servers fall back to full prefill.
        raise NotImplementedError(
            f"prefix-cached prefill not implemented for {kind!r} blocks")
    if kind == MAMBA2:
        y, st = ssm_lib.mamba2_forward(p["mixer"], h, cfg)
        return x + y, aux, {"ssm": st["ssm"], "conv": st["conv"]}
    if kind == RGLRU:
        y, st = rglru_lib.rglru_forward(p["rec"], h, cfg)
        x = x + y
        h2 = apply_norm(p["norm2"], x, cfg.norm_type)
        return x + apply_mlp(p["mlp"], h2, cfg.mlp_type), aux, st
    raise ValueError(kind)


def _block_decode(kind: str, p, x, cache, cfg: ModelConfig):
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    if kind in (ATTN, LOCAL_ATTN, MOE):
        window = cfg.sliding_window if (kind == LOCAL_ATTN or cfg.sliding_window > 0) else 0
        a, cache = attn_lib.decode_attention(p["attn"], h, cache, cfg, window=window)
        x = x + a
        h2 = apply_norm(p["norm2"], x, cfg.norm_type)
        if kind == MOE:
            m, _ = moe_lib.apply_moe(p["moe"], h2, cfg)
        else:
            m = apply_mlp(p["mlp"], h2, cfg.mlp_type)
        return x + m, cache
    if kind == MAMBA2:
        y, cache = ssm_lib.mamba2_decode(p["mixer"], h, cache, cfg)
        return x + y, cache
    if kind == RGLRU:
        y, cache = rglru_lib.rglru_decode(p["rec"], h, cache, cfg)
        x = x + y
        h2 = apply_norm(p["norm2"], x, cfg.norm_type)
        return x + apply_mlp(p["mlp"], h2, cfg.mlp_type), cache
    raise ValueError(kind)


def _block_decode_block(kind: str, p, x, cache, cfg: ModelConfig):
    """(B, k)-block decode step for one layer (speculative verify, §14).

    Only plain-KV global attention qualifies — recurrent mixers can't
    rewind rejected positions and windowed ring buffers overwrite slots
    the rewind would need back; ``Model.supports_spec_decode`` gates
    callers to ATTN/MOE stacks before tracing reaches here.
    """
    if kind not in (ATTN, MOE) or cfg.sliding_window > 0:
        raise ValueError(
            f"block decode requires global-attention KV layers, got {kind!r}")
    h = apply_norm(p["norm1"], x, cfg.norm_type)
    a, cache = attn_lib.decode_attention_block(p["attn"], h, cache, cfg)
    x = x + a
    h2 = apply_norm(p["norm2"], x, cfg.norm_type)
    if kind == MOE:
        m, _ = moe_lib.apply_moe(p["moe"], h2, cfg)
    else:
        m = apply_mlp(p["mlp"], h2, cfg.mlp_type)
    return x + m, cache


# ----------------------------------------------------------------- stacks

def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat:
        # prevent_cse=False: safe under scan (the standard remat-of-scan-body
        # setting) and avoids optimization-barrier artifacts that break
        # XLA's in-place dynamic-update-slice on the residual stack.
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable,
                              prevent_cse=False)
    return fn


def _run_stack_train(params, x, positions, cfg: ModelConfig):
    aux_total = jnp.zeros((), jnp.float32)

    def period_body(carry, period_params):
        x, aux = carry
        for j, kind in enumerate(cfg.block_pattern):
            x, a = _block_train(kind, period_params[j], x, positions, cfg)
            aux = aux + a
        return (x, aux), None

    body = _maybe_remat(period_body, cfg)
    if cfg.pattern_periods > 0:
        if cfg.scan_layers:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["scan"])
        else:
            for i in range(cfg.pattern_periods):
                pp = jax.tree.map(lambda t, i=i: t[i], params["scan"])
                (x, aux_total), _ = period_body((x, aux_total), pp)
    for i, kind in enumerate(cfg.pattern_remainder):
        x, a = _block_train(kind, params["rem"][i], x, positions, cfg)
        aux_total = aux_total + a
    return x, aux_total


def init_caches(params, batch: int, capacity: int, cfg: ModelConfig):
    del params
    scan_caches = []
    for kind in cfg.block_pattern:
        one = _init_block_cache(kind, batch, capacity, cfg)
        stacked = jax.tree.map(
            lambda t: jnp.broadcast_to(t, (cfg.pattern_periods,) + t.shape).copy(), one)
        scan_caches.append(stacked)
    rem = tuple(_init_block_cache(kind, batch, capacity, cfg)
                for kind in cfg.pattern_remainder)
    return {"scan": tuple(scan_caches), "rem": rem, "pos": jnp.zeros((), jnp.int32)}


def _run_stack_prefill(params, caches, x, positions, cfg: ModelConfig,
                       prefix=None):
    """``prefix``: a caches pytree holding each layer's shared-prefix KV
    (the output of a prefix-only prefill, DESIGN.md §9); its scan/rem
    structure mirrors ``caches`` so per-layer prefix KV threads through
    the period scan alongside the layer's own cache."""
    def period_body(x, period_in):
        pp, pc, ppre = period_in
        new_c = []
        for j, kind in enumerate(cfg.block_pattern):
            x, _, c = _block_prefill(kind, pp[j], x, positions, pc[j], cfg,
                                     prefix=None if ppre is None else ppre[j])
            new_c.append(c)
        return x, tuple(new_c)

    if cfg.pattern_periods > 0:
        if prefix is None:
            x, new_scan = jax.lax.scan(
                lambda x, pi: period_body(x, (*pi, None)),
                x, (params["scan"], caches["scan"]))
        else:
            x, new_scan = jax.lax.scan(
                period_body, x,
                (params["scan"], caches["scan"], prefix["scan"]))
    else:
        new_scan = caches["scan"]
    new_rem = []
    for i, kind in enumerate(cfg.pattern_remainder):
        x, _, c = _block_prefill(kind, params["rem"][i], x, positions,
                                 caches["rem"][i], cfg,
                                 prefix=None if prefix is None
                                 else prefix["rem"][i])
        new_rem.append(c)
    new_caches = {"scan": new_scan, "rem": tuple(new_rem),
                  "pos": positions[0, -1].astype(jnp.int32) + 1}
    return x, new_caches


def _run_stack_decode(params, caches, x, cfg: ModelConfig):
    def period_body(x, period_in):
        pp, pc = period_in
        new_c = []
        for j, kind in enumerate(cfg.block_pattern):
            x, c = _block_decode(kind, pp[j], x, pc[j], cfg)
            new_c.append(c)
        return x, tuple(new_c)

    if cfg.pattern_periods > 0:
        x, new_scan = jax.lax.scan(period_body, x, (params["scan"], caches["scan"]))
    else:
        new_scan = caches["scan"]
    new_rem = []
    for i, kind in enumerate(cfg.pattern_remainder):
        x, c = _block_decode(kind, params["rem"][i], x, caches["rem"][i], cfg)
        new_rem.append(c)
    return x, {"scan": new_scan, "rem": tuple(new_rem), "pos": caches["pos"] + 1}


def _run_stack_decode_block(params, caches, x, cfg: ModelConfig):
    def period_body(x, period_in):
        pp, pc = period_in
        new_c = []
        for j, kind in enumerate(cfg.block_pattern):
            x, c = _block_decode_block(kind, pp[j], x, pc[j], cfg)
            new_c.append(c)
        return x, tuple(new_c)

    if cfg.pattern_periods > 0:
        x, new_scan = jax.lax.scan(period_body, x, (params["scan"], caches["scan"]))
    else:
        new_scan = caches["scan"]
    new_rem = []
    for i, kind in enumerate(cfg.pattern_remainder):
        x, c = _block_decode_block(kind, params["rem"][i], x, caches["rem"][i], cfg)
        new_rem.append(c)
    kblk = x.shape[1]
    return x, {"scan": new_scan, "rem": tuple(new_rem),
               "pos": caches["pos"] + kblk}


# ----------------------------------------------------------------- heads

def _embed_inputs(params, tokens, cfg: ModelConfig, prefix_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        pe = jnp.einsum("bpe,ed->bpd", prefix_embeds.astype(x.dtype),
                        params["frontend_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    # Seed GSPMD with batch-sharded activations: the embedding gather would
    # otherwise propagate the table's sharding (d over 'data') and replicate
    # the batch dim across the whole mesh.
    x = shu.constrain(x, shu.BATCH, None, None)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return x, positions


def _logits(params, x, cfg: ModelConfig):
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    logits = shu.constrain(logits, shu.BATCH, None, "model")
    logits = logits.astype(jnp.float32)
    if cfg.logits_softcap > 0:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def forward(params, tokens, cfg: ModelConfig, prefix_embeds=None):
    """Training forward.  Returns (logits (B,S_total,V_padded), aux_loss)."""
    x, positions = _embed_inputs(params, tokens, cfg, prefix_embeds)
    x, aux = _run_stack_train(params, x, positions, cfg)
    return _logits(params, x, cfg), aux


def prefill(params, tokens, cfg: ModelConfig, capacity: int, prefix_embeds=None,
            prefix=None):
    """Inference prefill.  Returns (last-token logits (B,V), caches).

    With ``prefix`` (a caches pytree from a prefix-only prefill),
    ``tokens`` are treated as the SUFFIX of a longer sequence: positions
    continue from the prefix, every attention layer attends over
    ``[prefix KV | suffix]``, and the returned caches cover the full
    ``[0, P+S)`` span — logits and caches byte-identical to a full
    prefill of the concatenation (differential-tested, DESIGN.md §9).
    """
    x, positions = _embed_inputs(params, tokens, cfg, prefix_embeds)
    if prefix is not None:
        if prefix_embeds is not None:
            raise NotImplementedError(
                "prefix-cached prefill with frontend prefix_embeds")
        positions = positions + prefix["pos"].astype(jnp.int32)
    caches = init_caches(params, x.shape[0], capacity, cfg)
    x, caches = _run_stack_prefill(params, caches, x, positions, cfg,
                                   prefix=prefix)
    logits = _logits(params, x[:, -1:], cfg)
    return logits[:, 0], caches


def decode_step(params, token, caches, cfg: ModelConfig):
    """token: (B,) int32.  Returns (logits (B,V), caches)."""
    x = jnp.take(params["embed"], token[:, None], axis=0)
    x = shu.constrain(x, shu.BATCH, None, None)
    x, caches = _run_stack_decode(params, caches, x, cfg)
    logits = _logits(params, x, cfg)
    return logits[:, 0], caches


def decode_block(params, tokens, caches, cfg: ModelConfig):
    """tokens: (B, k) int32 verify block.  Returns (logits (B,k,V), caches).

    The speculative verify forward (DESIGN.md §14): logits[:, i] is the
    model's next-token distribution after consuming tokens[:, :i+1] on
    top of the cache.  Requires per-row (B,) cache positions (every KV
    leaf AND the top-level ``pos``) — ``paged_kv.row_pos_caches``
    converts a fresh prefill; rows diverge after their first rejected
    draft so a scalar position cannot represent the batch.
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    x = shu.constrain(x, shu.BATCH, None, None)
    x, caches = _run_stack_decode_block(params, caches, x, cfg)
    return _logits(params, x, cfg), caches


def cross_entropy(logits, targets, mask, vocab_size: int):
    """CE that stays efficient when the vocab dim is model-axis sharded.

    No take_along_axis over the (padded, sharded) vocab dim — GSPMD would
    all-gather the full (B,S,V) logits for the gather.  Instead the target
    logit is read through an iota-compare masked reduction and the padded
    vocab tail is masked out of the logsumexp; both are elementwise +
    reduce, which GSPMD partitions with a small all-reduce.
    """
    v = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
    vocab_ok = iota < vocab_size                                   # (V,)
    neg = jnp.asarray(-1e30, logits.dtype)
    masked = jnp.where(vocab_ok, logits, neg)
    m = jax.lax.stop_gradient(jnp.max(masked, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(masked - m), axis=-1)) + m[..., 0]
    tgt = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0), axis=-1)
    nll = lse - tgt
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom


def loss_fn(params, tokens, targets, mask, cfg: ModelConfig, prefix_embeds=None):
    """Next-token CE in fp32 over the exact (unpadded) vocab."""
    logits, aux = forward(params, tokens, cfg, prefix_embeds)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1]:]
    ce = cross_entropy(logits, targets, mask, cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux,
                      "tokens": jnp.sum(mask).astype(jnp.int32)}
