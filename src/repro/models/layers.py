"""Primitive layers: norms, MLP variants, rotary embeddings, init helpers.

All layers are functional: ``init_*`` returns a param pytree (nested dicts of
jnp arrays), ``apply`` functions are pure.  Param dtype follows the config;
norm/scale params stay fp32 for stability and are cast at use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, stddev, dtype):
    return (stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, d_in, d_out, dtype, stddev=None):
    stddev = stddev if stddev is not None else d_in ** -0.5
    return truncated_normal(key, (d_in, d_out), stddev, dtype)


# ----------------------------------------------------------------- norms

def init_norm(d, norm_type: str):
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(params, x, norm_type: str, eps: float = 1e-6):
    # Statistics via fp32-accumulator reductions, elementwise path in the
    # input dtype.  Never converts the full activation to fp32: that convert
    # gets hoisted across the remat-saved residual stack by XLA and doubles
    # activation memory on the big configs (f32 copy of every bf16 save).
    # params are (d,); broadcast them explicitly so the math stays legal
    # under jax_numpy_rank_promotion="raise" (the sanitize harness)
    expand = (1,) * (x.ndim - 1) + (-1,)
    scale = params["scale"].astype(x.dtype).reshape(expand)
    if norm_type == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
        xc = x - mu.astype(x.dtype)
        var = jnp.mean(jnp.square(xc), axis=-1, keepdims=True, dtype=jnp.float32)
        inv = jax.lax.rsqrt(var + eps)
        y = (xc * (inv.astype(x.dtype) * scale)
             + params["bias"].astype(x.dtype).reshape(expand))
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
        inv = jax.lax.rsqrt(ms + eps)
        y = x * (inv.astype(x.dtype) * scale)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- MLPs

def init_mlp(key, d, d_ff, mlp_type: str, dtype):
    ks = jax.random.split(key, 3)
    if mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, d_ff, dtype),
            "w_up": dense_init(ks[1], d, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d, dtype, stddev=d_ff ** -0.5),
        }
    return {
        "w_up": dense_init(ks[0], d, d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d, dtype, stddev=d_ff ** -0.5),
    }


def apply_mlp(params, x, mlp_type: str):
    if mlp_type == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        h = jax.nn.silu(g) * u
    elif mlp_type == "squared_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", x, params["w_up"])))
    else:  # gelu
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["w_up"]))
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ----------------------------------------------------------------- rotary

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dh, theta))  # (dh/2,)
    # explicit rank match (rank-promotion=raise safe): (..., S, 1) * (..., 1, dh/2)
    ang = (positions[..., :, None].astype(jnp.float32)
           * freqs.reshape((1,) * positions.ndim + (-1,)))  # (..., S, dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int):
    pos = np.arange(length)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(0, d, 2) / d)
    pe = np.zeros((length, d), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe)
