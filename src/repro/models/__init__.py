from .config import ModelConfig, ATTN, LOCAL_ATTN, MOE, MAMBA2, RGLRU
from .model import Model, build_model
