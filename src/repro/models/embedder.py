"""MiniLM-class sentence embedder (the paper's all-MiniLM-L6-v2 analogue).

6-layer bidirectional encoder, mean pooling over valid tokens, L2
normalisation — emits 384-dim unit vectors so cosine similarity is a plain
dot product, exactly as the TweakLLM cache consumes it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, init_mlp, init_norm, truncated_normal

MINILM_CONFIG = ModelConfig(
    name="embedder-minilm", family="encoder", num_layers=6, d_model=384,
    num_heads=12, num_kv_heads=12, d_ff=1536, vocab_size=32768,
    mlp_type="gelu", norm_type="layernorm", rope_theta=10_000.0,
    dtype="float32", max_seq_len=512,
)


def tiny_embedder_config(vocab_size: int = 4096) -> ModelConfig:
    return MINILM_CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                                 num_kv_heads=4, d_ff=128, vocab_size=vocab_size)


def init_embedder(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2 + cfg.num_layers)
    dt = jnp.dtype(cfg.dtype)
    layers = []
    for i in range(cfg.num_layers):
        lk = jax.random.split(ks[2 + i], 2)
        layers.append({
            "norm1": init_norm(cfg.d_model, cfg.norm_type),
            "attn": attn_lib.init_attention(lk[0], cfg),
            "norm2": init_norm(cfg.d_model, cfg.norm_type),
            "mlp": init_mlp(lk[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dt),
        })
    stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *layers)
    return {
        "embed": truncated_normal(ks[0], (cfg.padded_vocab, cfg.d_model), 0.02, dt),
        "scan": stacked,
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }


def encode(params, tokens, mask, cfg: ModelConfig):
    """tokens (B,S) int32, mask (B,S) {0,1} -> unit embeddings (B, d)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    valid = mask.astype(bool)

    def body(x, lp):
        h = apply_norm(lp["norm1"], x, cfg.norm_type)
        q, k, v = attn_lib._project_qkv(lp["attn"], h, cfg)
        q = attn_lib.apply_rope(q, positions, cfg.rope_theta)
        k = attn_lib.apply_rope(k, positions, cfg.rope_theta)
        ctx = attn_lib.attend(q, k, v, positions, positions, causal=False,
                              window=0, impl="naive", extra_mask=valid)
        x = x + jnp.einsum("bshk,hkd->bsd", ctx, lp["attn"]["w_o"])
        h2 = apply_norm(lp["norm2"], x, cfg.norm_type)
        return x + apply_mlp(lp["mlp"], h2, cfg.mlp_type), None

    x, _ = jax.lax.scan(body, x, params["scan"])
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    m = mask.astype(jnp.float32)[..., None]
    pooled = jnp.sum(x.astype(jnp.float32) * m, axis=1) / jnp.maximum(
        jnp.sum(m, axis=1), 1.0)
    return pooled / jnp.maximum(jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-8)
