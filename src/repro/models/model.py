"""Unified model facade: one interface over decoder-only and enc-dec stacks.

``Model`` bundles (cfg, init, forward/loss, prefill, decode_step) so the
serving engine, trainer and dry-run treat every architecture uniformly.

Decode contract (DESIGN.md §8): ``decode_step`` must be a pure,
shape-stable function of ``(params, token (B,), caches)`` — the cache
pytree it returns must have exactly the structure/shapes/dtypes of the one
it received.  The serving generator runs it inside a jitted
``jax.lax.while_loop`` (the fused decode loop), where any shape or
structure change in the carry is a compile error.  All architectures here
(ring-buffered KV attention incl. the Pallas decode kernel, Mamba2 SSM
state, RG-LRU state, enc-dec cross caches) satisfy this by construction.
"""
from __future__ import annotations

import dataclasses

from . import encdec as encdec_lib
from . import transformer as tf_lib
from .config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def is_encdec(self) -> bool:
        return self.cfg.enc_layers > 0

    def init(self, key):
        if self.is_encdec:
            return encdec_lib.init_encdec(key, self.cfg)
        return tf_lib.init_lm(key, self.cfg)

    # --- training -----------------------------------------------------
    def loss(self, params, batch):
        """batch keys: tokens, targets, mask [+ frames | prefix_embeds]."""
        cfg = self.cfg
        if self.is_encdec:
            logits, aux = encdec_lib.forward(params, batch["frames"],
                                             batch["tokens"], cfg)
            ce = tf_lib.cross_entropy(logits, batch["targets"], batch["mask"],
                                      cfg.vocab_size)
            return ce + aux, {"ce": ce, "aux": aux}
        loss, metrics = tf_lib.loss_fn(
            params, batch["tokens"], batch["targets"], batch["mask"], cfg,
            prefix_embeds=batch.get("prefix_embeds"))
        return loss, metrics

    def forward(self, params, batch):
        if self.is_encdec:
            return encdec_lib.forward(params, batch["frames"], batch["tokens"],
                                      self.cfg)
        return tf_lib.forward(params, batch["tokens"], self.cfg,
                              prefix_embeds=batch.get("prefix_embeds"))

    # --- inference ----------------------------------------------------
    def prefill(self, params, batch, capacity: int):
        if self.is_encdec:
            return encdec_lib.prefill(params, batch["frames"], batch["tokens"],
                                      self.cfg, capacity)
        return tf_lib.prefill(params, batch["tokens"], self.cfg, capacity,
                              prefix_embeds=batch.get("prefix_embeds"))

    def init_caches(self, batch_size: int, capacity: int):
        if self.is_encdec:
            return encdec_lib.init_decode_caches(batch_size, capacity, self.cfg)
        return tf_lib.init_caches(None, batch_size, capacity, self.cfg)

    def decode_step(self, params, token, caches):
        if self.is_encdec:
            return encdec_lib.decode_step(params, token, caches, self.cfg)
        return tf_lib.decode_step(params, token, caches, self.cfg)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
