"""Unified model facade: one interface over decoder-only and enc-dec stacks.

``Model`` bundles (cfg, init, forward/loss, prefill, decode_step) so the
serving engine, trainer and dry-run treat every architecture uniformly.

Decode contract (DESIGN.md §8): ``decode_step`` must be a pure,
shape-stable function of ``(params, token (B,), caches)`` — the cache
pytree it returns must have exactly the structure/shapes/dtypes of the one
it received.  The serving generator runs it inside a jitted
``jax.lax.while_loop`` (the fused decode loop), where any shape or
structure change in the carry is a compile error.  All architectures here
(ring-buffered KV attention incl. the Pallas decode kernel, Mamba2 SSM
state, RG-LRU state, enc-dec cross caches) satisfy this by construction.

Prefix-prefill contract (DESIGN.md §9): when
``supports_prefix_prefill`` is True, ``prefill_prefix(params, tokens)``
returns the KV state of a shared prompt prefix, and
``prefill_with_prefix(params, batch, capacity, prefix)`` prefills only
the suffix in ``batch`` while attending over the stored prefix KV — its
``(logits, caches)`` must be byte-identical to ``prefill`` of the
concatenated ``[prefix | suffix]`` tokens, so decode proceeds
indistinguishably.  Support currently means a decoder-only stack of
global-attention blocks (ATTN/MOE, no sliding window, no frontend
prefix embeddings).  Recurrent mixers (Mamba2, RG-LRU), windowed
attention, and enc-dec would need state-carry prefill; they report
False and callers MUST fall back to the full ``prefill`` — the methods
raise ``NotImplementedError`` rather than silently degrade.
"""
from __future__ import annotations

import dataclasses

from . import encdec as encdec_lib
from . import transformer as tf_lib
from .config import ATTN, MOE, ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def is_encdec(self) -> bool:
        return self.cfg.enc_layers > 0

    def init(self, key):
        if self.is_encdec:
            return encdec_lib.init_encdec(key, self.cfg)
        return tf_lib.init_lm(key, self.cfg)

    # --- training -----------------------------------------------------
    def loss(self, params, batch):
        """batch keys: tokens, targets, mask [+ frames | prefix_embeds]."""
        cfg = self.cfg
        if self.is_encdec:
            logits, aux = encdec_lib.forward(params, batch["frames"],
                                             batch["tokens"], cfg)
            ce = tf_lib.cross_entropy(logits, batch["targets"], batch["mask"],
                                      cfg.vocab_size)
            return ce + aux, {"ce": ce, "aux": aux}
        loss, metrics = tf_lib.loss_fn(
            params, batch["tokens"], batch["targets"], batch["mask"], cfg,
            prefix_embeds=batch.get("prefix_embeds"))
        return loss, metrics

    def forward(self, params, batch):
        if self.is_encdec:
            return encdec_lib.forward(params, batch["frames"], batch["tokens"],
                                      self.cfg)
        return tf_lib.forward(params, batch["tokens"], self.cfg,
                              prefix_embeds=batch.get("prefix_embeds"))

    # --- inference ----------------------------------------------------
    def prefill(self, params, batch, capacity: int):
        if self.is_encdec:
            return encdec_lib.prefill(params, batch["frames"], batch["tokens"],
                                      self.cfg, capacity)
        return tf_lib.prefill(params, batch["tokens"], self.cfg, capacity,
                              prefix_embeds=batch.get("prefix_embeds"))

    @property
    def supports_prefix_prefill(self) -> bool:
        """True when this arch can reuse a shared-prefix KV cache in prefill.

        Global-attention decoder-only stacks qualify; recurrent mixers
        (Mamba2/RG-LRU), sliding-window attention, enc-dec and
        frontend-prefix models do not (they would need state-carry
        prefill) and must be served via the full ``prefill`` instead.
        """
        cfg = self.cfg
        kinds = set(cfg.block_pattern) | set(cfg.pattern_remainder)
        # Byte-identicality additionally needs a length-invariant attention
        # reduction: the fixed-block flash impls qualify, the naive
        # full-axis softmax does not (XLA reassociates its key-axis sums
        # differently per sequence length, so a prefix-only pass would
        # drift ulps from the inline computation).
        return (not self.is_encdec and cfg.sliding_window == 0
                and kinds <= {ATTN, MOE} and cfg.num_prefix_tokens == 0
                and cfg.attention_impl in ("xla_flash", "pallas"))

    @property
    def supports_paged_decode(self) -> bool:
        """True when decode can run over a paged KV pool (DESIGN.md §11).

        Paged decode gathers K/V through a per-sequence block table, so
        every cached layer must be a plain KV cache: decoder-only stacks
        of global-attention blocks (ATTN/MOE, no sliding window).
        Recurrent mixers (Mamba2/RG-LRU) carry non-KV state, windowed
        attention ring-buffers its slots, and enc-dec adds cross caches
        — all must decode over the dense cache instead.
        """
        cfg = self.cfg
        kinds = set(cfg.block_pattern) | set(cfg.pattern_remainder)
        return (not self.is_encdec and cfg.sliding_window == 0
                and kinds <= {ATTN, MOE})

    @property
    def supports_spec_decode(self) -> bool:
        """True when decode can verify (B, k) draft blocks (DESIGN.md §14).

        Speculative verify writes k positions optimistically and REWINDS
        the rejected suffix, so every cached layer must be a plain KV
        cache whose slots can be invalidated by position: decoder-only
        global-attention stacks (ATTN/MOE, no sliding window) — the same
        condition as paged decode, and for the same structural reason.
        Recurrent state (Mamba2/RG-LRU) can't rewind; windowed ring
        buffers may have overwritten the slots a rewind needs back.
        """
        return self.supports_paged_decode

    def decode_block(self, params, tokens, caches):
        """tokens (B, k) -> (logits (B, k, V), caches); speculative verify.

        Caches must carry per-row positions (``paged_kv.row_pos_caches``)
        and the caller owns acceptance + rewind of rejected suffixes.
        """
        if not self.supports_spec_decode:
            raise NotImplementedError(
                f"{self.cfg.name}: block (speculative) decode unsupported "
                f"for this architecture — use decode_step")
        return tf_lib.decode_block(params, tokens, caches, self.cfg)

    def prefill_prefix(self, params, tokens):
        """KV state of a shared prefix: tokens (B, P) -> caches pytree.

        Capacity is exactly P — the result is the immutable prefix cache
        that ``prefill_with_prefix`` attends over (and copies into each
        request's decode cache), one build per (model, batch bucket).
        """
        if not self.supports_prefix_prefill:
            raise NotImplementedError(
                f"{self.cfg.name}: prefix-cached prefill unsupported for "
                f"this architecture — use the full prefill")
        _, caches = tf_lib.prefill(params, tokens, self.cfg,
                                   capacity=int(tokens.shape[1]))
        return caches

    def prefill_with_prefix(self, params, batch, capacity: int, prefix):
        """Suffix-only prefill over a stored prefix KV (DESIGN.md §9).

        ``batch["tokens"]`` holds ONLY the suffix; ``prefix`` is the
        pytree from ``prefill_prefix`` at the same batch size.  Returns
        (logits, caches) byte-identical to ``prefill`` of the
        concatenated sequence with the same total ``capacity``.
        """
        if not self.supports_prefix_prefill:
            raise NotImplementedError(
                f"{self.cfg.name}: prefix-cached prefill unsupported for "
                f"this architecture — use the full prefill")
        return tf_lib.prefill(params, batch["tokens"], self.cfg, capacity,
                              prefix=prefix)

    def init_caches(self, batch_size: int, capacity: int):
        if self.is_encdec:
            return encdec_lib.init_decode_caches(batch_size, capacity, self.cfg)
        return tf_lib.init_caches(None, batch_size, capacity, self.cfg)

    def decode_step(self, params, token, caches):
        if self.is_encdec:
            return encdec_lib.decode_step(params, token, caches, self.cfg)
        return tf_lib.decode_step(params, token, caches, self.cfg)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
