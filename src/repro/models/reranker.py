"""Cross-encoder re-ranker — joint (query, candidate) duplicate scoring.

Scores a (query, candidate-query) pair jointly: both sequences are
concatenated with a separator, run through a small bidirectional encoder,
and a scalar duplicate-probability head reads the pooled state.  Plays the
role of ``albert-duplicate-onnx`` / ``quora-distilroberta-base`` in Fig 2
(GPTCache baseline) and serves as the second-stage evidence source of the
calibrated router cascade (``core/router.py``): :func:`score_shortlist`
scores the live query against the cache lookup's top-k candidates in one
jitted batch.

Positions are PACKED (rank among valid tokens, ``cumsum(mask) - 1``), not
raw sequence offsets: padding inside the first segment must not shift the
second segment's rotary phases, or scores would depend on how the inputs
were padded rather than on their content (the padding-independence
property the tests pin).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import embedder as emb_lib
from .config import ModelConfig
from .layers import apply_mlp, apply_norm, dense_init


def tiny_reranker_config(vocab_size: int = 4096) -> ModelConfig:
    return emb_lib.MINILM_CONFIG.replace(
        name="reranker", num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=vocab_size)


def init_reranker(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    params = emb_lib.init_embedder(k1, cfg)
    params["score_head"] = dense_init(k2, cfg.d_model, 1, jnp.float32)
    return params


def score_pairs(params, tokens_a, mask_a, tokens_b, mask_b, cfg: ModelConfig,
                sep_token: int = 3):
    """Joint encoding of pairs -> duplicate logit (B,)."""
    b = tokens_a.shape[0]
    sep = jnp.full((b, 1), sep_token, jnp.int32)
    tokens = jnp.concatenate([tokens_a, sep, tokens_b], axis=1)
    mask = jnp.concatenate([mask_a, jnp.ones((b, 1), mask_a.dtype), mask_b],
                           axis=1)
    x = jnp.take(params["embed"], tokens, axis=0)
    # packed positions: the i-th VALID token sits at rotary phase i,
    # wherever padding falls — see module docstring
    positions = jnp.maximum(
        jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1, 0)
    valid = mask.astype(bool)

    def body(x, lp):
        h = apply_norm(lp["norm1"], x, cfg.norm_type)
        q, k, v = attn_lib._project_qkv(lp["attn"], h, cfg)
        q = attn_lib.apply_rope(q, positions, cfg.rope_theta)
        k = attn_lib.apply_rope(k, positions, cfg.rope_theta)
        ctx = attn_lib.attend(q, k, v, positions, positions, causal=False,
                              window=0, impl="naive", extra_mask=valid)
        x = x + jnp.einsum("bshk,hkd->bsd", ctx, lp["attn"]["w_o"])
        h2 = apply_norm(lp["norm2"], x, cfg.norm_type)
        return x + apply_mlp(lp["mlp"], h2, cfg.mlp_type), None

    x, _ = jax.lax.scan(body, x, params["scan"])
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    m = mask.astype(jnp.float32)[..., None]
    pooled = jnp.sum(x.astype(jnp.float32) * m, axis=1) / jnp.maximum(
        jnp.sum(m, axis=1), 1.0)
    return jnp.einsum("bd,do->bo", pooled, params["score_head"])[:, 0]


def score_shortlist(params, q_tokens, q_mask, cand_tokens, cand_mask,
                    cfg: ModelConfig, sep_token: int = 3):
    """Score one query against its K shortlist candidates -> logits (B, K).

    ``q_tokens``/``q_mask`` (B, Sq); ``cand_tokens``/``cand_mask``
    (B, K, Sc).  Flattens to a (B*K) pair batch for :func:`score_pairs` —
    candidates are scored independently, so the result is
    permutation-equivariant over the candidate axis by construction.
    """
    b, k, sc = cand_tokens.shape
    qt = jnp.repeat(q_tokens, k, axis=0)
    qm = jnp.repeat(q_mask, k, axis=0)
    flat = score_pairs(params, qt, qm, cand_tokens.reshape(b * k, sc),
                       cand_mask.reshape(b * k, sc), cfg, sep_token)
    return flat.reshape(b, k)
