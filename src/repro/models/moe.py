"""GShard-style top-k Mixture-of-Experts with capacity dispatch.

TPU-native formulation: the dispatch/combine tensors are einsummed so GSPMD
turns expert-sharded contractions into all-to-alls.  Experts are sharded on
the ``model`` mesh axis (expert parallelism); Arctic's parallel dense-FFN
residual is supported via ``moe_dense_residual``.

The einsum-dispatch FLOPs overhead is the known GShard cost; the sort-based
dispatch in ``dispatch_impl='sort'`` is the beyond-paper optimization lane
(see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def init_moe(key, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (std_in * jax.random.truncated_normal(ks[1], -2, 2, (e, d, f))).astype(dt),
        "w_up": (std_in * jax.random.truncated_normal(ks[2], -2, 2, (e, d, f))).astype(dt),
        "w_down": (std_out * jax.random.truncated_normal(ks[3], -2, 2, (e, f, d))).astype(dt),
    }
    if cfg.moe_dense_residual:
        from .layers import init_mlp
        p["dense"] = init_mlp(ks[4], d, cfg.d_ff, "swiglu", dt)
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(cfg.capacity_factor * cfg.experts_per_token * tokens_per_group  # hostsync: ok static config arithmetic
            / cfg.num_experts)
    return max(8, ((c + 7) // 8) * 8)


def router_topk(params, x, cfg: ModelConfig):
    """x: (G,S,d) -> (probs (G,S,k), idx (G,S,k), aux_loss scalar)."""
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), params["router"])
    k = cfg.experts_per_token
    topv, topi = jax.lax.top_k(logits, k)
    probs = jax.nn.softmax(topv, axis=-1)
    # Load-balance auxiliary loss (Switch-style): mean_prob * mean_assign * E
    all_probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(all_probs, axis=(0, 1))                       # (E,)
    assign = jax.nn.one_hot(topi[..., 0], cfg.num_experts)      # top-1 assignment share
    ce = jnp.mean(assign, axis=(0, 1))
    aux = cfg.num_experts * jnp.sum(me * ce)
    return probs, topi, aux


def apply_moe(params, x, cfg: ModelConfig):
    """x: (B,S,d) -> (out (B,S,d), aux_loss).  GShard capacity dispatch.

    Tokens are re-grouped into fixed-size dispatch groups (moe_group_size):
    the (G, S_g, E, C) dispatch tensor and its einsum cost scale with the
    GROUP length, not the full sequence — at 32k tokens per group the
    dispatch einsum would dwarf the expert matmuls (see EXPERIMENTS.md
    §Perf H1).
    """
    b, s, d = x.shape
    n = b * s
    gsz = min(cfg.moe_group_size, n)
    pad = (-n) % gsz
    xf = x.reshape(n, d)
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)], axis=0)
    xg = xf.reshape((n + pad) // gsz, gsz, d)
    # re-seed the batch sharding on the group dim: GSPMD loses it through
    # the (B,S)->(G,gsz) reshape and would replicate activations per layer
    from . import sharding_utils as shu
    xg = shu.constrain(xg, shu.BATCH, None, None)
    g_, s_ = xg.shape[0], gsz
    probs, topi, aux = router_topk(params, xg, cfg)
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = _capacity(cfg, s_)

    # Position of each (token, choice) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)            # (G,S,k,E)
    flat = onehot.reshape(g_, s_ * k, e)
    pos_in_e = jnp.cumsum(flat, axis=1) - 1                      # (G,S*k,E)
    pos = jnp.sum(pos_in_e * flat, axis=-1).reshape(g_, s_, k)   # (G,S,k)
    keep = pos < cap
    wts = probs * keep                                            # drop overflow

    # dispatch: (G,S,E,C) one-hot over (expert, slot)
    disp = jnp.einsum(
        "gske,gskc->gsec",
        jax.nn.one_hot(topi, e, dtype=x.dtype) * keep[..., None].astype(x.dtype),
        jax.nn.one_hot(pos, cap, dtype=x.dtype))
    # combine: like dispatch but carrying the routing probabilities
    comb = jnp.einsum(
        "gske,gskc,gsk->gsec",
        jax.nn.one_hot(topi, e, dtype=jnp.float32) * keep[..., None],
        jax.nn.one_hot(pos, cap, dtype=jnp.float32),
        wts).astype(x.dtype)

    # to experts: (E,G,C,d)
    ex_in = jnp.einsum("gsec,gsd->egcd", disp, xg)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", ex_in, params["w_gate"]))
    h = h * jnp.einsum("egcd,edf->egcf", ex_in, params["w_up"])
    ex_out = jnp.einsum("egcf,efd->egcd", h, params["w_down"])
    out = jnp.einsum("egcd,gsec->gsd", ex_out, comb)

    if cfg.moe_dense_residual:
        from .layers import apply_mlp
        out = out + apply_mlp(params["dense"], xg, "swiglu")
    out = out.reshape(g_ * s_, d)
    if pad:
        out = out[:n]
    return out.reshape(b, s, d), aux * cfg.router_aux_coef
