"""GQA attention: naive, blockwise-XLA-flash, and (via kernels/) Pallas impls.

Three entry points used by transformer.py / encdec.py:

* ``self_attention``  — full-sequence (train / prefill); returns output and
  the rotary-applied (k, v) for KV-cache construction.
* ``decode_attention`` — one new token against a KV cache (ring-buffered for
  sliding-window archs).
* ``cross_attention``  — decoder-over-encoder-memory (whisper).

The ``xla_flash`` implementation is a lax.scan over KV blocks with running
max/sum-exp (flash semantics expressed in XLA) so 32k-token prefill never
materialises an (S, S) score tensor.  The Pallas kernel in
``repro.kernels.flash_attention`` is the TPU-target version of the same
algorithm; ``attention_impl='pallas'`` dispatches to it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig):
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    p = {
        "w_q": dense_init(ks[0], d, h * dh, dt).reshape(d, h, dh),
        "w_k": dense_init(ks[1], d, hk * dh, dt).reshape(d, hk, dh),
        "w_v": dense_init(ks[2], d, hk * dh, dt).reshape(d, hk, dh),
        "w_o": dense_init(ks[3], h * dh, d, dt).reshape(h, dh, d),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h, dh), dt)
        p["b_k"] = jnp.zeros((hk, dh), dt)
        p["b_v"] = jnp.zeros((hk, dh), dt)
    return p


def _project_qkv(params, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    if cfg.qkv_bias:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    return q, k, v


def _mask(q_pos, k_pos, causal: bool, window: int):
    """(..., Sq, Sk) boolean allowed-mask from position vectors."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    return m


def _attend_naive(q, k, v, q_pos, k_pos, causal, window, extra_mask=None):
    """q: (B,Sq,H,dh), k/v: (B,Sk,Hk,dh) -> (B,Sq,H,dh). fp32 softmax."""
    b, sq, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, sq, hk, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (dh ** -0.5)
    m = _mask(q_pos, k_pos, causal, window)[:, None, None]  # (B,1,1,Sq,Sk)
    if extra_mask is not None:
        m = m & extra_mask[:, None, None, None, :]
    scores = jnp.where(m, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(b, sq, h, dh)


def _attend_xla_flash(q, k, v, q_pos, k_pos, causal, window, block_q, block_k,
                      extra_mask=None):
    """Blockwise flash attention in pure XLA: scan over KV blocks per Q block.

    Block sizes are FIXED (never clamped to the sequence): short inputs
    pad up to one block.  That makes the reduction *length-invariant* —
    every key-axis reduction runs over exactly ``block_k`` lanes in the
    same order, and appended fully-masked blocks are exact no-ops in the
    running max/sum/acc recurrence (``exp(NEG_INF)=0``, ``corr=1``).  So
    attention over ``[prefix | suffix]`` is bitwise identical whether the
    prefix KV was computed in a prefix-only pass or inline — the property
    the prefix-cached prefill's byte-identical contract rests on
    (DESIGN.md §9).  The naive impl does NOT have this property: XLA
    reassociates its full-axis softmax reductions differently per length.
    """
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    bq = block_q
    bk = block_k
    # Pad sequence dims to block multiples.
    pq = (-sq) % bq
    pk = (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pq)), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pk)), constant_values=2 ** 30)
        if extra_mask is not None:
            extra_mask = jnp.pad(extra_mask, ((0, 0), (0, pk)))
    nq, nk = q.shape[1] // bq, k.shape[1] // bk
    qg = q.reshape(b, nq, bq, hk, g, dh)
    kb = k.reshape(b, nk, bk, hk, dh)
    vb = v.reshape(b, nk, bk, hk, dh)
    kpb = k_pos.reshape(b, nk, bk)
    emb = None if extra_mask is None else extra_mask.reshape(b, nk, bk)
    qpb = q_pos.reshape(b, nq, bq)
    scale = dh ** -0.5

    def q_block(qi, qp):
        # qi: (b, bq, hk, g, dh); qp: (b, bq)
        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            ki, vi, kp, em = inp  # (b,bk,hk,dh), (b,bk,hk,dh), (b,bk), (b,bk)|None
            s = jnp.einsum("bskgd,btkd->bkgst", qi, ki).astype(jnp.float32) * scale
            allowed = _mask(qp, kp, causal, window)[:, None, None]
            if em is not None:
                allowed = allowed & em[:, None, None, None, :]
            s = jnp.where(allowed, s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hk, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hk, g, bq, dh), jnp.float32)
        xs = (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.moveaxis(kpb, 1, 0),
              None if emb is None else jnp.moveaxis(emb, 1, 0))
        if emb is None:
            (mf, lf, accf), _ = jax.lax.scan(
                lambda c, i: kv_step(c, (*i, None)), (m0, l0, a0), xs[:3])
        else:
            (mf, lf, accf), _ = jax.lax.scan(kv_step, (m0, l0, a0), xs)
        out = accf / jnp.maximum(lf[..., None], 1e-30)
        return jnp.einsum("bkgsd->bskgd", out)  # (b,bq,hk,g,dh)

    outb = jax.lax.map(
        lambda i: q_block(qg[:, i], qpb[:, i]), jnp.arange(nq))  # (nq,b,bq,hk,g,dh)
    out = jnp.moveaxis(outb, 0, 1).reshape(b, nq * bq, h, dh)
    return out[:, :sq].astype(q.dtype)


def _attend_pallas(q, k, v, q_pos, k_pos, causal, window, block_q, block_k):
    from repro.kernels.flash_attention import ops as flash_ops
    return flash_ops.flash_attention(
        q, k, v, q_pos, k_pos, causal=causal, window=window,
        block_q=block_q, block_k=block_k)


def attend(q, k, v, q_pos, k_pos, *, causal: bool, window: int, impl: str,
           block_q: int = 512, block_k: int = 512, extra_mask=None):
    sq, sk = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "xla_flash" if max(sq, sk) > 2048 else "naive"
    if impl == "naive":
        return _attend_naive(q, k, v, q_pos, k_pos, causal, window, extra_mask)
    if impl == "pallas":
        if extra_mask is not None:
            raise NotImplementedError("pallas path has no extra_mask")
        return _attend_pallas(q, k, v, q_pos, k_pos, causal, window, block_q, block_k)
    return _attend_xla_flash(q, k, v, q_pos, k_pos, causal, window,
                             block_q, block_k, extra_mask)


# ------------------------------------------------------------- entry points

def self_attention(params, x, positions, cfg: ModelConfig, *, causal=True,
                   window: int = 0, use_rope=True, prefix=None):
    """Full-sequence self attention.  Returns (out, (k, v)) — k/v post-rope.

    ``prefix`` (optional) is a KV cache dict for an already-prefilled
    shared prefix (``{"k": (B,P,hk,dh), "v": ..., "slot_pos": (B,P)}``,
    rope already applied at the prefix's own positions).  The queries —
    whose ``positions`` must start AFTER the prefix — then attend over
    ``[prefix | self]``, and the returned k/v are the concatenated
    ``(k_all, v_all, k_pos_all)`` covering both, ready for
    ``fill_kv_cache`` to lay out slots ``[0, P+S)`` exactly as a full
    prefill would (DESIGN.md §9).
    """
    q, k, v = _project_qkv(params, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if prefix is None:
        ctx = attend(q, k, v, positions, positions, causal=causal,
                     window=window, impl=cfg.attention_impl,
                     block_q=cfg.flash_block_q, block_k=cfg.flash_block_k)
        out = jnp.einsum("bshk,hkd->bsd", ctx, params["w_o"])
        return out, (k, v)
    k_all = jnp.concatenate([prefix["k"].astype(k.dtype), k], axis=1)
    v_all = jnp.concatenate([prefix["v"].astype(v.dtype), v], axis=1)
    k_pos = jnp.concatenate([prefix["slot_pos"], positions], axis=1)
    ctx = attend(q, k_all, v_all, positions, k_pos, causal=causal,
                 window=window, impl=cfg.attention_impl,
                 block_q=cfg.flash_block_q, block_k=cfg.flash_block_k)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["w_o"])
    return out, (k_all, v_all, k_pos)


def init_kv_cache(batch, capacity, cfg: ModelConfig, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    hk, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, capacity, hk, dh), dt),
        "v": jnp.zeros((batch, capacity, hk, dh), dt),
        "pos": jnp.zeros((), jnp.int32),        # total tokens seen so far
        "slot_pos": jnp.zeros((batch, capacity), jnp.int32) - 1,  # abs position per slot
    }


def fill_kv_cache(cache, k, v, positions):
    """Write a prefill's k/v (B,S,hk,dh) into slots [0, S) (S <= capacity)."""
    s = k.shape[1]
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    cache["slot_pos"] = jax.lax.dynamic_update_slice(
        cache["slot_pos"], positions.astype(jnp.int32), (0, 0))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return cache


def _paged_decode_attention(params, x, cache, cfg: ModelConfig, *,
                            use_rope=True):
    """One-token decode against paged KV (DESIGN.md §11).

    ``cache`` is a paged leaf: ``kp``/``vp`` are the pool's page arrays
    ``(num_pages + 1, page, hk, dh)``, ``block_tbl`` (B, npg) maps each
    row's logical pages to physical ones, ``pos`` (B,) is per-row (rows
    of a persistent slot batch sit at different depths), and
    ``slot_pos`` (B, cap) keeps the EXACT dense logical capacity.

    Bitwise contract with the dense path: the new token is scattered
    into its page, then K/V are gathered back through the block table
    into logical-slot order and SLICED to ``cap`` — pure data movement —
    and the attend call is identical (same impl, same shapes, same
    mask).  Rows whose block table points at the TRASH page (evicted /
    empty slots) write there harmlessly and attend over an all-masked
    cache; their sampled tokens are discarded by done-masking upstream.
    """
    b = x.shape[0]
    kp, vp, tbl = cache["kp"], cache["vp"], cache["block_tbl"]
    page = kp.shape[1]
    npg = tbl.shape[1]
    cap = cache["slot_pos"].shape[1]
    pos = cache["pos"]                                   # (B,) per-row
    q, k, v = _project_qkv(params, x, cfg)
    cur = pos[:, None]                                   # (B, 1)
    if use_rope:
        q = apply_rope(q, cur, cfg.rope_theta)
        k = apply_rope(k, cur, cfg.rope_theta)
    slot = jnp.minimum(pos, cap - 1)                     # (B,)
    pg = jnp.take_along_axis(tbl, (slot // page)[:, None], axis=1)[:, 0]
    off = slot % page
    kp = kp.at[pg, off].set(k[:, 0].astype(kp.dtype))
    vp = vp.at[pg, off].set(v[:, 0].astype(vp.dtype))
    hot = jnp.arange(cap, dtype=jnp.int32)[None, :] == slot[:, None]
    slot_pos = jnp.where(hot, cur, cache["slot_pos"])
    kg = kp[tbl].reshape(b, npg * page, *kp.shape[2:])[:, :cap]
    vg = vp[tbl].reshape(b, npg * page, *vp.shape[2:])[:, :cap]
    valid = slot_pos >= 0
    ctx = attend(q, kg, vg, cur, slot_pos, causal=True, window=0,
                 impl="naive", extra_mask=valid)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["w_o"])
    new_cache = dict(cache)
    new_cache.update(kp=kp, vp=vp, slot_pos=slot_pos, pos=pos + 1)
    return out, new_cache


def decode_attention(params, x, cache, cfg: ModelConfig, *, window: int = 0,
                     use_rope=True):
    """One-token decode: x (B,1,d) against ring-buffered KV cache."""
    if "kp" in cache:
        if window > 0:
            raise NotImplementedError(
                "paged KV decode is global-attention only; windowed "
                "stacks must use the dense ring-buffered cache")
        return _paged_decode_attention(params, x, cache, cfg,
                                       use_rope=use_rope)
    b = x.shape[0]
    capacity = cache["k"].shape[1]
    pos = cache["pos"]  # scalar: number of tokens already in cache
    q, k, v = _project_qkv(params, x, cfg)
    cur = jnp.full((b, 1), pos, jnp.int32)
    if use_rope:
        q = apply_rope(q, cur, cfg.rope_theta)
        k = apply_rope(k, cur, cfg.rope_theta)
    slot = jnp.where(window > 0, pos % capacity, jnp.minimum(pos, capacity - 1))
    # One-hot masked write instead of dynamic_update_slice: elementwise over
    # the (possibly model-axis-sharded) sequence dim, so GSPMD never has to
    # all-gather the cache to place the new token (the donated buffer makes
    # it an in-place masked store).
    hot = (jnp.arange(capacity, dtype=jnp.int32) == slot)          # (T,)
    hot_kv = hot[None, :, None, None]
    new_cache = dict(cache)
    new_cache["k"] = jnp.where(hot_kv, k.astype(cache["k"].dtype), cache["k"])
    new_cache["v"] = jnp.where(hot_kv, v.astype(cache["v"].dtype), cache["v"])
    new_cache["slot_pos"] = jnp.where(hot[None, :], pos, cache["slot_pos"])
    new_cache["pos"] = pos + 1
    k_pos = new_cache["slot_pos"]  # (B, capacity); -1 = never written
    valid = k_pos >= 0
    ctx = attend(q, new_cache["k"], new_cache["v"], cur, k_pos,
                 causal=True, window=window, impl="naive", extra_mask=valid)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["w_o"])
    return out, new_cache


# ----------------------------------------------------- q-block decode (k>1)

def _paged_decode_attention_block(params, x, cache, cfg: ModelConfig, *,
                                  use_rope=True):
    """(B, k)-block decode against paged KV (speculative verify, §14).

    Same contract as ``_paged_decode_attention`` with k query positions
    per row: K/V for positions ``[pos, pos + k)`` are scattered
    optimistically through the block table (the caller rewinds rejected
    suffixes via ``paged_kv.rewind_kv``), and the queries attend over
    the full gathered cache with causal masking — in-block causality
    falls out of the position comparison, no special path.  Writes whose
    logical slot falls beyond the capacity are redirected to the TRASH
    page (their query rows are garbage that budget-clamping upstream
    never emits — same discard-by-masking contract as evicted rows).
    """
    b, kblk = x.shape[0], x.shape[1]
    kp, vp, tbl = cache["kp"], cache["vp"], cache["block_tbl"]
    page = kp.shape[1]
    npg = tbl.shape[1]
    cap = cache["slot_pos"].shape[1]
    trash = kp.shape[0] - 1
    pos = cache["pos"]                                   # (B,) per-row
    q, k, v = _project_qkv(params, x, cfg)
    cur = pos[:, None] + jnp.arange(kblk, dtype=jnp.int32)[None, :]  # (B,k)
    if use_rope:
        q = apply_rope(q, cur, cfg.rope_theta)
        k = apply_rope(k, cur, cfg.rope_theta)
    slot = jnp.minimum(cur, cap - 1)                     # (B,k) clamped
    pg = jnp.take_along_axis(tbl, slot // page, axis=1)
    pg = jnp.where(cur < cap, pg, trash)                 # overflow -> TRASH
    off = slot % page
    kp = kp.at[pg, off].set(k.astype(kp.dtype))
    vp = vp.at[pg, off].set(v.astype(vp.dtype))
    c = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32)[None, :], (b, cap))
    in_blk = (c >= pos[:, None]) & (c < pos[:, None] + kblk)
    slot_pos = jnp.where(in_blk, c, cache["slot_pos"])
    kg = kp[tbl].reshape(b, npg * page, *kp.shape[2:])[:, :cap]
    vg = vp[tbl].reshape(b, npg * page, *vp.shape[2:])[:, :cap]
    valid = slot_pos >= 0
    ctx = attend(q, kg, vg, cur, slot_pos, causal=True, window=0,
                 impl="naive", extra_mask=valid)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["w_o"])
    new_cache = dict(cache)
    new_cache.update(kp=kp, vp=vp, slot_pos=slot_pos, pos=pos + kblk)
    return out, new_cache


def decode_attention_block(params, x, cache, cfg: ModelConfig, *,
                           use_rope=True):
    """(B, k)-block decode: k candidate tokens per row in ONE forward.

    The speculative verify step (DESIGN.md §14): ``x`` (B, k, d) embeds
    the last accepted token followed by k-1 draft tokens; all k
    positions' K/V are written optimistically at slots
    ``[pos, pos + k)`` and the k queries attend causally over the whole
    cache (in-block causality comes from the position mask, since slot
    index == absolute position for global attention).  The caller keeps
    the longest accepted prefix and rewinds the rest
    (``paged_kv.rewind_kv``).

    Unlike ``decode_attention``, the dense cache's ``pos`` MUST already
    be per-row (B,) — rows of a speculating batch sit at different
    depths after their first divergence (``paged_kv.row_pos_caches``
    converts a fresh prefill).  With k == 1 this computes exactly what
    ``decode_attention`` computes (same write mask, same attend shapes),
    which the differential tests pin token-for-token.
    """
    if "kp" in cache:
        return _paged_decode_attention_block(params, x, cache, cfg,
                                             use_rope=use_rope)
    b, kblk = x.shape[0], x.shape[1]
    cap = cache["k"].shape[1]
    pos = cache["pos"]                                   # (B,) per-row
    q, k, v = _project_qkv(params, x, cfg)
    cur = pos[:, None] + jnp.arange(kblk, dtype=jnp.int32)[None, :]  # (B,k)
    if use_rope:
        q = apply_rope(q, cur, cfg.rope_theta)
        k = apply_rope(k, cur, cfg.rope_theta)
    # Masked gather-write instead of a scatter: slot c of row b takes the
    # block's (c - pos_b)-th token when c lands inside [pos_b, pos_b + k)
    # — elementwise over the sequence dim like the one-hot single-token
    # write, so GSPMD never all-gathers the cache.  Positions beyond the
    # capacity simply don't write (their queries are discarded upstream).
    c = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32)[None, :], (b, cap))
    in_blk = (c >= pos[:, None]) & (c < pos[:, None] + kblk)
    hot = in_blk[:, :, None, None]
    new_cache = dict(cache)
    if kblk == 1:
        # Fallback-phase hot path (every draft-exhausted row decodes
        # through here): the gather-select degenerates to a broadcast of
        # the single token, same cost class as the one-hot write above.
        k_new, v_new = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    else:
        idx = jnp.clip(c - pos[:, None], 0, kblk - 1)
        kv_sel = idx[:, :, None, None]
        k_new = jnp.take_along_axis(k.astype(cache["k"].dtype), kv_sel,
                                    axis=1)
        v_new = jnp.take_along_axis(v.astype(cache["v"].dtype), kv_sel,
                                    axis=1)
    new_cache["k"] = jnp.where(hot, k_new, cache["k"])
    new_cache["v"] = jnp.where(hot, v_new, cache["v"])
    new_cache["slot_pos"] = jnp.where(in_blk, c, cache["slot_pos"])
    new_cache["pos"] = pos + kblk
    k_pos = new_cache["slot_pos"]
    valid = k_pos >= 0
    ctx = attend(q, new_cache["k"], new_cache["v"], cur, k_pos,
                 causal=True, window=0, impl="naive", extra_mask=valid)
    out = jnp.einsum("bshk,hkd->bsd", ctx, params["w_o"])
    return out, new_cache


# ------------------------------------------------------------- cross attn

def init_cross_attention(key, cfg: ModelConfig):
    return init_attention(key, cfg)


def cross_attention(params, x, memory, cfg: ModelConfig):
    """Decoder query over encoder memory (no rope, no mask)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("btd,dhk->bthk", memory, params["w_k"])
    v = jnp.einsum("btd,dhk->bthk", memory, params["w_v"])
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    b, sq = x.shape[0], x.shape[1]
    t = memory.shape[1]
    qp = jnp.zeros((b, sq), jnp.int32)
    kp = jnp.zeros((b, t), jnp.int32)
    ctx = attend(q, k, v, qp, kp, causal=False, window=0, impl="naive")
    return jnp.einsum("bshk,hkd->bsd", ctx, params["w_o"])
