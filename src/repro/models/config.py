"""Unified model configuration covering every assigned architecture family.

One ``ModelConfig`` expresses dense/GQA, sliding-window, MoE (with optional
parallel dense residual, for Arctic), Mamba-2 SSD, RG-LRU hybrids,
encoder-decoder (whisper) and VLM/audio prefix-embedding frontends.

``block_pattern`` is the repeating period of block kinds; heterogeneous
stacks (RecurrentGemma's RG-RG-ATTN) still scan over whole periods, with the
remainder layers applied unscanned.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# Block kinds understood by transformer.py
ATTN = "attn"              # global attention + dense MLP
LOCAL_ATTN = "local_attn"  # sliding-window attention + dense MLP
MOE = "moe"                # global attention + MoE FFN (optional dense residual)
MAMBA2 = "mamba2"          # SSD mixer only (no MLP)
RGLRU = "rglru"            # RG-LRU recurrent block + dense MLP


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm | audio

    # Core transformer dims.
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # Block layout.
    block_pattern: Tuple[str, ...] = (ATTN,)

    # Attention details.
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = global; >0 = SWA width (for LOCAL_ATTN / all-attn SWA archs)
    attention_impl: str = "auto"     # auto | naive | xla_flash | pallas
    # Flash block sizes are FIXED, never clamped to the sequence (the
    # length-invariance the prefix-prefill contract needs, DESIGN.md §9):
    # short inputs pad UP to one block, so serving configs that run short
    # prefills through xla_flash should size these near their typical
    # length bucket (the tweak-path models use 32).
    flash_block_q: int = 128
    flash_block_k: int = 128

    # MLP.
    mlp_type: str = "swiglu"  # swiglu | gelu | squared_relu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm

    # MoE.
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_dense_residual: bool = False  # Arctic: parallel dense FFN residual
    capacity_factor: float = 1.0
    router_aux_coef: float = 0.01
    moe_group_size: int = 2048   # GShard dispatch group (tokens); capacity
                                 # scales with the group, so fixed-size groups
                                 # keep dispatch-einsum cost ~ expert cost

    # Mamba-2 SSD.
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # RG-LRU.
    rnn_width: int = 0               # 0 -> d_model
    rglru_c: float = 8.0
    rglru_conv_width: int = 4

    # Encoder-decoder (whisper).
    enc_layers: int = 0
    enc_frames: int = 1500           # stub conv-frontend output length

    # Prefix-embedding frontend (VLM/audio stub).
    frontend: str = "none"           # none | vision_stub | audio_stub
    num_prefix_tokens: int = 0
    frontend_dim: int = 0            # raw embedding dim from the stubbed encoder

    # Numerics / training.
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    logits_softcap: float = 0.0
    max_seq_len: int = 8192
    remat: bool = False
    scan_layers: bool = True
    train_microbatches: int = 1  # grad-accum steps for train_4k (memory lever)

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 for even TP sharding."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def pattern_periods(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def pattern_remainder(self) -> Tuple[str, ...]:
        r = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:r]

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def resolved_rnn_width(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def is_subquadratic(self) -> bool:
        """True iff every block kind decodes with O(1)-or-windowed state."""
        for kind in self.block_pattern:
            if kind in (ATTN, MOE) and self.sliding_window <= 0:
                return False
        return True

    @property
    def decode_cache_len_cap(self) -> int:
        """Max KV entries a cache must physically hold per attention layer."""
        return self.sliding_window if self.sliding_window > 0 else 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter-count estimate (exact vocab, analytic).  N for MODEL_FLOPS.
    def param_count(self, active_only: bool = False) -> int:
        d, dh = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        per_kind = {}
        attn_p = d * (self.num_heads + 2 * self.num_kv_heads) * dh + self.num_heads * dh * d
        if self.qkv_bias:
            attn_p += (self.num_heads + 2 * self.num_kv_heads) * dh
        mlp_mult = 3 if self.mlp_type == "swiglu" else 2
        mlp_p = mlp_mult * d * self.d_ff
        per_kind[ATTN] = attn_p + mlp_p
        per_kind[LOCAL_ATTN] = attn_p + mlp_p
        if self.num_experts:
            e = self.num_experts if not active_only else self.experts_per_token
            moe_mlp_mult = 3  # swiglu experts
            moe_p = e * moe_mlp_mult * d * self.moe_d_ff + d * self.num_experts
            if self.moe_dense_residual:
                moe_p += mlp_p
            per_kind[MOE] = attn_p + moe_p
        if self.ssm_state:
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            g = self.ssm_groups
            in_p = d * (2 * di + 2 * g * ns + nh)
            conv_p = (di + 2 * g * ns) * self.ssm_conv_width
            out_p = di * d
            per_kind[MAMBA2] = in_p + conv_p + out_p + 2 * nh + di
        if RGLRU in self.block_pattern:
            w = self.resolved_rnn_width
            per_kind[RGLRU] = d * w * 2 + w * d + w * self.rglru_conv_width + 3 * w + mlp_p
        for i in range(self.num_layers):
            kind = self.block_pattern[i % len(self.block_pattern)]
            total += per_kind[kind]
        if self.enc_layers:
            total += self.enc_layers * (attn_p + mlp_p)
        return int(total)  # hostsync: ok static config arithmetic, no device values
