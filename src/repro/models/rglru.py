"""RG-LRU recurrent block (RecurrentGemma, arXiv:2402.19427).

Block = two parallel branches over the normed input:
  gate branch   : GeLU(W_y x)
  temporal branch: W_x x -> causal depthwise conv1d -> RG-LRU
merged elementwise, then projected back to d_model.

The RG-LRU diagonal recurrence
  h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t * u_t),
  a_t = exp(c * r_t * log sigmoid(lambda))
runs as a jax.lax.associative_scan over the sequence (log-depth on TPU);
decode is a single fused step over the carried (B, W) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init


def init_rglru(key, cfg: ModelConfig):
    d, w = cfg.d_model, cfg.resolved_rnn_width
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    # init lambda so that a ~ uniform(0.9, 0.999) at r=1 (standard LRU init)
    u = jax.random.uniform(ks[4], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(u / (1 - u))  # sigmoid^-1
    return {
        "w_y": dense_init(ks[0], d, w, dt),
        "w_x": dense_init(ks[1], d, w, dt),
        "conv_w": (0.1 * jax.random.normal(ks[2], (w, cfg.rglru_conv_width))).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_a": dense_init(ks[3], w, w, jnp.float32, stddev=w ** -0.5),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(ks[5], w, w, jnp.float32, stddev=w ** -0.5),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "w_o": dense_init(jax.random.fold_in(key, 7), w, d, dt, stddev=w ** -0.5),
    }


def _gates(params, u, cfg: ModelConfig):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf, params["w_a"]) + params["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf, params["w_i"]) + params["b_i"])
    log_a = cfg.rglru_c * r * jax.nn.log_sigmoid(params["lam"])  # (B,S,W) negative
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-8)) * (i * uf)
    return a, gated


def _conv(params, x, conv_state=None):
    w = params["conv_w"].astype(jnp.float32)
    width = w.shape[1]
    xf = x.astype(jnp.float32)
    pad = (jnp.zeros((xf.shape[0], width - 1, xf.shape[2]), xf.dtype)
           if conv_state is None else conv_state.astype(jnp.float32))
    xp = jnp.concatenate([pad, xf], axis=1)
    y = sum(xp[:, i:i + xf.shape[1], :] * w[:, i] for i in range(width))
    return (y + params["conv_b"].astype(jnp.float32)).astype(x.dtype), \
        xp[:, -(width - 1):, :].astype(x.dtype)


def rglru_forward(params, x, cfg: ModelConfig):
    """x: (B,S,d) -> (out (B,S,d), state dict)."""
    y_branch = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_y"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    u, conv_state = _conv(params, u)
    a, b = _gates(params, u, cfg)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = hh.astype(x.dtype)                                # (B,S,W)
    merged = y_branch * h
    out = jnp.einsum("bsw,wd->bsd", merged, params["w_o"])
    state = {"h": hh[:, -1].astype(jnp.float32), "conv": conv_state}
    return out, state


def init_rglru_state(batch, cfg: ModelConfig, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    w = cfg.resolved_rnn_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, w), dt),
    }


def rglru_decode(params, x, state, cfg: ModelConfig):
    """Single step.  x: (B,1,d)."""
    y_branch = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_y"]))
    u = jnp.einsum("bsd,dw->bsw", x, params["w_x"])
    u, conv_state = _conv(params, u, conv_state=state["conv"])
    a, b = _gates(params, u, cfg)                          # (B,1,W)
    h = a[:, 0] * state["h"] + b[:, 0]
    merged = y_branch * h[:, None, :].astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", merged, params["w_o"])
    return out, {"h": h, "conv": conv_state}
