"""Whisper-style encoder-decoder.

The mel-spectrogram + conv feature extractor is STUBBED per the assignment
carve-out: ``input_specs`` supplies post-conv frame embeddings
(B, enc_frames, d_model).  Everything downstream — sinusoidal positions,
bidirectional encoder, causal decoder with cross-attention, KV caches for
both self- and cross-attention — is implemented here.

Both stacks scan over layers.  Decode caches: per-layer self-attention ring
cache + per-layer cross K/V computed once at prefill.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from . import attention as attn_lib
from . import sharding_utils as shu
from .config import ModelConfig
from .layers import (apply_mlp, apply_norm, init_mlp, init_norm,
                     sinusoidal_positions, truncated_normal)


def _init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "norm1": init_norm(d, cfg.norm_type),
        "attn": attn_lib.init_attention(ks[0], cfg),
        "norm2": init_norm(d, cfg.norm_type),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_type, jnp.dtype(cfg.dtype)),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "norm1": init_norm(d, cfg.norm_type),
        "self_attn": attn_lib.init_attention(ks[0], cfg),
        "norm2": init_norm(d, cfg.norm_type),
        "cross_attn": attn_lib.init_cross_attention(ks[1], cfg),
        "norm3": init_norm(d, cfg.norm_type),
        "mlp": init_mlp(ks[2], d, cfg.d_ff, cfg.mlp_type, jnp.dtype(cfg.dtype)),
    }


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    params: Dict[str, Any] = {
        "embed": truncated_normal(ks[0], (cfg.padded_vocab, cfg.d_model), 0.02, dt),
        "enc_norm": init_norm(cfg.d_model, cfg.norm_type),
        "final_norm": init_norm(cfg.d_model, cfg.norm_type),
    }
    ek = jax.random.split(ks[1], cfg.enc_layers)
    params["enc_scan"] = jax.vmap(lambda k: _init_enc_layer(k, cfg))(ek)
    dk = jax.random.split(ks[2], cfg.num_layers)
    params["dec_scan"] = jax.vmap(lambda k: _init_dec_layer(k, cfg))(dk)
    # whisper ties the output head to the token embedding
    return params


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable,
                              prevent_cse=False)
    return fn


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, F, d_model) stub conv output -> memory (B, F, d_model)."""
    f = frames.shape[1]
    x = frames.astype(jnp.dtype(cfg.dtype)) + sinusoidal_positions(
        f, cfg.d_model).astype(jnp.dtype(cfg.dtype))
    x = shu.constrain(x, shu.BATCH, None, None)
    b = x.shape[0]
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

    def body(x, lp):
        h = apply_norm(lp["norm1"], x, cfg.norm_type)
        a, _ = attn_lib.self_attention(lp["attn"], h, positions, cfg,
                                       causal=False, use_rope=False)
        x = x + a
        h2 = apply_norm(lp["norm2"], x, cfg.norm_type)
        return x + apply_mlp(lp["mlp"], h2, cfg.mlp_type), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_scan"])
    return apply_norm(params["enc_norm"], x, cfg.norm_type)


def _dec_embed(params, tokens, cfg: ModelConfig, offset=0):
    x = jnp.take(params["embed"], tokens, axis=0)
    pe = sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
    pos = offset + jnp.arange(tokens.shape[1], dtype=jnp.int32)
    x = x + jnp.take(pe, pos, axis=0).astype(x.dtype)
    x = shu.constrain(x, shu.BATCH, None, None)
    positions = jnp.broadcast_to(pos, tokens.shape)
    return x, positions


def forward(params, frames, tokens, cfg: ModelConfig):
    """Training forward: (logits (B,S,Vp), aux=0)."""
    memory = encode(params, frames, cfg)
    x = jnp.take(params["embed"], tokens, axis=0)
    s = tokens.shape[1]
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    x = shu.constrain(x, shu.BATCH, None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), tokens.shape)

    def body(x, lp):
        h = apply_norm(lp["norm1"], x, cfg.norm_type)
        a, _ = attn_lib.self_attention(lp["self_attn"], h, positions, cfg,
                                       causal=True, use_rope=False)
        x = x + a
        h2 = apply_norm(lp["norm2"], x, cfg.norm_type)
        x = x + attn_lib.cross_attention(lp["cross_attn"], h2, memory, cfg)
        h3 = apply_norm(lp["norm3"], x, cfg.norm_type)
        return x + apply_mlp(lp["mlp"], h3, cfg.mlp_type), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec_scan"])
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = shu.constrain(jnp.einsum("bsd,vd->bsv", x, params["embed"]),
                           shu.BATCH, None, "model").astype(jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def _cross_kv(lp, memory, cfg: ModelConfig):
    k = jnp.einsum("btd,dhk->bthk", memory, lp["cross_attn"]["w_k"])
    v = jnp.einsum("btd,dhk->bthk", memory, lp["cross_attn"]["w_v"])
    if cfg.qkv_bias:
        k = k + lp["cross_attn"]["b_k"]
        v = v + lp["cross_attn"]["b_v"]
    return k, v


def prefill(params, frames, tokens, cfg: ModelConfig, capacity: int):
    """Returns (last-token logits (B,V), caches)."""
    memory = encode(params, frames, cfg)
    x, positions = _dec_embed(params, tokens, cfg)
    b, s = tokens.shape
    self_cache0 = attn_lib.init_kv_cache(b, capacity, cfg)
    self_caches0 = jax.tree.map(
        lambda t: jnp.broadcast_to(t, (cfg.num_layers,) + t.shape).copy(), self_cache0)

    def body(x, inp):
        lp, sc = inp
        h = apply_norm(lp["norm1"], x, cfg.norm_type)
        a, (k, v) = attn_lib.self_attention(lp["self_attn"], h, positions, cfg,
                                            causal=True, use_rope=False)
        sc = attn_lib.fill_kv_cache(sc, k, v, positions)
        x = x + a
        h2 = apply_norm(lp["norm2"], x, cfg.norm_type)
        x = x + attn_lib.cross_attention(lp["cross_attn"], h2, memory, cfg)
        ck, cv = _cross_kv(lp, memory, cfg)
        h3 = apply_norm(lp["norm3"], x, cfg.norm_type)
        return x + apply_mlp(lp["mlp"], h3, cfg.mlp_type), (sc, ck, cv)

    x, (self_caches, cross_k, cross_v) = jax.lax.scan(
        body, x, (params["dec_scan"], self_caches0))
    x = apply_norm(params["final_norm"], x[:, -1:], cfg.norm_type)
    logits = shu.constrain(jnp.einsum("bsd,vd->bsv", x, params["embed"]),
                           shu.BATCH, None, "model").astype(jnp.float32)
    caches = {"self": self_caches, "cross_k": cross_k, "cross_v": cross_v,
              "pos": jnp.asarray(s, jnp.int32)}
    return logits[:, 0], caches


def init_decode_caches(batch: int, capacity: int, cfg: ModelConfig):
    """Empty caches for a decode-only dry-run (prefill assumed done)."""
    self_cache0 = attn_lib.init_kv_cache(batch, capacity, cfg)
    self_caches = jax.tree.map(
        lambda t: jnp.broadcast_to(t, (cfg.num_layers,) + t.shape).copy(), self_cache0)
    hk, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = jnp.dtype(cfg.dtype)
    cross = jnp.zeros((cfg.num_layers, batch, cfg.enc_frames, hk, dh), dt)
    return {"self": self_caches, "cross_k": cross, "cross_v": cross,
            "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, token, caches, cfg: ModelConfig):
    """token: (B,).  Returns (logits (B,Vp), new caches)."""
    pos = caches["pos"]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    pe = sinusoidal_positions(cfg.max_seq_len, cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None].astype(x.dtype)
    x = shu.constrain(x, shu.BATCH, None, None)

    def body(x, inp):
        lp, sc, ck, cv = inp
        h = apply_norm(lp["norm1"], x, cfg.norm_type)
        a, sc = attn_lib.decode_attention(lp["self_attn"], h, sc, cfg,
                                          use_rope=False)
        x = x + a
        h2 = apply_norm(lp["norm2"], x, cfg.norm_type)
        q = jnp.einsum("bsd,dhk->bshk", h2, lp["cross_attn"]["w_q"])
        if cfg.qkv_bias:
            q = q + lp["cross_attn"]["b_q"]
        b = x.shape[0]
        qp = jnp.zeros((b, 1), jnp.int32)
        kp = jnp.zeros((b, ck.shape[1]), jnp.int32)
        ctx = attn_lib.attend(q, ck, cv, qp, kp, causal=False, window=0, impl="naive")
        x = x + jnp.einsum("bshk,hkd->bsd", ctx, lp["cross_attn"]["w_o"])
        h3 = apply_norm(lp["norm3"], x, cfg.norm_type)
        return x + apply_mlp(lp["mlp"], h3, cfg.mlp_type), (sc, ck, cv)

    x, (self_caches, ck, cv) = jax.lax.scan(
        body, x, (params["dec_scan"], caches["self"], caches["cross_k"],
                  caches["cross_v"]))
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    logits = shu.constrain(jnp.einsum("bsd,vd->bsv", x, params["embed"]),
                           shu.BATCH, None, "model").astype(jnp.float32)
    caches = {"self": self_caches, "cross_k": ck, "cross_v": cv, "pos": pos + 1}
    return logits[:, 0], caches
