"""Mamba-2 SSD (state-space duality) mixer — chunked scan formulation.

Follows arXiv:2405.21060: the intra-chunk term is the masked-matmul "dual"
form (MXU-friendly), inter-chunk states propagate through a lax.scan over
chunk boundaries, so the materialised state is O(S/chunk) not O(S).

Decode is a single-step recurrence over the (H, P, N) state plus a rolling
depthwise-conv state — O(1) per token, which is what makes the ``long_500k``
shape servable for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, init_norm, apply_norm


def _conv_dim(cfg: ModelConfig) -> int:
    return cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba2(key, cfg: ModelConfig):
    d = cfg.d_model
    di, ns, nh, g = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    in_dim = 2 * di + 2 * g * ns + nh
    return {
        "w_in": dense_init(ks[0], d, in_dim, dt),
        "conv_w": (0.1 * jax.random.normal(ks[1], (_conv_dim(cfg), cfg.ssm_conv_width))).astype(dt),
        "conv_b": jnp.zeros((_conv_dim(cfg),), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_norm": init_norm(di, "rmsnorm"),
        "w_out": dense_init(ks[2], di, d, dt, stddev=di ** -0.5),
    }


def _split_proj(params, x, cfg: ModelConfig):
    di, ns, nh, g = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [di, di + _conv_dim(cfg)], axis=-1)
    return z, xbc, dt  # dt: (B,S,nh)


def _causal_conv(params, xbc, conv_state=None):
    """Depthwise causal conv, width W.  xbc: (B,S,C).  Returns (y, new_state)."""
    w = params["conv_w"].astype(jnp.float32)  # (C, W)
    width = w.shape[1]
    xf = xbc.astype(jnp.float32)
    if conv_state is None:
        pad = jnp.zeros((xf.shape[0], width - 1, xf.shape[2]), xf.dtype)
    else:
        pad = conv_state.astype(jnp.float32)  # (B, W-1, C)
    xp = jnp.concatenate([pad, xf], axis=1)
    # explicit (1, 1, C) broadcasts keep this legal under
    # jax_numpy_rank_promotion="raise" (the sanitize harness)
    y = sum(xp[:, i:i + xf.shape[1], :] * w[None, None, :, i]
            for i in range(width))
    y = jax.nn.silu(y + params["conv_b"].astype(jnp.float32)[None, None, :])
    new_state = xp[:, -(width - 1):, :]
    return y.astype(xbc.dtype), new_state.astype(xbc.dtype)


def _ssd_chunked(x, a_log, b, c, dt, cfg: ModelConfig, h0=None):
    """x: (B,S,H,P); a_log:(B,S,H) log-decay; b,c:(B,S,G,N); dt:(B,S,H).

    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    L = min(cfg.ssm_chunk, s)
    pad = (-s) % L
    if pad:
        # Front-pad to a chunk multiple: exact because h0 == 0 (padded tokens
        # have x = 0 so they contribute nothing, and there is no prior state
        # for their decay to corrupt).
        assert h0 is None, "front-padding requires zero initial state"
        zf = lambda t: jnp.pad(t, ((0, 0), (pad, 0)) + ((0, 0),) * (t.ndim - 2))
        y, h_last = _ssd_chunked(zf(x), zf(a_log), zf(b), zf(c), zf(dt), cfg)
        return y[:, pad:], h_last
    assert s % L == 0, f"seq {s} not divisible by chunk {L}"
    nc = s // L
    rep = h // g

    def ch(t):  # (B,S,...) -> (B,nc,L,...)
        return t.reshape(bsz, nc, L, *t.shape[2:])

    xc, ac, dtc = ch(x.astype(jnp.float32)), ch(a_log), ch(dt)
    bc_ = ch(b.astype(jnp.float32))
    cc_ = ch(c.astype(jnp.float32))
    la = jnp.cumsum(ac, axis=2)                      # (B,nc,L,H) cumulative log decay
    # Intra-chunk (dual / matmul form)
    bh = jnp.repeat(bc_, rep, axis=3) if g != h else bc_  # (B,nc,L,H,N)
    chh = jnp.repeat(cc_, rep, axis=3) if g != h else cc_
    gmat = jnp.einsum("bclhn,bcshn->bchls", chh, bh)
    seg = la[..., :, None, :] - la[..., None, :, :]  # (B,nc,L,L,H) la_t - la_s
    seg = jnp.moveaxis(seg, -1, 2)                   # (B,nc,H,L,L)
    mask = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: future positions have seg -> +inf, and exp(+inf)
    # poisons the VJP with 0*inf = NaN even under where().
    seg = jnp.where(mask, seg, -1e9)
    dec = jnp.exp(seg)
    m = gmat * dec
    xdt = xc * dtc[..., None]                        # (B,nc,L,H,P)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", m, xdt)
    # Chunk states: S_c = sum_s exp(la_L - la_s) xdt_s B_s
    decay_to_end = jnp.exp(la[:, :, -1:, :] - la)    # (B,nc,L,H)
    s_chunk = jnp.einsum("bcshn,bcshp,bcsh->bchpn", bh, xdt, decay_to_end)
    chunk_decay = jnp.exp(la[:, :, -1, :])           # (B,nc,H)

    def scan_fn(hprev, inp):
        s_c, d_c = inp  # (B,H,P,N), (B,H)
        hnew = hprev * d_c[:, :, None, None] + s_c
        return hnew, hprev

    hinit = jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    hlast, hprevs = jax.lax.scan(
        scan_fn, hinit,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)              # (B,nc,H,P,N) state entering chunk
    y_inter = jnp.einsum("bclhn,bchpn,bclh->bclhp", chh, hprevs, jnp.exp(la))
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), hlast


def mamba2_forward(params, x, cfg: ModelConfig):
    """Full-sequence SSD.  x: (B,S,d) -> (y (B,S,d), final_state dict)."""
    di, ns, nh, g = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    p_hd = cfg.ssm_head_dim
    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xbc, conv_state = _causal_conv(params, xbc)
    xin, b, c = jnp.split(xbc, [di, di + g * ns], axis=-1)
    bsz, s = x.shape[0], x.shape[1]
    xin = xin.reshape(bsz, s, nh, p_hd)
    b = b.reshape(bsz, s, g, ns)
    c = c.reshape(bsz, s, g, ns)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a_log = -jnp.exp(params["a_log"])[None, None, :] * dt  # (B,S,H) log decay
    y, h_last = _ssd_chunked(xin, a_log, b, c, dt, cfg)
    y = y + xin.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = apply_norm(params["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    state = {"ssm": h_last.astype(jnp.float32), "conv": conv_state}
    return out, state


def init_mamba2_state(batch, cfg: ModelConfig, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    return {
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, _conv_dim(cfg)), dt),
    }


def mamba2_decode(params, x, state, cfg: ModelConfig):
    """Single-token step.  x: (B,1,d) -> (y (B,1,d), new_state)."""
    di, ns, nh, g = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    p_hd = cfg.ssm_head_dim
    z, xbc, dt_raw = _split_proj(params, x, cfg)
    xbc, conv_state = _causal_conv(params, xbc, conv_state=state["conv"])
    xin, b, c = jnp.split(xbc, [di, di + g * ns], axis=-1)
    bsz = x.shape[0]
    xin = xin.reshape(bsz, nh, p_hd).astype(jnp.float32)
    b = b.reshape(bsz, g, ns).astype(jnp.float32)
    c = c.reshape(bsz, g, ns).astype(jnp.float32)
    rep = nh // g
    bh = jnp.repeat(b, rep, axis=1) if g != nh else b   # (B,H,N)
    chh = jnp.repeat(c, rep, axis=1) if g != nh else c
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"][None, :])   # (B,H)
    a = jnp.exp(-jnp.exp(params["a_log"])[None, :] * dt)  # (B,H)
    h = state["ssm"] * a[:, :, None, None] + jnp.einsum(
        "bhp,bhn,bh->bhpn", xin, bh, dt)
    y = jnp.einsum("bhpn,bhn->bhp", h, chh) + xin * params["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = apply_norm(params["gate_norm"], y * jax.nn.silu(z), "rmsnorm")
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, {"ssm": h, "conv": conv_state}
