"""Multi-agent debate protocol (paper §4.2.2 + Appendix B, after ChatEval).

Three personas, two rounds, fixed order (factual -> UX -> relevance).  Each
persona emits verdict A / B / AB with a margin-based tie band; in round 2
each referee sees the history and is pulled toward the running consensus
(the paper's "must consider other referees' judgements"), but keeps its own
evidence — majority verdict over the final round decides.

Blinding + order randomisation: response order is shuffled per item with a
seeded RNG, mirroring the paper's shuffled side-by-side presentation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .judge import PERSONAS, persona_score

TIE_BAND = 0.03          # score margin below which a persona votes AB
HISTORY_PULL = 0.35      # round-2 consensus weight


@dataclasses.dataclass
class DebateResult:
    verdict: str                 # "A" | "B" | "AB"
    votes: List[str]             # final-round persona votes
    margins: List[float]


def _vote(margin: float) -> str:
    if abs(margin) <= TIE_BAND:
        return "AB"
    return "A" if margin > 0 else "B"


def run_debate(query: str, resp_a: str, resp_b: str, loglik_a: float,
               loglik_b: float, *, rng: np.random.Generator) -> DebateResult:
    # blinding: randomly swap the presentation order
    swap = bool(rng.integers(2))
    ra, rb = (resp_b, resp_a) if swap else (resp_a, resp_b)
    la, lb = (loglik_b, loglik_a) if swap else (loglik_a, loglik_b)

    margins = []
    votes: List[str] = []
    # round 1: independent
    for p in PERSONAS:
        m = persona_score(p, la, query, ra) - persona_score(p, lb, query, rb)
        margins.append(m)
    # round 2: sees history (consensus pull), sequential order per paper
    consensus = float(np.mean(margins))
    final_margins = []
    for i, _p in enumerate(PERSONAS):
        m2 = (1 - HISTORY_PULL) * margins[i] + HISTORY_PULL * consensus
        final_margins.append(m2)
        votes.append(_vote(m2))
    # majority verdict
    counts = {v: votes.count(v) for v in ("A", "B", "AB")}
    verdict = max(counts, key=lambda v: (counts[v], v == "AB"))
    if swap:  # unblind
        verdict = {"A": "B", "B": "A", "AB": "AB"}[verdict]
        votes = [{"A": "B", "B": "A", "AB": "AB"}[v] for v in votes]
        final_margins = [-m for m in final_margins]
    return DebateResult(verdict, votes, final_margins)


def debate_batch(queries: Sequence[str], resp_a: Sequence[str],
                 resp_b: Sequence[str], logliks_a: Sequence[float],
                 logliks_b: Sequence[float], seed: int = 0) -> List[DebateResult]:
    rng = np.random.default_rng(seed)
    return [run_debate(q, a, b, la, lb, rng=rng)
            for q, a, b, la, lb in zip(queries, resp_a, resp_b,
                                       logliks_a, logliks_b)]


def verdict_shares(results: List[DebateResult]) -> dict:
    n = max(len(results), 1)
    return {v: sum(r.verdict == v for r in results) / n for v in ("A", "B", "AB")}
