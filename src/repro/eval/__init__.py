from .judge import make_loglik_scorer, PERSONAS, persona_score
from .debate import run_debate, debate_batch, verdict_shares, DebateResult
from .metrics import precision_recall, pr_curve
