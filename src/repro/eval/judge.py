"""LLM-as-judge: response scoring under a referee language model.

The paper uses GPT-4o referees; offline, the referee is one of OUR models —
each persona scores a (query, response) pair as a weighted blend of

  * length-normalised log-likelihood of the response under the referee LM
    conditioned on the query (the model-based quality signal), and
  * persona-specific measurable features (relevance overlap, structure,
    length appropriateness) matching each persona's stated focus (Table 2).

The debate protocol in ``debate.py`` composes three personas over two
rounds exactly as Appendix B specifies.
"""
from __future__ import annotations

import dataclasses
import re
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.tokenizer import HashWordTokenizer


def make_loglik_scorer(model: Model, params, tokenizer: HashWordTokenizer,
                       max_len: int = 192):
    """Returns f(query, response) -> mean per-token logprob of response."""

    @jax.jit
    def _score(tokens, targets, mask):
        logits, _ = model.forward(params, {"tokens": tokens})
        logits = logits[..., : model.cfg.vocab_size]
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return jnp.sum(ll * mask, 1) / jnp.maximum(jnp.sum(mask, 1), 1.0)

    def score(queries: List[str], responses: List[str]) -> np.ndarray:
        texts = [q + " . " + r for q, r in zip(queries, responses)]
        toks, mask = tokenizer.encode_batch(texts, max_len + 1)
        qlens = np.array([len(tokenizer.encode(q + " . ")) for q in queries])
        tgt_mask = mask[:, 1:].copy()
        for i, ql in enumerate(qlens):  # only score the response span
            tgt_mask[i, : max(ql - 1, 0)] = 0.0
        return np.asarray(_score(jnp.asarray(toks[:, :-1]),
                                 jnp.asarray(toks[:, 1:]),
                                 jnp.asarray(tgt_mask)))

    return score


# ---------------------------------------------------------------- features

_STRUCTURE_WORDS = ("first", "then", "summary", "steps", "common", "best",
                    "track", "consult")


def _words(t: str) -> set:
    return set(re.findall(r"[a-z']+", t.lower()))


def relevance_overlap(query: str, response: str) -> float:
    qw, rw = _words(query), _words(response)
    if not qw:
        return 0.0
    return len(qw & rw) / len(qw)


def structure_score(response: str) -> float:
    rw = _words(response)
    return sum(w in rw for w in _STRUCTURE_WORDS) / len(_STRUCTURE_WORDS)


def length_appropriateness(response: str, lo: int = 8, hi: int = 120) -> float:
    n = len(response.split())
    if n < lo:
        return n / lo
    if n > hi:
        return max(0.0, 1.0 - (n - hi) / hi)
    return 1.0


@dataclasses.dataclass(frozen=True)
class Persona:
    name: str
    w_loglik: float
    w_relevance: float
    w_structure: float
    w_length: float


PERSONAS = (
    Persona("factual_accuracy", 1.0, 0.3, 0.1, 0.0),
    Persona("user_experience", 0.4, 0.2, 0.4, 0.6),
    Persona("relevance_completeness", 0.4, 1.0, 0.2, 0.2),
)


def persona_score(persona: Persona, loglik: float, query: str,
                  response: str) -> float:
    return (persona.w_loglik * loglik
            + persona.w_relevance * relevance_overlap(query, response)
            + persona.w_structure * structure_score(response)
            + persona.w_length * length_appropriateness(response))
