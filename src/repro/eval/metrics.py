"""Precision/recall metrics for cache-hit evaluation (paper §4.2.1)."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def precision_recall(hits: np.ndarray, labels: np.ndarray) -> Tuple[float, float]:
    """hits: bool (query produced a cache hit); labels: bool (true duplicate).

    TP = hit & duplicate; FP = hit & ~duplicate; FN = ~hit & duplicate.
    """
    tp = float(np.sum(hits & labels))
    fp = float(np.sum(hits & ~labels))
    fn = float(np.sum(~hits & labels))
    precision = tp / max(tp + fp, 1e-9)
    recall = tp / max(tp + fn, 1e-9)
    return precision, recall


def pr_curve(scores: np.ndarray, labels: np.ndarray,
             thresholds: np.ndarray) -> List[dict]:
    out = []
    for t in thresholds:
        p, r = precision_recall(scores >= t, labels)
        out.append({"threshold": float(t), "precision": p, "recall": r,
                    "hit_rate": float(np.mean(scores >= t))})
    return out
