"""Deterministic offline tokenizer.

Word-level hashing tokenizer: lowercased word/punct pieces map to stable ids
via blake2, so identical words always share an id across runs and processes
(a requirement for the semantic-cache experiments — paraphrases must share
token statistics).  No external vocab files; fully offline.
"""
from __future__ import annotations

import hashlib
import re
from typing import List, Sequence, Tuple

import numpy as np

SPECIAL_TOKENS = {"pad": 0, "bos": 1, "eos": 2, "sep": 3, "unk": 4}
NUM_SPECIAL = len(SPECIAL_TOKENS)
_WORD_RE = re.compile(r"[a-z0-9']+|[^\sa-z0-9']")


class HashWordTokenizer:
    def __init__(self, vocab_size: int = 32768):
        assert vocab_size > NUM_SPECIAL + 16
        self.vocab_size = vocab_size
        self.pad = SPECIAL_TOKENS["pad"]
        self.bos = SPECIAL_TOKENS["bos"]
        self.eos = SPECIAL_TOKENS["eos"]
        self.sep = SPECIAL_TOKENS["sep"]

    def _word_id(self, w: str) -> int:
        h = hashlib.blake2s(w.encode("utf-8"), digest_size=8).digest()
        return NUM_SPECIAL + int.from_bytes(h, "little") % (self.vocab_size - NUM_SPECIAL)

    def encode(self, text: str, add_bos: bool = True, add_eos: bool = False) -> List[int]:
        ids = [self.bos] if add_bos else []
        ids += [self._word_id(w) for w in _WORD_RE.findall(text.lower())]
        if add_eos:
            ids.append(self.eos)
        return ids

    def encode_batch(self, texts: Sequence[str], max_len: int,
                     add_bos: bool = True) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens (B, max_len) int32, mask (B, max_len) float32)."""
        b = len(texts)
        toks = np.full((b, max_len), self.pad, np.int32)
        mask = np.zeros((b, max_len), np.float32)
        for i, t in enumerate(texts):
            ids = self.encode(t, add_bos=add_bos)[:max_len]
            toks[i, : len(ids)] = ids
            mask[i, : len(ids)] = 1.0
        return toks, mask

    def decode_ids(self, ids: Sequence[int]) -> str:
        """Hash tokenizer is lossy; emit stable placeholder words for ids."""
        out = []
        inv = {v: k for k, v in SPECIAL_TOKENS.items()}
        for i in ids:
            out.append(f"<{inv[i]}>" if i in inv else f"w{i}")
        return " ".join(out)
