from .tokenizer import HashWordTokenizer, SPECIAL_TOKENS
