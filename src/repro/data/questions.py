"""Synthetic datasets standing in for Quora Question Pairs / LMSYS / WildChat.

The container is offline, so we reproduce the paper's *protocols* on
generated data whose similarity structure is controllable:

* ``QuestionPairGenerator`` — labeled duplicate / non-duplicate question
  pairs.  Duplicates are paraphrases (frame swap, synonym swap, filler
  insertion); non-duplicates include the paper's §6 hard negatives: same
  surface, opposite intent ("Why is X good?" vs "Why is X bad?") and
  entity swaps in templated questions.
* ``WorkloadGenerator`` — a chat query stream with Zipf-distributed topic
  repetition + paraphrase noise; ``profile='lmsys'`` repeats harder than
  ``profile='wildchat'`` so the hit-rate curves land in the paper's regimes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

# ------------------------------------------------------------ vocabulary

_SUBJECTS = ["python", "javascript", "rust", "linux", "keto", "vegan",
             "crypto", "stock", "guitar", "piano", "chess", "yoga",
             "marathon", "startup", "resume", "interview", "college",
             "visa", "credit", "mortgage", "garden", "puppy", "cat",
             "solar", "electric", "quantum", "welding", "pottery",
             "archery", "sailing", "beekeeping", "roofing", "plumbing",
             "calligraphy", "origami", "astronomy", "genealogy", "taxidermy",
             "falconry", "orienteering"]
_ASPECTS = ["training", "setup", "diet", "investing", "practice", "strategy",
            "routine", "application", "care", "installation", "tutorial",
            "maintenance", "course", "project", "certification", "budgeting",
            "scheduling", "insurance", "licensing", "troubleshooting"]
_QUALIFIERS = ["beginner", "advanced", "weekend", "professional", "budget",
               "intensive", "remote", "seasonal", "family", "competitive"]
# 40 x 20 x 10 = 8000 lexically distinctive topics: any two random topics
# share at most one content word, so the embedder can actually separate
# cells (the paper's datasets have this diversity for free).
_TOPICS = [f"{q} {s} {a}" for q in _QUALIFIERS for s in _SUBJECTS
           for a in _ASPECTS]

_FRAMES = {
    "how": ["how do i learn {t}", "what is the best way to learn {t}",
            "how can someone get started with {t}",
            "what are good steps to begin {t}",
            "how should a beginner approach {t}"],
    "why_good": ["why is {t} good", "what makes {t} worthwhile",
                 "what are the benefits of {t}", "why should i try {t}"],
    "why_bad": ["why is {t} bad", "what are the downsides of {t}",
                "what are the risks of {t}", "why should i avoid {t}"],
    "cost": ["how much does {t} cost", "what is the price of {t}",
             "is {t} expensive"],
    "time": ["how long does {t} take", "what is the time needed for {t}"],
    "compare": ["is {t} better than alternatives",
                "how does {t} compare to other options"],
}
_INTENTS = list(_FRAMES.keys())
_FILLERS = ["", "please tell me ", "i was wondering ", "quick question "]
_SUFFIX = ["", " exactly", " in practice", " these days", " for a beginner"]


@dataclasses.dataclass
class Query:
    text: str
    topic: int
    intent: str


def _render(rng: np.random.Generator, topic: int, intent: str) -> str:
    frame = _FRAMES[intent][rng.integers(len(_FRAMES[intent]))]
    q = frame.format(t=_TOPICS[topic])
    return (_FILLERS[rng.integers(len(_FILLERS))] + q
            + _SUFFIX[rng.integers(len(_SUFFIX))]).strip()


def synthesize_response(query_text: str, topic: int = -1, intent: str = "",
                        quality: str = "big") -> str:
    """Deterministic 'LLM response' for cache population.

    quality='big' emits a structured, detailed answer; 'small' a terse one —
    used by the judge protocol to reproduce the Fig-6 control (Small-direct
    clearly inferior to Big-direct).
    """
    topic_name = _TOPICS[topic] if topic >= 0 else "the subject"
    if quality == "big":
        return (f"here is a detailed answer about {topic_name} regarding"
                f" {intent or 'your question'}: first understand the"
                f" fundamentals of {topic_name}, then practice consistently,"
                f" track progress weekly, and consult expert resources."
                f" common pitfalls include rushing early stages and ignoring"
                f" feedback. summary: steady structured effort on"
                f" {topic_name} works best. (answering: {query_text})")
    return f"{topic_name}: it depends. try searching online about {query_text}."


class QuestionPairGenerator:
    """Labeled pairs in the spirit of Quora Question Pairs."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def duplicate_pair(self) -> Tuple[Query, Query]:
        t = int(self.rng.integers(len(_TOPICS)))
        intent = _INTENTS[self.rng.integers(len(_INTENTS))]
        return (Query(_render(self.rng, t, intent), t, intent),
                Query(_render(self.rng, t, intent), t, intent))

    def hard_negative_pair(self) -> Tuple[Query, Query]:
        """Shared words, different meaning (polarity flip or entity swap)."""
        t = int(self.rng.integers(len(_TOPICS)))
        if self.rng.random() < 0.5:  # polarity flip
            a = Query(_render(self.rng, t, "why_good"), t, "why_good")
            b = Query(_render(self.rng, t, "why_bad"), t, "why_bad")
        else:  # entity swap, same frame
            intent = _INTENTS[self.rng.integers(len(_INTENTS))]
            t2 = int(self.rng.integers(len(_TOPICS)))
            while t2 == t:
                t2 = int(self.rng.integers(len(_TOPICS)))
            a = Query(_render(self.rng, t, intent), t, intent)
            b = Query(_render(self.rng, t2, intent), t2, intent)
        return a, b

    def triple(self) -> Tuple[Query, Query, Query]:
        """(anchor, duplicate, hard-negative-of-anchor) for contrastive
        training: the negative shares the anchor's topic with flipped
        polarity, or shares its frame with a swapped entity."""
        t = int(self.rng.integers(len(_TOPICS)))
        if self.rng.random() < 0.5:
            ia, ineg = (("why_good", "why_bad")
                        if self.rng.random() < 0.5 else ("why_bad", "why_good"))
            a = Query(_render(self.rng, t, ia), t, ia)
            b = Query(_render(self.rng, t, ia), t, ia)
            n = Query(_render(self.rng, t, ineg), t, ineg)
        elif self.rng.random() < 0.5:
            intent = _INTENTS[self.rng.integers(len(_INTENTS))]
            t2 = self._near_topic(t)
            a = Query(_render(self.rng, t, intent), t, intent)
            b = Query(_render(self.rng, t, intent), t, intent)
            n = Query(_render(self.rng, t2, intent), t2, intent)
        else:  # same topic, different intent (cost vs time vs compare ...)
            ia, ib = self.rng.choice(len(_INTENTS), 2, replace=False)
            a = Query(_render(self.rng, t, _INTENTS[ia]), t, _INTENTS[ia])
            b = Query(_render(self.rng, t, _INTENTS[ia]), t, _INTENTS[ia])
            n = Query(_render(self.rng, t, _INTENTS[ib]), t, _INTENTS[ib])
        return a, b, n

    def _near_topic(self, t: int) -> int:
        """A topic sharing words with t (same subject or aspect) — the
        hardest entity-swap negative."""
        na, ns_ = len(_ASPECTS), len(_SUBJECTS)
        q, rem = divmod(t, ns_ * na)
        s, a = divmod(rem, na)
        if self.rng.random() < 0.5:
            a2 = (a + 1 + int(self.rng.integers(na - 1))) % na
            return q * ns_ * na + s * na + a2
        s2 = (s + 1 + int(self.rng.integers(ns_ - 1))) % ns_
        return q * ns_ * na + s2 * na + a

    def random_negative_pair(self) -> Tuple[Query, Query]:
        a = self._random_query()
        b = self._random_query()
        while b.topic == a.topic and b.intent == a.intent:
            b = self._random_query()
        return a, b

    def _random_query(self) -> Query:
        t = int(self.rng.integers(len(_TOPICS)))
        intent = _INTENTS[self.rng.integers(len(_INTENTS))]
        return Query(_render(self.rng, t, intent), t, intent)

    def generate(self, n: int, dup_frac: float = 0.5,
                 hard_frac: float = 0.25) -> List[Tuple[Query, Query, int]]:
        out = []
        for _ in range(n):
            r = self.rng.random()
            if r < dup_frac:
                a, b = self.duplicate_pair()
                out.append((a, b, 1))
            elif r < dup_frac + hard_frac:
                a, b = self.hard_negative_pair()
                out.append((a, b, 0))
            else:
                a, b = self.random_negative_pair()
                out.append((a, b, 0))
        return out


class WorkloadGenerator:
    """Zipfian chat-query stream (LMSYS-like / WildChat-like profiles)."""

    PROFILES = {
        # (zipf_alpha over topic-intent cells, exact_repeat_prob) —
        # calibrated (EXPERIMENTS.md §Paper-reproduction) so the trained
        # embedder's half-insert/half-query hit rate at cosine 0.8 lands in
        # the paper's regimes: LMSYS-like ~68%, WildChat-like as low as the
        # synthetic cross-topic leakage floor allows (~50% vs paper's 40%).
        "lmsys": (0.85, 0.04),
        "wildchat": (0.25, 0.0),
    }

    def __init__(self, profile: str = "lmsys", seed: int = 0):
        self.alpha, self.exact_prob = self.PROFILES[profile]
        self.rng = np.random.default_rng(seed)
        n_cells = len(_TOPICS) * len(_INTENTS)
        ranks = np.arange(1, n_cells + 1, dtype=np.float64)
        p = ranks ** (-self.alpha)
        self.p = p / p.sum()
        perm = self.rng.permutation(n_cells)
        self.cells = perm  # rank -> cell id
        self._seen: dict = {}

    def sample(self, n: int) -> List[Query]:
        out = []
        ranks = self.rng.choice(len(self.p), size=n, p=self.p)
        for r in ranks:
            cell = int(self.cells[r])
            t, ii = divmod(cell, len(_INTENTS))
            intent = _INTENTS[ii]
            if cell in self._seen and self.rng.random() < self.exact_prob:
                text = self._seen[cell]  # exact repeat (paper §6.1 fast path)
            else:
                text = _render(self.rng, t, intent)
                self._seen[cell] = text
            out.append(Query(text, t, intent))
        return out
