"""Synthetic token-stream pipeline for the training examples.

Deterministic, offline: renders templated documents (the same vocabulary the
cache experiments use), tokenizes, packs into fixed-length training batches
with next-token targets.  Good enough for "loss goes down" end-to-end
drivers without any external corpus.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.tokenizer import HashWordTokenizer
from .questions import QuestionPairGenerator, synthesize_response


def document_stream(seed: int = 0) -> Iterator[str]:
    gen = QuestionPairGenerator(seed=seed)
    while True:
        q = gen._random_query()
        yield q.text + " . " + synthesize_response(q.text, q.topic, q.intent)


def token_stream_batches(tokenizer: HashWordTokenizer, batch: int, seq_len: int,
                         seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {tokens (B,S), targets (B,S), mask (B,S)} packed batches."""
    docs = document_stream(seed)
    buf: list = []
    need = batch * (seq_len + 1)
    while True:
        while len(buf) < need:
            buf.extend(tokenizer.encode(next(docs), add_bos=True, add_eos=True))
        arr = np.asarray(buf[:need], np.int32).reshape(batch, seq_len + 1)
        buf = buf[need:]
        yield {
            "tokens": arr[:, :-1],
            "targets": arr[:, 1:],
            "mask": np.ones((batch, seq_len), np.float32),
        }
