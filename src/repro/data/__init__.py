from .questions import (QuestionPairGenerator, WorkloadGenerator,
                        synthesize_response)
from .pretrain import token_stream_batches
