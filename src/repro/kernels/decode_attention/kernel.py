"""Pallas TPU decode attention: one query token vs a long KV cache.

Memory-bound by design (the cache read IS the cost), so the kernel streams
(block_t, dh) KV tiles through VMEM with a running max/sum-exp — the
flash-decoding inner loop.  All q-heads of one KV group are processed
together as a (g, dh) panel per KV head: the KV tile is read ONCE per
group, not once per q-head — on GQA archs this divides HBM traffic by
H/Hk (e.g. 7x for deepseek-coder-33b).

Grid: (B * Hk, T/block_t), KV axis sequential, scratch persists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_t: int, batch: int):
    t_step = pl.program_id(1)

    @pl.when(t_step == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (g, dh)
    k = k_ref[0].astype(jnp.float32)          # (block_t, dh)
    v = v_ref[0].astype(jnp.float32)
    dh = q.shape[-1]
    cache_len = len_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * (dh ** -0.5)
    tp = t_step * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(tp < cache_len, s, NEG)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_prev * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(t_step == pl.num_programs(1) - 1)
    def _final():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _kernel_block(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, block_t: int, g: int):
    """Q-block variant: the panel carries K*g rows — K speculative queries
    × g grouped heads — and each query masks its own causal limit
    ``cache_len + i`` (the block's keys are already in the cache at slots
    ``cache_len + i``, DESIGN.md §14).  Same flash recurrence otherwise.
    """
    t_step = pl.program_id(1)

    @pl.when(t_step == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (K*g, dh)
    k = k_ref[0].astype(jnp.float32)          # (block_t, dh)
    v = v_ref[0].astype(jnp.float32)
    dh = q.shape[-1]
    cache_len = len_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * (dh ** -0.5)
    tp = t_step * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
    s = jnp.where(tp < cache_len + row + 1, s, NEG)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_prev * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(t_step == pl.num_programs(1) - 1)
    def _final():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_block_pallas(q, k, v, cache_len, *, block_t: int = 1024,
                                  interpret: bool = True):
    """q: (B,K,H,dh); k/v: (B,T,Hk,dh); cache_len: (B,) pre-block slots.
    Returns (B,K,H,dh).  KV tiles are still read once per KV group — the
    K speculative queries ride in the same panel, so the HBM traffic of a
    verify step equals ONE decode step, the whole point of speculation."""
    b, kq, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    block_t = min(block_t, t)
    pt = (-t) % block_t
    qt = jnp.moveaxis(q.reshape(b, kq, hk, g, dh), 2, 1).reshape(
        b * hk, kq * g, dh)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * hk, t, dh)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * hk, t, dh)
    if pt:
        kt = jnp.pad(kt, ((0, 0), (0, pt), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pt), (0, 0)))
    nt = (t + pt) // block_t
    grid = (b * hk, nt)
    lens = jnp.broadcast_to(cache_len[:, None], (b, hk)).reshape(b * hk)

    out = pl.pallas_call(
        functools.partial(_kernel_block, block_t=block_t, g=g),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bk, j: (bk,)),
            pl.BlockSpec((1, kq * g, dh), lambda bk, j: (bk, 0, 0)),
            pl.BlockSpec((1, block_t, dh), lambda bk, j: (bk, j, 0)),
            pl.BlockSpec((1, block_t, dh), lambda bk, j: (bk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, kq * g, dh), lambda bk, j: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hk, kq * g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((kq * g, 1), jnp.float32),
            pltpu.VMEM((kq * g, 1), jnp.float32),
            pltpu.VMEM((kq * g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qt, kt, vt)
    return jnp.moveaxis(out.reshape(b, hk, kq, g, dh), 1, 2).reshape(
        b, kq, h, dh)


def decode_attention_pallas(q, k, v, cache_len, *, block_t: int = 1024,
                            interpret: bool = True):
    """q: (B,H,dh); k/v: (B,T,Hk,dh); cache_len: (B,) -> (B,H,dh)."""
    b, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    block_t = min(block_t, t)
    pt = (-t) % block_t
    qt = q.reshape(b, hk, g, dh).reshape(b * hk, g, dh)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * hk, t, dh)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * hk, t, dh)
    if pt:
        kt = jnp.pad(kt, ((0, 0), (0, pt), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pt), (0, 0)))
    nt = (t + pt) // block_t
    grid = (b * hk, nt)
    lens = jnp.broadcast_to(cache_len[:, None], (b, hk)).reshape(b * hk)

    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t, batch=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bk, j: (bk,)),
            pl.BlockSpec((1, g, dh), lambda bk, j: (bk, 0, 0)),
            pl.BlockSpec((1, block_t, dh), lambda bk, j: (bk, j, 0)),
            pl.BlockSpec((1, block_t, dh), lambda bk, j: (bk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda bk, j: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hk, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qt, kt, vt)
    return out.reshape(b, hk, g, dh).reshape(b, h, dh)
