"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, cache_len):
    """q: (B,H,dh); k/v: (B,T,Hk,dh); cache_len: (B,) valid prefix lengths.

    Returns (B,H,dh).  Slots >= cache_len are masked out.
    """
    b, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q.reshape(b, hk, g, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    valid = jnp.arange(t)[None, :] < cache_len[:, None]       # (B,T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)


def decode_attention_block_ref(q, k, v, cache_len):
    """q: (B,K,H,dh) — K speculative queries per row (DESIGN.md §14).

    k/v: (B,T,Hk,dh); cache_len: (B,) counts the slots filled BEFORE the
    block; the block's own keys occupy slots ``cache_len + i``.  Query i
    attends causally within the block: slots ``< cache_len + i + 1``.
    Returns (B,K,H,dh).  K=1 equals ``decode_attention_ref`` with
    ``cache_len + 1``.
    """
    b, kq, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q.reshape(b, kq, hk, g, dh)
    s = jnp.einsum("bikgd,btkd->bkgit", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    limit = cache_len[:, None] + jnp.arange(kq)[None, :] + 1       # (B,K)
    valid = jnp.arange(t)[None, None, :] < limit[:, :, None]       # (B,K,T)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgit,btkd->bikgd", w, v.astype(jnp.float32))
    return out.reshape(b, kq, h, dh).astype(q.dtype)
