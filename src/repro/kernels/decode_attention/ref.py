"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, cache_len):
    """q: (B,H,dh); k/v: (B,T,Hk,dh); cache_len: (B,) valid prefix lengths.

    Returns (B,H,dh).  Slots >= cache_len are masked out.
    """
    b, h, dh = q.shape
    t, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q.reshape(b, hk, g, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    valid = jnp.arange(t)[None, :] < cache_len[:, None]       # (B,T)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)
