"""Jit'd public wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import decode_attention_block_pallas, decode_attention_pallas
from .ref import decode_attention_block_ref, decode_attention_ref


@functools.partial(jax.jit, static_argnames=("block_t", "impl"))
def decode_attention(q, k, v, cache_len, *, block_t: int = 1024,
                     impl: str = "pallas"):
    """q (B,H,dh) vs cache k/v (B,T,Hk,dh), valid prefix cache_len (B,)."""
    if impl == "pallas":
        return decode_attention_pallas(
            q, k, v, cache_len, block_t=block_t,
            interpret=jax.default_backend() != "tpu")
    return decode_attention_ref(q, k, v, cache_len)


@functools.partial(jax.jit, static_argnames=("block_t", "impl"))
def decode_attention_block(q, k, v, cache_len, *, block_t: int = 1024,
                           impl: str = "pallas"):
    """Speculative verify (DESIGN.md §14): q (B,K,H,dh) — K draft queries
    per row whose keys sit at slots ``cache_len + i`` — against cache k/v
    (B,T,Hk,dh) with pre-block valid prefix cache_len (B,); causal inside
    the block."""
    if impl == "pallas":
        return decode_attention_block_pallas(
            q, k, v, cache_len, block_t=block_t,
            interpret=jax.default_backend() != "tpu")
    return decode_attention_block_ref(q, k, v, cache_len)
