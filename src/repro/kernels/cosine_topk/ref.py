"""Pure-jnp oracle for the cosine top-k cache-lookup kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def cosine_topk_ref(queries, db, k: int, valid=None):
    """queries: (B, D) unit vectors; db: (N, D) unit vectors.

    Returns (scores (B, k) f32 desc-sorted, indices (B, k) i32).
    ``valid``: optional (N,) bool; invalid entries score -inf.
    """
    scores = jnp.einsum("bd,nd->bn", queries.astype(jnp.float32),
                        db.astype(jnp.float32))
    if valid is not None:
        scores = jnp.where(valid[None, :], scores, -jnp.inf)
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_s, top_i.astype(jnp.int32)


def cosine_topk_gather_ref(queries, cand_emb, cand_idx, cand_valid, k: int):
    """Shortlist variant: score per-query candidate sets (the IVF probe).

    queries (B, D); cand_emb (B, M, D) pre-gathered candidate rows;
    cand_idx (B, M) i32 global row ids (-1 for padding); cand_valid (B, M)
    bool.  Returns (scores (B, k) f32 desc-sorted, indices (B, k) i32);
    slots with no live candidate score -inf with index -1.
    """
    scores = jnp.einsum("bd,bmd->bm", queries.astype(jnp.float32),
                        cand_emb.astype(jnp.float32))
    scores = jnp.where(cand_valid, scores, -jnp.inf)
    top_s, pos = jax.lax.top_k(scores, k)
    top_i = jnp.take_along_axis(cand_idx, pos, axis=1).astype(jnp.int32)
    return top_s, jnp.where(jnp.isfinite(top_s), top_i, -1)
