"""Pure-jnp oracle for the cosine top-k cache-lookup kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def cosine_topk_ref(queries, db, k: int, valid=None):
    """queries: (B, D) unit vectors; db: (N, D) unit vectors.

    Returns (scores (B, k) f32 desc-sorted, indices (B, k) i32).
    ``valid``: optional (N,) bool; invalid entries score -inf.
    """
    scores = jnp.einsum("bd,nd->bn", queries.astype(jnp.float32),
                        db.astype(jnp.float32))
    if valid is not None:
        scores = jnp.where(valid[None, :], scores, -jnp.inf)
    top_s, top_i = jax.lax.top_k(scores, k)
    return top_s, top_i.astype(jnp.int32)
