"""Jit'd public wrapper for the cosine top-k lookup.

Dispatches to the Pallas kernel on TPU (or interpret mode for validation)
and to the XLA reference elsewhere.  This is the op the semantic cache
calls; ``repro.core.distributed`` shards it with shard_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cosine_topk_gather_pallas, cosine_topk_pallas
from .ref import cosine_topk_gather_ref, cosine_topk_ref


@functools.partial(jax.jit, static_argnames=("k", "impl", "block_n"))
def cosine_topk(queries, db, valid=None, *, k: int = 4, impl: str = "xla",
                block_n: int = 1024):
    """queries (B,D) x db (N,D) -> (scores (B,k), indices (B,k))."""
    if impl == "pallas":
        s, i = cosine_topk_pallas(queries, db, k, valid, block_n=block_n,
                                  interpret=jax.default_backend() != "tpu")
        # kernel reports NEG for sub-k matches; normalize to -inf like ref
        return jnp.where(i >= 0, s, -jnp.inf), i
    return cosine_topk_ref(queries, db, k, valid)


@functools.partial(jax.jit, static_argnames=("k", "impl", "block_m"))
def cosine_topk_gather(queries, db, cand_idx, cand_valid, *, k: int = 4,
                       impl: str = "xla", block_m: int = 256):
    """Gather-then-scan: score only a per-query shortlist of db rows.

    queries (B,D) x db (N,D), cand_idx (B,M) i32 row ids (-1 = padding),
    cand_valid (B,M) bool -> (scores (B,k), indices (B,k) GLOBAL rows).
    The shortlist gather runs in XLA (one (B,M,D) take); scoring + top-k
    dispatch to the Pallas tile kernel on TPU or the jnp oracle elsewhere.
    """
    b, m = cand_idx.shape
    cand_valid = cand_valid & (cand_idx >= 0)
    cand_emb = jnp.take(db, jnp.clip(cand_idx, 0, None), axis=0)  # (B,M,D)
    if impl == "pallas":
        block_m = min(block_m, m)
        pad = (-m) % block_m
        if pad:
            zcol = jnp.zeros((b, pad), jnp.int32)
            cand_idx = jnp.concatenate([cand_idx, zcol - 1], axis=1)
            cand_valid = jnp.concatenate(
                [cand_valid, jnp.zeros((b, pad), bool)], axis=1)
            cand_emb = jnp.concatenate(
                [cand_emb, jnp.zeros((b, pad, db.shape[1]), cand_emb.dtype)],
                axis=1)
        s, i = cosine_topk_gather_pallas(
            queries, cand_emb, cand_idx, cand_valid, k, block_m=block_m,
            interpret=jax.default_backend() != "tpu")
        return jnp.where(i >= 0, s, -jnp.inf), i
    return cosine_topk_gather_ref(queries, cand_emb, cand_idx, cand_valid, k)
