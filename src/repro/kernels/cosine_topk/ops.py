"""Jit'd public wrapper for the cosine top-k lookup.

Dispatches to the Pallas kernel on TPU (or interpret mode for validation)
and to the XLA reference elsewhere.  This is the op the semantic cache
calls; ``repro.core.distributed`` shards it with shard_map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cosine_topk_pallas
from .ref import cosine_topk_ref


@functools.partial(jax.jit, static_argnames=("k", "impl", "block_n"))
def cosine_topk(queries, db, valid=None, *, k: int = 4, impl: str = "xla",
                block_n: int = 1024):
    """queries (B,D) x db (N,D) -> (scores (B,k), indices (B,k))."""
    if impl == "pallas":
        s, i = cosine_topk_pallas(queries, db, k, valid, block_n=block_n,
                                  interpret=jax.default_backend() != "tpu")
        # kernel reports NEG for sub-k matches; normalize to -inf like ref
        return jnp.where(i >= 0, s, -jnp.inf), i
    return cosine_topk_ref(queries, db, k, valid)
