"""Pallas TPU kernel: tiled cosine-similarity scan with running top-k.

The semantic-cache lookup hot loop.  The (N, D) embedding shard streams
through VMEM in (block_n, D) tiles; each tile's (B, block_n) score panel is
one MXU matmul; a per-query running top-k lives in VMEM scratch across the
sequential grid.  Top-k update is k rounds of masked max (k is small — a
sort network is a poor fit for the VPU).

Grid: (N // block_n,) — sequential on TPU, so scratch persists across steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30  # python float: jnp constants get captured as kernel consts


def _kernel(q_ref, db_ref, valid_ref, out_s_ref, out_i_ref,
            run_s, run_i, *, k: int, block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)              # (B, D)
    db = db_ref[...].astype(jnp.float32)            # (block_n, D)
    scores = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (B, block_n)
    base = step * block_n
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + base
    scores = jnp.where(valid_ref[...][None, :] != 0, scores, NEG)

    rs, ri = _topk_merge(run_s[...], run_i[...], scores, col, k)
    run_s[...] = rs
    run_i[...] = ri

    @pl.when(step == pl.num_programs(0) - 1)
    def _final():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def _topk_merge(rs, ri, s, idx, k: int):
    """Fold a (B, m) score/index tile into the (B, k) running top-k.

    k rounds of masked max; the loser of each slot comparison is
    re-injected into the pool to compete for the next slot (VPU-friendly:
    no gather, no sort network).
    """
    for j in range(k):
        best = jnp.max(s, axis=1, keepdims=True)                    # (B,1)
        bidx = jnp.argmax(s, axis=1)                                # (B,)
        consumed = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == bidx[:, None]
        bcol = jnp.sum(jnp.where(consumed, idx, 0), axis=1, keepdims=True)
        slot_s = rs[:, j:j + 1]
        slot_i = ri[:, j:j + 1]
        take_new = best > slot_s
        rs = jax.lax.dynamic_update_slice(
            rs, jnp.where(take_new, best, slot_s), (0, j))
        ri = jax.lax.dynamic_update_slice(
            ri, jnp.where(take_new, bcol, slot_i), (0, j))
        s = jnp.where(consumed & take_new, jnp.broadcast_to(slot_s, s.shape), s)
        idx = jnp.where(consumed & take_new, jnp.broadcast_to(slot_i, idx.shape), idx)
    return rs, ri


def _gather_kernel(q_ref, cand_ref, idx_ref, valid_ref, out_s_ref, out_i_ref,
                   run_s, run_i, *, k: int, block_m: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)               # (B, D)
    cand = cand_ref[...].astype(jnp.float32)         # (B, block_m, D)
    # per-query candidate sets: batched matvec on the MXU
    scores = jax.lax.dot_general(
        q, cand, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)           # (B, block_m)
    scores = jnp.where(valid_ref[...] != 0, scores, NEG)
    idx = idx_ref[...]                                # (B, block_m)

    rs, ri = _topk_merge(run_s[...], run_i[...], scores, idx, k)
    run_s[...] = rs
    run_i[...] = ri

    @pl.when(step == pl.num_programs(0) - 1)
    def _final():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def cosine_topk_gather_pallas(queries, cand_emb, cand_idx, cand_valid, k: int,
                              *, block_m: int = 256, interpret: bool = True):
    """Shortlist scan: queries (B, D) x cand_emb (B, M, D) -> top-k.

    The IVF probe path — the (B, M, D) candidate tensor (gathered by XLA
    outside the kernel) streams through VMEM in (B, block_m, D) tiles;
    indices come from ``cand_idx`` instead of a column iota, so the kernel
    reports GLOBAL bank rows.  Padding/stale candidates (``cand_valid``
    false) score NEG and never surface.
    """
    b, m, d = cand_emb.shape
    block_m = min(block_m, m)
    assert m % block_m == 0, f"M={m} not divisible by block_m={block_m}"
    grid = (m // block_m,)
    out_s, out_i = pl.pallas_call(
        functools.partial(_gather_kernel, k=k, block_m=block_m),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((b, block_m, d), lambda i: (0, i, 0)),
            pl.BlockSpec((b, block_m), lambda i: (0, i)),
            pl.BlockSpec((b, block_m), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((b, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, k), jnp.float32),
            pltpu.VMEM((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, cand_emb, cand_idx, cand_valid.astype(jnp.int32))
    return out_s, out_i


def cosine_topk_pallas(queries, db, k: int, valid=None, *,
                       block_n: int = 1024, interpret: bool = True):
    b, d = queries.shape
    n = db.shape[0]
    block_n = min(block_n, n)
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"
    if valid is None:
        valid = jnp.ones((n,), jnp.int32)
    else:
        valid = valid.astype(jnp.int32)
    grid = (n // block_n,)
    out_s, out_i = pl.pallas_call(
        functools.partial(_kernel, k=k, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((b, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, k), jnp.float32),
            pltpu.VMEM((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, db, valid)
    return out_s, out_i
