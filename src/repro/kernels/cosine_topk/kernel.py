"""Pallas TPU kernel: tiled cosine-similarity scan with running top-k.

The semantic-cache lookup hot loop.  The (N, D) embedding shard streams
through VMEM in (block_n, D) tiles; each tile's (B, block_n) score panel is
one MXU matmul; a per-query running top-k lives in VMEM scratch across the
sequential grid.  Top-k update is k rounds of masked max (k is small — a
sort network is a poor fit for the VPU).

Grid: (N // block_n,) — sequential on TPU, so scratch persists across steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30  # python float: jnp constants get captured as kernel consts


def _kernel(q_ref, db_ref, valid_ref, out_s_ref, out_i_ref,
            run_s, run_i, *, k: int, block_n: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        run_s[...] = jnp.full_like(run_s, NEG)
        run_i[...] = jnp.full_like(run_i, -1)

    q = q_ref[...].astype(jnp.float32)              # (B, D)
    db = db_ref[...].astype(jnp.float32)            # (block_n, D)
    scores = jax.lax.dot_general(
        q, db, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (B, block_n)
    base = step * block_n
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1) + base
    scores = jnp.where(valid_ref[...][None, :] != 0, scores, NEG)

    rs, ri = run_s[...], run_i[...]                  # (B, k), sorted desc
    s, idx = scores, col
    for j in range(k):
        # best remaining candidate in the tile pool (VPU-friendly: no gather)
        best = jnp.max(s, axis=1, keepdims=True)                    # (B,1)
        bidx = jnp.argmax(s, axis=1)                                # (B,)
        consumed = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == bidx[:, None]
        bcol = jnp.sum(jnp.where(consumed, idx, 0), axis=1, keepdims=True)
        # compare with the j-th running slot: larger wins the slot, the
        # loser is re-injected into the pool to compete for slot j+1
        slot_s = rs[:, j:j + 1]
        slot_i = ri[:, j:j + 1]
        take_new = best > slot_s
        rs = jax.lax.dynamic_update_slice(
            rs, jnp.where(take_new, best, slot_s), (0, j))
        ri = jax.lax.dynamic_update_slice(
            ri, jnp.where(take_new, bcol, slot_i), (0, j))
        # when the candidate wins, the demoted slot value takes its pool spot;
        # when it loses it simply stays in the pool.
        s = jnp.where(consumed & take_new, jnp.broadcast_to(slot_s, s.shape), s)
        idx = jnp.where(consumed & take_new, jnp.broadcast_to(slot_i, idx.shape), idx)
    run_s[...] = rs
    run_i[...] = ri

    @pl.when(step == pl.num_programs(0) - 1)
    def _final():
        out_s_ref[...] = run_s[...]
        out_i_ref[...] = run_i[...]


def cosine_topk_pallas(queries, db, k: int, valid=None, *,
                       block_n: int = 1024, interpret: bool = True):
    b, d = queries.shape
    n = db.shape[0]
    block_n = min(block_n, n)
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"
    if valid is None:
        valid = jnp.ones((n,), jnp.int32)
    else:
        valid = valid.astype(jnp.int32)
    grid = (n // block_n,)
    out_s, out_i = pl.pallas_call(
        functools.partial(_kernel, k=k, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b, k), lambda i: (0, 0)),
            pl.BlockSpec((b, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((b, k), jnp.float32),
            pltpu.VMEM((b, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, db, valid)
    return out_s, out_i
