"""Pallas TPU flash attention (prefill): blockwise softmax in VMEM.

Grid: (B * H, Sq/block_q, Sk/block_k) — the KV axis is innermost and
sequential on TPU, so the running (m, l, acc) state lives in VMEM scratch
across KV steps.  GQA is handled in the index map: q-head h reads kv-head
h // (H / Hk), so each KV block is fetched once per q-head group.

Causal/window masking is computed from block offsets (prefill positions are
contiguous from 0).  Block shapes default to (512, 512) — (block_q + 2 *
block_k) * dh * 4B of VMEM working set, MXU-aligned for dh >= 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, causal: bool, window: int, sk: int):
    kv_step = pl.program_id(2)
    q_step = pl.program_id(1)

    @pl.when(kv_step == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)        # (block_q, dh)
    k = k_ref[0].astype(jnp.float32)        # (block_k, dh)
    v = v_ref[0].astype(jnp.float32)
    dh = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * (dh ** -0.5)
    qp = q_step * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kp = kv_step * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kp < sk
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, NEG)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_prev * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(kv_step == pl.num_programs(2) - 1)
    def _final():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = True):
    """q: (B,Sq,H,dh), k/v: (B,Sk,Hk,dh) -> (B,Sq,H,dh)."""
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, dh)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * hk, sk, dh)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * hk, sk, dh)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, pk), (0, 0)))
    nq = (sq + pq) // block_q
    nk = (sk + pk) // block_k
    grid = (b * h, nq, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, block_q=block_q, block_k=block_k,
                          causal=causal, window=window, sk=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, i, j: (bh, i, 0)),
            # GQA: flat q index bh = bi*H + hi maps to kv index bi*Hk + hi//g
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, i, j: ((bh // h) * hk + (bh % h) // g, j, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, i, j: ((bh // h) * hk + (bh % h) // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out[:, :sq].reshape(b, h, sq, dh)
    return jnp.moveaxis(out, 1, 2)
