"""Jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "impl"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, causal: bool = True,
                    window: int = 0, block_q: int = 512, block_k: int = 512,
                    impl: str = "pallas"):
    """Prefill attention (contiguous positions from 0).  GQA via head ratio."""
    del q_pos, k_pos  # contiguous-prefill layout; kept for API parity
    if impl == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, block_q=block_q,
            block_k=block_k, interpret=jax.default_backend() != "tpu")
    return flash_attention_ref(q, k, v, causal=causal, window=window)
