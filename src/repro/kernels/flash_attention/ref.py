"""Pure-jnp oracle for blockwise (flash) attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,Sq,H,dh); k/v: (B,Sk,Hk,dh); GQA by head grouping.

    Positions are assumed contiguous from 0 (prefill layout).
    Returns (B,Sq,H,dh) in q.dtype.
    """
    b, sq, h, dh = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q.reshape(b, sq, hk, g, dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    s = jnp.where(m[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)
