"""Pallas TPU paged decode attention: one query token vs block-table KV.

Same flash-decoding recurrence as ``kernels/decode_attention`` — running
max/sum-exp over KV tiles, all q-heads of a KV group as one (g, dh)
panel — but the KV tile for grid step (row b, logical page j) is DMA'd
straight from physical page ``block_tbl[b, j]`` of the shared pool.
The block table rides in as a SCALAR-PREFETCH argument
(``pltpu.PrefetchScalarGridSpec``): it is resident in SMEM before the
body runs, so the BlockSpec index_maps can compute each step's DMA
source from it — the gather never materialises the (B, cap) dense
cache, which is the entire point of paging (DESIGN.md §11).

Grid: (B * Hk, npg); the page axis is sequential, scratch persists.
Validity comes from ``slot_pos`` (B, npg*page): slots < 0 are masked —
that single mask covers empty slots, the sliced tail of the last page,
and rows parked on the TRASH page.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(tbl_ref, q_ref, k_ref, v_ref, sp_ref, o_ref, m_scr, l_scr,
            acc_scr):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (g, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)      # (page, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    dh = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * (dh ** -0.5)
    s = jnp.where(sp_ref[0] >= 0, s, NEG)       # (g, page) vs (page,)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_prev * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _final():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def _kernel_block(tbl_ref, qpos_ref, q_ref, k_ref, v_ref, sp_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, g: int):
    """Q-block variant (DESIGN.md §14): the panel carries K*g rows — K
    speculative queries × g grouped heads.  Query i (panel rows i*g ..)
    sits at absolute position ``q_pos + i`` and masks keys by position:
    ``slot_pos <= q_pos + i`` — causality inside the block falls out of
    the same comparison that orders it against the cache."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (K*g, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)      # (page, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    dh = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * (dh ** -0.5)
    sp = sp_ref[0]                              # (1, page)
    row = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
    s = jnp.where((sp >= 0) & (sp <= qpos_ref[0] + row), s, NEG)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_prev * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _final():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def paged_decode_attention_block_pallas(q, kp, vp, block_tbl, slot_pos,
                                        q_pos, *, interpret: bool = True):
    """q: (B,K,H,dh); kp/vp: (P+1,page,Hk,dh); block_tbl: (B,npg) int32;
    slot_pos: (B,cap) int32 (-1 = invalid); q_pos: (B,) absolute position
    of each row's first query.  Returns (B,K,H,dh)."""
    b, kq, h, dh = q.shape
    page, hk = kp.shape[1], kp.shape[2]
    npg = block_tbl.shape[1]
    cap = slot_pos.shape[1]
    g = h // hk
    qt = jnp.moveaxis(q.reshape(b, kq, hk, g, dh), 2, 1).reshape(
        b * hk, kq * g, dh)
    sp = jnp.pad(slot_pos, ((0, 0), (0, npg * page - cap)),
                 constant_values=-1).reshape(b, npg, page)
    tbl = block_tbl.astype(jnp.int32)
    qpos = jnp.broadcast_to(q_pos[:, None], (b, hk)).reshape(b * hk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hk, npg),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, j, tbl: (bh,)),
            pl.BlockSpec((1, kq * g, dh), lambda bh, j, tbl: (bh, 0, 0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda bh, j, tbl: (tbl[bh // hk, j], 0,
                                             bh % hk, 0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda bh, j, tbl: (tbl[bh // hk, j], 0,
                                             bh % hk, 0)),
            pl.BlockSpec((1, 1, page), lambda bh, j, tbl: (bh // hk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, kq * g, dh), lambda bh, j, tbl: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kq * g, 1), jnp.float32),
            pltpu.VMEM((kq * g, 1), jnp.float32),
            pltpu.VMEM((kq * g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel_block, g=g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hk, kq * g, dh), q.dtype),
        interpret=interpret,
    )(tbl, qpos, qt, kp, vp, sp)
    return jnp.moveaxis(out.reshape(b, hk, kq, g, dh), 1, 2).reshape(
        b, kq, h, dh)


def paged_decode_attention_pallas(q, kp, vp, block_tbl, slot_pos, *,
                                  interpret: bool = True):
    """q: (B,H,dh); kp/vp: (P+1,page,Hk,dh); block_tbl: (B,npg) int32;
    slot_pos: (B,cap) int32, -1 = invalid slot.  Returns (B,H,dh)."""
    b, h, dh = q.shape
    page, hk = kp.shape[1], kp.shape[2]
    npg = block_tbl.shape[1]
    cap = slot_pos.shape[1]
    g = h // hk
    qt = q.reshape(b, hk, g, dh).reshape(b * hk, g, dh)
    # Pad slot_pos out to whole pages with -1: the tail of the last page
    # beyond ``cap`` masks out exactly like an empty slot.
    sp = jnp.pad(slot_pos, ((0, 0), (0, npg * page - cap)),
                 constant_values=-1).reshape(b, npg, page)
    tbl = block_tbl.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,          # the block table, SMEM-resident
        grid=(b * hk, npg),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda bh, j, tbl: (bh, 0, 0)),
            # K/V tile: physical page tbl[row, j] of this row's KV head —
            # the block table indirection happens HERE, in the DMA source.
            pl.BlockSpec((1, page, 1, dh),
                         lambda bh, j, tbl: (tbl[bh // hk, j], 0,
                                             bh % hk, 0)),
            pl.BlockSpec((1, page, 1, dh),
                         lambda bh, j, tbl: (tbl[bh // hk, j], 0,
                                             bh % hk, 0)),
            pl.BlockSpec((1, 1, page), lambda bh, j, tbl: (bh // hk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda bh, j, tbl: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hk, g, dh), q.dtype),
        interpret=interpret,
    )(tbl, qt, kp, vp, sp)
    return out.reshape(b, hk, g, dh).reshape(b, h, dh)
