"""Pure-jnp oracle for single-token decode attention over PAGED KV.

The dense oracle (``decode_attention/ref.py``) reads contiguous
per-sequence caches; here each row's KV lives in pool pages indirected
through a block table (DESIGN.md §11).  The reference materialises the
gather — physical pages back to logical slot order — then runs the same
masked softmax, so the Pallas kernel (which never materialises the
gathered cache) is checked against straight-line semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def gather_pages(kp, block_tbl, cap: int):
    """Physical pages -> logical slots: (P+1,page,Hk,dh) -> (B,cap,Hk,dh).

    ``block_tbl`` (B, npg) names each row's pages in logical order; the
    flattened gather is sliced to ``cap`` (the logical capacity), which
    drops the unused tail of the last page.
    """
    b, npg = block_tbl.shape
    page = kp.shape[1]
    return kp[block_tbl].reshape(b, npg * page, *kp.shape[2:])[:, :cap]


def paged_decode_attention_ref(q, kp, vp, block_tbl, slot_pos):
    """q: (B,H,dh); kp/vp: (P+1,page,Hk,dh) pool pages; block_tbl: (B,npg);
    slot_pos: (B,cap) absolute position per logical slot, -1 = empty.

    Returns (B,H,dh).  Slots with ``slot_pos < 0`` are masked out.
    """
    b, h, dh = q.shape
    hk = kp.shape[2]
    cap = slot_pos.shape[1]
    g = h // hk
    k = gather_pages(kp, block_tbl, cap)
    v = gather_pages(vp, block_tbl, cap)
    qg = q.reshape(b, hk, g, dh)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    valid = slot_pos >= 0                                     # (B,cap)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, dh).astype(q.dtype)


def paged_decode_attention_block_ref(q, kp, vp, block_tbl, slot_pos, q_pos):
    """Speculative verify over paged KV (DESIGN.md §14).

    q: (B,K,H,dh) — K draft queries per row, query i at absolute position
    ``q_pos + i`` (q_pos (B,)); its key is already scattered into the
    pages at that slot.  Validity per query: ``slot_pos >= 0`` (written)
    AND ``slot_pos <= q_pos + i`` (causal).  Returns (B,K,H,dh).
    """
    b, kq, h, dh = q.shape
    hk = kp.shape[2]
    cap = slot_pos.shape[1]
    g = h // hk
    k = gather_pages(kp, block_tbl, cap)
    v = gather_pages(vp, block_tbl, cap)
    qg = q.reshape(b, kq, hk, g, dh)
    s = jnp.einsum("bikgd,btkd->bkgit", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    limit = q_pos[:, None] + jnp.arange(kq)[None, :]          # (B,K)
    valid = ((slot_pos[:, None, :] >= 0)
             & (slot_pos[:, None, :] <= limit[:, :, None]))   # (B,K,cap)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgit,btkd->bikgd", w, v.astype(jnp.float32))
    return out.reshape(b, kq, h, dh).astype(q.dtype)
