from .ops import paged_decode_attention, paged_decode_attention_block
from .ref import paged_decode_attention_block_ref, paged_decode_attention_ref

__all__ = ["paged_decode_attention", "paged_decode_attention_block",
           "paged_decode_attention_ref", "paged_decode_attention_block_ref"]
