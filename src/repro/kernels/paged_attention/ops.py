"""Jit'd public wrapper for the paged decode-attention kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import (paged_decode_attention_block_pallas,
                     paged_decode_attention_pallas)
from .ref import paged_decode_attention_block_ref, paged_decode_attention_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_decode_attention(q, kp, vp, block_tbl, slot_pos, *,
                           impl: str = "pallas"):
    """q (B,H,dh) vs pool pages kp/vp (P+1,page,Hk,dh) through block_tbl
    (B,npg); slot validity from slot_pos (B,cap) (< 0 = masked)."""
    if impl == "pallas":
        return paged_decode_attention_pallas(
            q, kp, vp, block_tbl, slot_pos,
            interpret=jax.default_backend() != "tpu")
    return paged_decode_attention_ref(q, kp, vp, block_tbl, slot_pos)


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_decode_attention_block(q, kp, vp, block_tbl, slot_pos, q_pos, *,
                                 impl: str = "pallas"):
    """Speculative verify (DESIGN.md §14): q (B,K,H,dh) draft queries, row
    query i at absolute position ``q_pos + i``, against pool pages through
    block_tbl; per-query causal masking via slot_pos positions."""
    if impl == "pallas":
        return paged_decode_attention_block_pallas(
            q, kp, vp, block_tbl, slot_pos, q_pos,
            interpret=jax.default_backend() != "tpu")
    return paged_decode_attention_block_ref(q, kp, vp, block_tbl, slot_pos,
                                            q_pos)
