"""Jit'd public wrapper for the paged decode-attention kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import paged_decode_attention_pallas
from .ref import paged_decode_attention_ref


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_decode_attention(q, kp, vp, block_tbl, slot_pos, *,
                           impl: str = "pallas"):
    """q (B,H,dh) vs pool pages kp/vp (P+1,page,Hk,dh) through block_tbl
    (B,npg); slot validity from slot_pos (B,cap) (< 0 = masked)."""
    if impl == "pallas":
        return paged_decode_attention_pallas(
            q, kp, vp, block_tbl, slot_pos,
            interpret=jax.default_backend() != "tpu")
    return paged_decode_attention_ref(q, kp, vp, block_tbl, slot_pos)
