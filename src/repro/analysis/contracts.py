"""Jaxpr/HLO contract checks over the registered hot paths (DESIGN.md §10).

Layer 2 of the analyzer: where the AST lint (Layer 1) reads source, this
module *traces* each hot path against its declared bucket shapes and
checks properties of the jaxpr and the lowered artifact:

* **no callbacks** — ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` primitives anywhere in the jaxpr (including inside
  while/scan/cond sub-jaxprs) mean a host round-trip per dispatch.
* **no 64-bit widening** — an f64/i64 var in a hot-path jaxpr doubles
  bandwidth on every touched buffer and usually signals an accidental
  Python-float promotion.
* **donation is real** — declaring ``donate_argnums`` is only half the
  story; the compiled artifact must actually alias inputs to outputs
  (``tf.aliasing_output`` in the lowered text), otherwise the cache
  update silently degrades to copy-on-write.
* **the recompile gate** — executing the FULL bucket set twice must
  produce exactly ``len(buckets)`` compilations.  A shape leak that
  defeats the batcher becomes a CI failure here instead of a production
  latency mystery.

Run via ``python -m repro.analysis.contracts`` (or ``make analyze``).
Contracts use deliberately tiny shapes — the properties checked are
shape-independent, and CI pays the trace cost on every push.
"""
from __future__ import annotations

import sys
from typing import Iterable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CALLBACK_PRIMITIVES = ("pure_callback", "io_callback", "debug_callback")
WIDE_DTYPES = ("float64", "int64", "uint64", "complex128")


# --------------------------------------------------------- jaxpr helpers

def iter_eqns(jaxpr) -> Iterable:
    """All equations in a (Closed)Jaxpr, recursing into sub-jaxprs
    (while/scan/cond bodies, pjit calls)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def _subjaxprs(value):
    if hasattr(value, "eqns") or hasattr(value, "jaxpr"):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def callback_eqns(jaxpr) -> List[str]:
    """Names of callback primitives present anywhere in the jaxpr."""
    return [e.primitive.name for e in iter_eqns(jaxpr)
            if e.primitive.name in CALLBACK_PRIMITIVES]


def wide_dtype_vars(jaxpr) -> List[str]:
    """'primitive -> dtype' for every 64-bit-wide value produced."""
    out = []
    for eqn in iter_eqns(jaxpr):
        for var in eqn.outvars:
            dt = getattr(getattr(var, "aval", None), "dtype", None)
            if dt is not None and str(dt) in WIDE_DTYPES:
                out.append(f"{eqn.primitive.name} -> {dt}")
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for var in inner.invars:
        dt = getattr(getattr(var, "aval", None), "dtype", None)
        if dt is not None and str(dt) in WIDE_DTYPES:
            out.append(f"input -> {dt}")
    return out


def has_donation(lowered_text: str) -> bool:
    """Did donation survive into the compiled artifact's aliasing table?"""
    return "tf.aliasing_output" in lowered_text


def while_count(jaxpr) -> int:
    return sum(1 for e in iter_eqns(jaxpr) if e.primitive.name == "while")


def check_traced(name: str, traced, *, expect_donation: bool = False,
                 expect_while: bool = False) -> List[str]:
    """Static checks on one ``jitted.trace(...)`` result."""
    failures = []
    jaxpr = traced.jaxpr
    cbs = callback_eqns(jaxpr)
    if cbs:
        failures.append(f"{name}: host callback primitive(s) in the "
                        f"jaxpr: {sorted(set(cbs))} — hot paths must not "
                        "round-trip to Python per dispatch")
    wide = wide_dtype_vars(jaxpr)
    if wide:
        failures.append(f"{name}: 64-bit values in the jaxpr "
                        f"({sorted(set(wide))[:4]}) — check for Python "
                        "float/int promotion")
    text = traced.lower().as_text()
    if expect_donation and not has_donation(text):
        failures.append(f"{name}: donate_argnums declared but no "
                        "tf.aliasing_output in the lowered module — "
                        "donation was dropped (copy-on-write cache update)")
    if not expect_donation and has_donation(text):
        failures.append(f"{name}: unexpected input-output aliasing — an "
                        "argument is being donated that the registry says "
                        "is read-only")
    if expect_while and while_count(jaxpr) == 0:
        failures.append(f"{name}: expected a fused lax.while_loop in the "
                        "jaxpr but found none — the decode loop has been "
                        "unrolled or hoisted back to the host")
    return failures


def check_recompiles(name: str, jitted, calls: int) -> List[str]:
    """The recompile gate: after running the bucket set (twice), the jit
    cache must hold exactly ``calls`` entries."""
    size = jitted._cache_size()
    if size != calls:
        return [f"{name}: {size} compilations for {calls} bucket calls — "
                + ("a shape/dtype leak is defeating the batcher"
                   if size > calls else "bucket set under-exercised")]
    return []


# ------------------------------------------------------------- contracts

_BATCH_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8)
_DIM = 32


def _cache_cfg(**kw):
    from repro.core.cache import CacheConfig
    base = dict(capacity=64, dim=_DIM, max_query_tokens=8,
                max_response_tokens=16, topk=4)
    base.update(kw)
    return CacheConfig(**base)


def _unit_rows(b: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, _DIM)).astype(np.float32)
    return jnp.asarray(x / np.linalg.norm(x, axis=1, keepdims=True))


def contract_lookup_and_touch(
        buckets: Sequence[int] = _BATCH_BUCKETS) -> List[str]:
    """Fused lookup+route+touch: donated state, no callbacks, one compile
    per batch bucket (the PR 1 single-round-trip invariant)."""
    from repro.core import cache, router
    cfg = _cache_cfg()
    rcfg = router.RouterConfig()
    jitted = jax.jit(
        lambda state, q: cache.lookup_and_touch(state, cfg, rcfg, q),
        donate_argnums=(0,))
    failures = []
    for b in buckets:
        tr = jitted.trace(cache.init_cache(cfg), _unit_rows(b))
        failures += check_traced(f"lookup_and_touch[b={b}]", tr,
                                 expect_donation=True)
    for _ in range(2):          # second sweep must be all cache hits
        for b in buckets:
            out = jitted(cache.init_cache(cfg), _unit_rows(b))
            jax.block_until_ready(out)
    failures += check_recompiles("lookup_and_touch", jitted, len(buckets))
    return failures


def contract_insert_batch(
        buckets: Sequence[int] = _BATCH_BUCKETS) -> List[str]:
    """Miss-batch commit: donated state; the traced ``count`` arg (not the
    batch width) must be the only per-call variation within a bucket."""
    from repro.core import cache
    cfg = _cache_cfg()
    jitted = cache.make_insert_batch(cfg)
    failures = []

    def args(b, count):
        return (cache.init_cache(cfg), _unit_rows(b),
                jnp.zeros((b, cfg.max_query_tokens), jnp.int32),
                jnp.ones((b, cfg.max_query_tokens), jnp.float32),
                jnp.zeros((b, cfg.max_response_tokens), jnp.int32),
                jnp.ones((b, cfg.max_response_tokens), jnp.float32),
                jnp.asarray(count, jnp.int32))

    for b in buckets:
        tr = jitted.trace(*args(b, b))
        failures += check_traced(f"insert_batch[b={b}]", tr,
                                 expect_donation=True)
    for count_off in (0, 1):    # varying count must NOT retrace
        for b in buckets:
            out = jitted(*args(b, max(1, b - count_off)))
            jax.block_until_ready(out)
    failures += check_recompiles("insert_batch", jitted, len(buckets))
    return failures


def contract_ivf_lookup(buckets: Sequence[int] = _BATCH_BUCKETS) -> List[str]:
    """Clustered (IVF) probe: fixed-shape two-stage lookup — the member
    shortlist must never take a data-dependent shape (DESIGN.md §7)."""
    from repro.core import cache
    cfg = _cache_cfg(index="ivf", nclusters=8, nprobe=4)
    state = cache.init_cache(cfg)
    jitted = jax.jit(lambda state, q: cache.lookup(state, cfg, q))
    failures = []
    for b in buckets:
        tr = jitted.trace(state, _unit_rows(b))
        failures += check_traced(f"ivf_lookup[b={b}]", tr)
    for _ in range(2):
        for b in buckets:
            jax.block_until_ready(jitted(state, _unit_rows(b)))
    failures += check_recompiles("ivf_lookup", jitted, len(buckets))
    return failures


def _tiny_generator(mnt: int = 4):
    from repro.models import ModelConfig, build_model
    from repro.serving import GenerateConfig, Generator, SamplerConfig
    vocab = 128
    # xla_flash: the length-invariant attention reduction that qualifies
    # the arch for byte-identical prefix prefill (models/model.py)
    cfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
                      d_ff=64, vocab_size=vocab, max_seq_len=128,
                      dtype="float32", attention_impl="xla_flash",
                      flash_block_q=16, flash_block_k=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))  # seed: ok deterministic contract probe
    gc = GenerateConfig(max_new_tokens=mnt,
                        sampler=SamplerConfig(vocab_size=vocab))
    return Generator(model, params, gc)


def contract_fused_decode(buckets: Sequence[int] = (1, 2)) -> List[str]:
    """Fused decode: ONE while_loop on device, caches threaded through the
    carry, no callbacks, one compile per batch bucket (PR 4)."""
    mnt = 4
    gen = _tiny_generator(mnt)
    failures = []
    for b in buckets:
        batch = {"tokens": jnp.ones((b, 8), jnp.int32)}
        logits, caches = gen._prefill(gen.params, batch, 8 + mnt + 1)
        tr = gen._decode_fused.trace(gen.params, logits, caches,
                                     jax.random.PRNGKey(0), mnt=mnt)  # seed: ok deterministic contract probe
        failures += check_traced(f"decode_fused[b={b}]", tr,
                                 expect_while=True)
    for _ in range(2):
        for b in buckets:
            out = gen.generate({"tokens": jnp.ones((b, 8), jnp.int32)},
                               max_new_tokens=mnt, seed=0)  # seed: ok deterministic contract probe
    failures += check_recompiles("decode_fused", gen._decode_fused,
                                 len(buckets))
    return failures


def contract_prefix_suffix_prefill(
        suffix_buckets: Sequence[int] = (8, 16)) -> List[str]:
    """Prefix-KV reuse: suffix prefill compiles once per suffix length
    bucket over a FIXED shared-prefix KV (PR 5), with the prefix pytree
    read-only (no aliasing)."""
    mnt, b = 4, 2
    gen = _tiny_generator(mnt)
    prefix = gen.build_prefix_cache((5, 6, 7, 8), batch=b)
    failures = []
    for s in suffix_buckets:
        batch = {"tokens": jnp.ones((b, s), jnp.int32)}
        capacity = prefix.length + s + mnt + 1
        tr = gen._prefill_with_prefix.trace(gen.params, batch, capacity,
                                            prefix.caches)
        failures += check_traced(f"prefill_with_prefix[s={s}]", tr)
    for _ in range(2):
        for s in suffix_buckets:
            out = gen.generate({"tokens": jnp.ones((b, s), jnp.int32)},
                               max_new_tokens=mnt, seed=0,  # seed: ok deterministic contract probe
                               prefix_cache=prefix)
    failures += check_recompiles("prefill_with_prefix",
                                 gen._prefill_with_prefix,
                                 len(suffix_buckets))
    return failures


CONTRACTS = (
    ("lookup_and_touch", contract_lookup_and_touch),
    ("insert_batch", contract_insert_batch),
    ("ivf_lookup", contract_ivf_lookup),
    ("fused_decode", contract_fused_decode),
    ("prefix_suffix_prefill", contract_prefix_suffix_prefill),
)


def run_all() -> List[str]:
    failures: List[str] = []
    for _name, fn in CONTRACTS:
        failures += fn()
    return failures


def main(argv=None) -> int:
    failures = run_all()
    for f in failures:
        print(f)
    if failures:
        print(f"FAIL: {len(failures)} contract violation(s)")
        return 1
    print(f"analysis contracts: {len(CONTRACTS)} hot paths clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
