"""Central registry of jitted entry points and jit-hot modules (DESIGN.md §10).

Every ``jax.jit`` call site in ``src/repro`` MUST appear here with its
declared donation and static-argument policy.  The AST lint
(``repro.analysis.lint``) cross-checks this table against the real call
sites: an unregistered jit, a policy drift (donation silently dropped,
static argnames changed), or a stale entry each fails ``make analyze``.
The contract checker (``repro.analysis.contracts``) uses the same table
to know which hot paths to trace against their bucket sets.

Why a registry instead of grepping?  Donation and static-argnum choices
are *load-bearing* serving invariants (PRs 1, 4, 5): dropping
``donate_argnums=(0,)`` from the cache write path doubles peak memory and
adds a copy per serve batch; losing a ``static_argnames`` entry turns a
bounded compile-bucket family into a per-value retrace.  Declaring the
policy next to a prose note makes every future refactor diff the *intent*
alongside the code.

Conventions
-----------
* ``file`` is the path relative to ``src/repro`` (posix separators).
* ``qualname`` is the enclosing scope chain at the call site
  (``Class.method`` / ``outer_fn.inner_fn``); for a decorated function it
  is the decorated function's own qualified name.  Several sites in one
  qualname are declared in SOURCE ORDER.
* ``donate`` / ``static`` declare the expected literal value of
  ``donate_argnums`` / ``static_argnums``+``static_argnames`` at the
  site; ``None`` means the site computes the policy dynamically (the
  note must say why) and the lint only checks the site is named here.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

# --------------------------------------------------------------- hot set
# Modules whose code runs on (or orchestrates) the serve hot path.  The
# hostsync lint rules (HS1xx) apply only inside these: a stray `.item()`,
# `int()` on a device value, or `np.asarray` here is a per-request
# host<->device round-trip that silently defeats the O(1)-syncs-per-batch
# design (DESIGN.md §5).  Entries ending in "/" are directory prefixes.
HOT_MODULES: Tuple[str, ...] = (
    "core/cache.py",
    "core/router.py",
    "core/index.py",
    "core/engine.py",
    "core/distributed.py",
    "serving/generate.py",
    "serving/scheduler.py",
    "serving/paged_kv.py",
    "serving/continuous.py",
    "models/",
    "kernels/",
)


def is_hot(rel: str) -> bool:
    """Is ``rel`` (path relative to src/repro) a jit-hot module?"""
    rel = rel.replace("\\", "/")
    for m in HOT_MODULES:
        if m.endswith("/"):
            if rel.startswith(m):
                return True
        elif rel == m:
            return True
    return False


# ------------------------------------------------------------- jit sites
Argnums = Optional[Tuple[Union[int, str], ...]]


@dataclasses.dataclass(frozen=True)
class JitSite:
    """One declared ``jax.jit`` call site and its compilation policy."""
    file: str            # path relative to src/repro
    qualname: str        # enclosing scope chain ("<module>" for top level)
    donate: Argnums = () # expected donate_argnums; None = dynamic (see note)
    static: Argnums = () # expected static_argnums + static_argnames
    note: str = ""       # why this policy — shown in lint failures


JIT_REGISTRY: Tuple[JitSite, ...] = (
    # ---- core: the serve hot path -----------------------------------
    JitSite("core/cache.py", "make_insert_batch", donate=None,
            note="miss-batch commit; donates the cache state for in-place "
                 "update (DESIGN.md §5) unless the caller opts out "
                 "(contract tests build the no-donate variant on purpose)"),
    JitSite("core/cache.py", "make_second_stage", donate=None,
            note="cascade stage 2 (DESIGN.md §13): reranker shortlist "
                 "scoring + uncertain-row resolution; donates state for "
                 "in-place touch/admission updates unless the caller opts "
                 "out (byte-identity tests keep the pre-state alive)"),
    JitSite("core/engine.py", "TweakLLMEngine.__init__",
            note="embedder encode; params/tokens are read-only"),
    JitSite("core/engine.py", "SharedCacheBank.__init__", donate=(0,),
            note="fused lookup+route+touch on the shared bank; donates "
                 "cache state so hit accounting happens in place "
                 "(DESIGN.md §5/§12)"),
    JitSite("core/baseline.py", "GPTCacheBaseline.__init__",
            note="baseline embedder encode"),
    JitSite("core/baseline.py", "GPTCacheBaseline.__init__",
            note="baseline flat lookup (no touch fusion — GPTCache "
                 "semantics keep lookup read-only)"),
    JitSite("core/baseline.py", "GPTCacheBaseline.__init__",
            note="optional cross-encoder rerank of the shortlist"),
    JitSite("core/index.py", "_spherical_kmeans",
            note="maintenance path: k-means assignment GEMM, host-driven"),
    JitSite("core/index.py", "build_index",
            note="maintenance path: bank-to-centroid similarity GEMM"),
    JitSite("core/distributed.py", "make_distributed_lookup.lookup",
            note="shard_map flat lookup; state rows sharded, queries "
                 "replicated, read-only"),
    JitSite("core/distributed.py", "make_distributed_ivf_lookup.lookup",
            note="shard_map IVF lookup; read-only"),
    JitSite("core/distributed.py", "make_distributed_insert.insert",
            note="single-entry sharded insert (reference path, no "
                 "donation: keeps the differential oracle's inputs alive)"),
    JitSite("core/distributed.py",
            "make_distributed_lookup_and_touch.lookup_touch", donate=(0,),
            note="sharded fused lookup+route+touch: per-shard scan + "
                 "winner merge + replicated-index scatter on the sharded "
                 "recency arrays, one device call per serve batch "
                 "(DESIGN.md §12)"),
    JitSite("core/distributed.py", "make_distributed_insert_batch.insert_batch",
            donate=(0,),
            note="sharded miss-batch commit; donates state like the local "
                 "insert_batch"),
    # ---- serving: prefill + fused decode ----------------------------
    JitSite("serving/generate.py", "Generator.__init__._prefill",
            static=("capacity",),
            note="KV capacity fixes the cache allocation; one compile per "
                 "(batch, prompt, capacity) bucket"),
    JitSite("serving/generate.py", "Generator.__init__._prefill_with_prefix",
            static=("capacity",),
            note="suffix prefill over the shared prefix KV (DESIGN.md §9)"),
    JitSite("serving/generate.py", "Generator.__init__._prefill_prefix",
            note="one-time shared-prefix KV build per (model, batch bucket)"),
    JitSite("serving/generate.py", "Generator.__init__._step",
            note="host-loop decode step — the differential oracle "
                 "(DESIGN.md §8); caches threaded functionally, not donated, "
                 "so the oracle can re-run a step"),
    JitSite("serving/generate.py", "Generator.__init__._decode_fused",
            static=("mnt",),
            note="whole decode loop in one device call; mnt bounds the "
                 "while_loop trip count and the output block shape"),
    JitSite("serving/generate.py", "Generator.__init__._decode_fused_spec",
            static=("mnt", "k"),
            note="draft-verify speculative decode (DESIGN.md §14): verify "
                 "phase + per-row fallback phase in one device call; mnt "
                 "and the verify block width k fix every carried shape; "
                 "greedy-only so no PRNG key is carried"),
    # ---- serving: paged KV pool + persistent decode session ---------
    JitSite("serving/paged_kv.py", "pack_caches", donate=(0,),
            note="dense prefill KV -> pool pages; donates the pool storage "
                 "so page writes alias in place (DESIGN.md §11); pinned "
                 "block-table entries are redirected to the TRASH page"),
    JitSite("serving/paged_kv.py", "write_pinned", donate=(0,),
            note="one-time shared-prefix pin into reserved pages; donates "
                 "pool storage like pack_caches"),
    JitSite("serving/continuous.py", "DecodeSession._build_ops._admit",
            donate=(0,),
            note="splice a prefilled cohort into free slots; donates the "
                 "session state (the pool lives inside it) so the splice "
                 "is a true in-place join (DESIGN.md §11)"),
    JitSite("serving/continuous.py", "DecodeSession._build_ops._chunk",
            donate=(1,), static=("steps",),
            note="up to `steps` decode steps in one device call; steps "
                 "bounds the while_loop and is a small bucket set "
                 "(chunk size), state donated like the fused decode loop"),
    JitSite("serving/continuous.py", "DecodeSession._build_ops._step_once",
            donate=(1,),
            note="single decode step — the host-stepped differential "
                 "oracle for the chunked loop (DESIGN.md §8/§11)"),
    JitSite("serving/continuous.py", "DecodeSession._build_ops._evict",
            donate=(0,),
            note="clear harvested slots: block tables -> TRASH page in "
                 "place so freed pages can be re-issued safely"),
    # ---- kernels: jit'd public wrappers -----------------------------
    JitSite("kernels/cosine_topk/ops.py", "cosine_topk",
            static=("k", "impl", "block_n"),
            note="kernel meta-params select the Pallas/XLA lowering"),
    JitSite("kernels/cosine_topk/ops.py", "cosine_topk_gather",
            static=("k", "impl", "block_m"),
            note="gathered-shortlist variant for the IVF probe"),
    JitSite("kernels/decode_attention/ops.py", "decode_attention",
            static=("block_t", "impl"),
            note="decode attention over the KV cache"),
    JitSite("kernels/decode_attention/ops.py", "decode_attention_block",
            static=("block_t", "impl"),
            note="speculative verify q-block (DESIGN.md §14): K draft "
                 "queries per row in one pass with in-block causal masking"),
    JitSite("kernels/paged_attention/ops.py", "paged_decode_attention",
            static=("impl",),
            note="decode attention gathered through the page block table "
                 "(DESIGN.md §11)"),
    JitSite("kernels/paged_attention/ops.py", "paged_decode_attention_block",
            static=("impl",),
            note="speculative verify q-block over the page pool "
                 "(DESIGN.md §14); per-query causality via slot positions"),
    JitSite("kernels/flash_attention/ops.py", "flash_attention",
            static=("causal", "window", "block_q", "block_k", "impl"),
            note="prefill flash attention; window/causal change the "
                 "lowered kernel"),
    # ---- analyzer self-probes ---------------------------------------
    JitSite("analysis/contracts.py", "contract_lookup_and_touch",
            donate=(0,),
            note="contract probe: mirrors the engine's fused lookup jit "
                 "policy so donation is checked exactly as deployed"),
    JitSite("analysis/contracts.py", "contract_ivf_lookup",
            note="contract probe: read-only IVF lookup"),
    # ---- offline / maintenance / tooling ----------------------------
    JitSite("eval/judge.py", "make_loglik_scorer._score",
            note="eval-only loglik scorer"),
    JitSite("training/embedder_train.py", "train_embedder.step",
            note="contrastive embedder training step (offline)"),
    JitSite("training/reranker_train.py", "train_reranker.step",
            note="cross-encoder reranker training step (offline; feeds "
                 "the cascade's second stage, DESIGN.md §13)"),
    JitSite("launch/train.py", "main",
            note="CLI training step; params/opt threaded functionally"),
    JitSite("launch/dryrun.py", "run_one", donate=None, static=None,
            note="train-step lowering probe; donation gated on --donate "
                 "to measure aliasing impact, shardings vary per arch"),
    JitSite("launch/dryrun.py", "run_one", donate=None, static=None,
            note="prefill lowering probe (no donation: cache is an output)"),
    JitSite("launch/dryrun.py", "run_one", donate=None, static=None,
            note="decode lowering probe; cache donation gated on --donate"),
)


def sites_for(rel: str, qualname: str) -> Tuple[JitSite, ...]:
    """Declared sites for one (file, qualname), in declaration order."""
    rel = rel.replace("\\", "/")
    return tuple(s for s in JIT_REGISTRY
                 if s.file == rel and s.qualname == qualname)


def registered_files() -> Tuple[str, ...]:
    return tuple(sorted({s.file for s in JIT_REGISTRY}))
