"""Hot-path invariant analyzer (DESIGN.md §10).

Three layers, all wired into ``make analyze`` / the ``analysis`` CI job:

* :mod:`repro.analysis.registry` — the declared jit-site and hot-module
  tables the other layers check against.
* :mod:`repro.analysis.lint` — repo-specific AST lint (host syncs, seed
  hygiene, import-time side effects, registry parity).
* :mod:`repro.analysis.contracts` — jaxpr/HLO contract checks: no
  callbacks, no 64-bit widening, real donation, bounded recompiles.
"""
from . import registry  # noqa: F401
