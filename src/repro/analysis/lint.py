"""Repo-specific AST lint over ``src/repro`` (DESIGN.md §10, Layer 1).

Run as ``python -m repro.analysis.lint`` (or ``make analyze``); the same
checks run as a pytest in ``tests/test_analysis_lint.py`` so CI cannot
pass with a dirty tree.

Rules
-----
Host-sync rules — apply only inside jit-hot modules
(``registry.HOT_MODULES``); each flagged call is a potential per-request
host<->device round-trip on the serve path (DESIGN.md §5):

* **HS101** ``.item()`` call.
* **HS102** ``int(x)`` / ``float(x)`` where ``x`` may be a traced/device
  value (literals, ``len()``, ``.shape``/``.ndim``/``.size`` reads, and
  comparisons are exempt — those are static under tracing).
* **HS103** ``np.asarray`` / ``np.array`` / ``jax.device_get`` /
  ``.block_until_ready()`` — explicit sync points; the INTENTIONAL
  per-batch sync is fine but must carry a waiver naming itself.
* **HS104** ``bool(x)`` on a possibly-traced value (the explicit spelling
  of an implicit array bool; the runtime transfer-guard harness catches
  the implicit form).

Seed hygiene — everywhere in ``src/repro`` (the PR 4 bug class: replayed
``PRNGKey(0)`` streams made every serve batch sample identically):

* **SD201** hard-coded key: ``PRNGKey(<literal>)`` / ``jax.random.key(<literal>)``.
* **SD202** literal ``seed=0`` keyword at a call site (API *defaults*
  ``seed: int = 0`` are caller-overridable and stay legal).

Import hygiene:

* **IS301** import-time side effect at module scope (``os.environ``
  mutation, ``jax.config.update``, ``warnings.filterwarnings``,
  ``sys.path`` mutation, ...).  Importing a module for its helpers must
  not rewrite process state (the dryrun.py XLA_FLAGS lesson).

Jit registry — cross-checked against ``registry.JIT_REGISTRY``:

* **JR401** ``jax.jit`` site not in the registry (or an un-analyzable
  bare reference).
* **JR402** site policy (donate/static argnums) != registered policy.
* **JR403** stale registry entry with no matching site.

Waivers
-------
``# hostsync: ok <reason>``, ``# seed: ok <reason>``,
``# import-side-effect: ok <reason>`` on the offending line or the line
above suppress the matching rule family.  A ``# hostsync: ok`` on a
``def`` line waives the whole function — for host-side maintenance paths
(k-means rebuilds, the host-loop decode oracle) that sync by design.
JR rules have no comment waiver: the registry IS the waiver mechanism.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import sys
from typing import Dict, List, Optional, Tuple

from . import registry

WAIVER_TOKENS = {
    "HS": "hostsync: ok",
    "SD": "seed: ok",
    "IS": "import-side-effect: ok",
}

_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
               "jax.device_get"}
_SIDE_EFFECT_CALLS = {
    "os.environ.update", "os.environ.setdefault", "os.environ.pop",
    "os.putenv", "os.unsetenv",
    "jax.config.update", "jax.distributed.initialize",
    "warnings.filterwarnings", "warnings.simplefilter",
    "logging.basicConfig",
    "np.random.seed", "numpy.random.seed", "random.seed",
    "sys.path.insert", "sys.path.append", "sys.path.extend",
    "matplotlib.use", "multiprocessing.set_start_method",
}
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}


@dataclasses.dataclass(frozen=True)
class Violation:
    rel: str
    line: int
    rule: str
    msg: str

    def __str__(self) -> str:
        return f"{self.rel}:{self.line}: {self.rule} {self.msg}"


@dataclasses.dataclass
class JitUse:
    """One ``jax.jit`` usage found in the AST, with its literal kwargs."""
    rel: str
    qualname: str
    line: int
    kwargs: Dict[str, ast.expr]


_NONLITERAL = object()


def _dotted(node: ast.expr) -> Optional[str]:
    """'np.asarray' for Attribute/Name chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _maybe_traced(node: ast.expr) -> bool:
    """Could this expression hold a traced/device value?  (Conservative:
    static-under-jit spellings — literals, len(), .shape reads,
    comparisons — are exempt; everything else is assumed device-tainted.)
    """
    if isinstance(node, ast.Constant):
        return False
    if isinstance(node, (ast.Compare, ast.BoolOp)):
        return False
    if isinstance(node, ast.UnaryOp):
        return _maybe_traced(node.operand)
    if isinstance(node, ast.BinOp):
        return _maybe_traced(node.left) or _maybe_traced(node.right)
    if isinstance(node, ast.Call):
        name = _dotted(node.func)
        if name == "len":
            return False        # len(traced) is a static Python int
        if name in ("min", "max", "round", "abs") and node.args:
            return any(_maybe_traced(a) for a in node.args)
        return True
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        return True
    if isinstance(node, ast.Subscript):
        # x.shape[i] is static under trace; anything else may gather
        if isinstance(node.value, ast.Attribute) and \
                node.value.attr in _STATIC_ATTRS:
            return False
        return True
    if isinstance(node, (ast.Name, ast.IfExp, ast.Starred)):
        return True
    return True


def _is_jax_jit(node: ast.expr, jit_aliases: set) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "jit" and \
            isinstance(node.value, ast.Name) and node.value.id == "jax":
        return True
    return isinstance(node, ast.Name) and node.id in jit_aliases


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str, hot: bool):
        self.rel = rel
        self.hot = hot
        self.violations: List[Violation] = []
        self.jit_uses: List[JitUse] = []
        self.scope: List[str] = []
        self.depth = 0              # function/class nesting (0 = module)
        self.hs_waived = 0          # nested hostsync-waived functions
        self.jit_aliases: set = set()
        self.consumed: set = set()  # id() of jit nodes already recorded

    # ----------------------------------------------------------- helpers
    def _flag(self, node: ast.AST, rule: str, msg: str) -> None:
        if rule.startswith("HS") and self.hs_waived:
            return
        self.violations.append(
            Violation(self.rel, getattr(node, "lineno", 0), rule, msg))

    def _qualname(self) -> str:
        return ".".join(self.scope) if self.scope else "<module>"

    def _record_jit(self, node: ast.AST, qualname: str,
                    kwargs: Dict[str, ast.expr]) -> None:
        self.jit_uses.append(
            JitUse(self.rel, qualname, getattr(node, "lineno", 0), kwargs))

    def _match_jit_call(self, call: ast.Call) -> Optional[Dict[str, ast.expr]]:
        """kwargs if ``call`` is jax.jit(...) or functools.partial(jax.jit, ...)."""
        if _is_jax_jit(call.func, self.jit_aliases):
            self.consumed.add(id(call.func))
            return {k.arg: k.value for k in call.keywords if k.arg}
        fname = _dotted(call.func)
        if fname in ("functools.partial", "partial") and call.args and \
                _is_jax_jit(call.args[0], self.jit_aliases):
            self.consumed.add(id(call.args[0]))
            return {k.arg: k.value for k in call.keywords if k.arg}
        return None

    # ----------------------------------------------------------- imports
    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "jax":
            for alias in node.names:
                if alias.name == "jit":
                    self.jit_aliases.add(alias.asname or "jit")
        self.generic_visit(node)

    # ------------------------------------------------------------ scopes
    def _visit_function(self, node) -> None:
        for dec in node.decorator_list:
            handled = False
            if isinstance(dec, ast.Call):
                kwargs = self._match_jit_call(dec)
                if kwargs is not None:
                    self._record_jit(
                        dec, ".".join(self.scope + [node.name]), kwargs)
                    # still lint the decorator's argument expressions
                    self.generic_visit(dec)
                    handled = True
            elif _is_jax_jit(dec, self.jit_aliases):
                self.consumed.add(id(dec))
                self._record_jit(dec, ".".join(self.scope + [node.name]), {})
                handled = True
            if not handled:
                self.visit(dec)
        for default in list(node.args.defaults) + \
                [d for d in node.args.kw_defaults if d is not None]:
            self.visit(default)
        waived = _line_has_waiver_text(self._lines, node.lineno, "HS")
        self.scope.append(node.name)
        self.depth += 1
        self.hs_waived += int(waived)
        for stmt in node.body:
            self.visit(stmt)
        self.hs_waived -= int(waived)
        self.depth -= 1
        self.scope.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_function(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_function(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        for dec in node.decorator_list:
            self.visit(dec)
        self.scope.append(node.name)
        self.depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.depth -= 1
        self.scope.pop()

    def visit_Lambda(self, node: ast.Lambda) -> None:
        # lambdas stay in the enclosing qualname (jit sites here are
        # registered under the enclosing function)
        self.generic_visit(node)

    # ------------------------------------------------------------- calls
    def visit_Call(self, node: ast.Call) -> None:
        kwargs = self._match_jit_call(node)
        if kwargs is not None:
            self._record_jit(node, self._qualname(), kwargs)
        name = _dotted(node.func)
        if self.hot:
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                self._flag(node, "HS101",
                           ".item() is a device->host sync on the hot path")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "block_until_ready":
                self._flag(node, "HS103",
                           "block_until_ready() stalls the dispatch pipeline")
            elif name in _SYNC_CALLS:
                self._flag(node, "HS103",
                           f"{name}() pulls device data to host — batch it "
                           "into the per-serve-batch sync or waive it")
            elif name in ("int", "float") and len(node.args) == 1 and \
                    not node.keywords and _maybe_traced(node.args[0]):
                self._flag(node, "HS102",
                           f"{name}() on a possibly-traced value forces a "
                           "host sync — use .tolist()/device_get batching")
            elif name == "bool" and len(node.args) == 1 and \
                    _maybe_traced(node.args[0]):
                self._flag(node, "HS104",
                           "bool() on a possibly-traced value forces a "
                           "host sync")
        if name is not None and (name.endswith(".PRNGKey")
                                 or name == "PRNGKey"
                                 or name == "jax.random.key"):
            if node.args and isinstance(node.args[0], ast.Constant):
                self._flag(node, "SD201",
                           f"hard-coded PRNG key {name}"
                           f"({node.args[0].value!r}) — thread a per-call "
                           "seed instead (the PR 4 replayed-stream bug)")
        for kw in node.keywords:
            if kw.arg == "seed" and isinstance(kw.value, ast.Constant) and \
                    kw.value.value == 0:
                # anchor at the kwarg's own line so a waiver comment can
                # sit next to `seed=0` in a multi-line call
                self._flag(kw.value, "SD202",
                           "literal seed=0 at a call site replays one key "
                           "stream — thread a counter or config seed")
        self.generic_visit(node)

    # ----------------------------------------------- module-level effects
    def _check_module_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.Assign, ast.AugAssign)):
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for t in targets:
                if isinstance(t, ast.Subscript) and \
                        _dotted(t.value) in ("os.environ", "environ"):
                    self._flag(stmt, "IS301",
                               "os.environ mutated at import time — move it "
                               "behind main()/a function (importing a module "
                               "for helpers must not rewrite process state)")
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
            name = _dotted(stmt.value.func)
            if name in _SIDE_EFFECT_CALLS:
                self._flag(stmt, "IS301",
                           f"import-time call to {name}() — move it behind "
                           "main()/a function")
        for child in ast.iter_child_nodes(stmt):
            if isinstance(child, ast.stmt):
                self._check_module_stmt(child)

    # -------------------------------------------------------------- run
    def run(self, tree: ast.Module, lines: List[str]) -> None:
        self._lines = lines
        for stmt in tree.body:
            if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.ClassDef)):
                self._check_module_stmt(stmt)
        self.visit(tree)
        # bare jax.jit references that none of the recognized patterns
        # consumed (aliased, stored, passed around) are un-analyzable
        for node in ast.walk(tree):
            if _is_jax_jit(node, self.jit_aliases) and \
                    id(node) not in self.consumed and \
                    isinstance(node, ast.Attribute):
                self._flag(node, "JR401",
                           "bare jax.jit reference — only direct "
                           "jax.jit(...) / functools.partial(jax.jit, ...) "
                           "sites can be registry-checked")


def _line_has_waiver_text(lines: List[str], lineno: int, family: str) -> bool:
    token = WAIVER_TOKENS.get(family)
    if token is None or not lines:
        return False
    idx = lineno - 1
    if 0 <= idx < len(lines) and token in lines[idx]:
        return True
    prev = idx - 1
    if 0 <= prev < len(lines):
        stripped = lines[prev].strip()
        if stripped.startswith("#") and token in stripped:
            return True
    return False


def _apply_waivers(violations: List[Violation],
                   lines: List[str]) -> List[Violation]:
    out = []
    for v in violations:
        if _line_has_waiver_text(lines, v.line, v.rule[:2]):
            continue
        out.append(v)
    return out


# ---------------------------------------------------------------- driver

def lint_source(source: str, rel: str,
                collect_jit: Optional[List[JitUse]] = None) -> List[Violation]:
    """Lint one module's source; ``rel`` is its path relative to src/repro.

    Registry cross-checking is a whole-tree property — use
    :func:`check_registry` over the collected ``JitUse`` list (or
    :func:`lint_tree`, which does both).
    """
    tree = ast.parse(source, filename=rel)
    lines = source.splitlines()
    linter = _Linter(rel, hot=registry.is_hot(rel))
    linter.run(tree, lines)
    if collect_jit is not None:
        collect_jit.extend(linter.jit_uses)
    return _apply_waivers(linter.violations, lines)


def _literal_argnums(node: Optional[ast.expr]):
    """Literal tuple value of a donate/static kwarg, or _NONLITERAL."""
    if node is None:
        return ()
    if isinstance(node, ast.Constant):
        return (node.value,) if node.value is not None else _NONLITERAL
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if not isinstance(e, ast.Constant):
                return _NONLITERAL
            vals.append(e.value)
        return tuple(vals)
    return _NONLITERAL


def check_registry(uses: List[JitUse],
                   table: Tuple[registry.JitSite, ...] = registry.JIT_REGISTRY,
                   files_scanned: Optional[List[str]] = None
                   ) -> List[Violation]:
    """Cross-check found jit sites against the declared registry."""
    violations: List[Violation] = []
    by_key: Dict[Tuple[str, str], List[JitUse]] = {}
    for u in uses:
        by_key.setdefault((u.rel, u.qualname), []).append(u)
    declared: Dict[Tuple[str, str], List[registry.JitSite]] = {}
    for s in table:
        declared.setdefault((s.file, s.qualname), []).append(s)

    for key, found in sorted(by_key.items()):
        decl = declared.pop(key, [])
        for i, use in enumerate(found):
            if i >= len(decl):
                violations.append(Violation(
                    use.rel, use.line, "JR401",
                    f"jax.jit site #{i + 1} in `{use.qualname}` is not in "
                    "analysis/registry.py — declare its donation/static "
                    "policy there"))
                continue
            site = decl[i]
            actual_donate = _literal_argnums(
                use.kwargs.get("donate_argnums",
                               use.kwargs.get("donate_argnames")))
            if site.donate is not None:
                if actual_donate is _NONLITERAL:
                    violations.append(Violation(
                        use.rel, use.line, "JR402",
                        f"`{use.qualname}` computes donate_argnums "
                        "dynamically but the registry declares "
                        f"{site.donate!r} — register donate=None with a "
                        "note"))
                elif tuple(actual_donate) != tuple(site.donate):
                    violations.append(Violation(
                        use.rel, use.line, "JR402",
                        f"`{use.qualname}` donate_argnums="
                        f"{tuple(actual_donate)!r} but the registry "
                        f"declares {tuple(site.donate)!r}"
                        + (f" ({site.note})" if site.note else "")))
            nums = _literal_argnums(use.kwargs.get("static_argnums"))
            names = _literal_argnums(use.kwargs.get("static_argnames"))
            if site.static is not None:
                if nums is _NONLITERAL or names is _NONLITERAL:
                    violations.append(Violation(
                        use.rel, use.line, "JR402",
                        f"`{use.qualname}` computes static argnums "
                        "dynamically but the registry declares "
                        f"{site.static!r} — register static=None with a "
                        "note"))
                else:
                    actual_static = tuple(nums) + tuple(names)
                    if actual_static != tuple(site.static):
                        violations.append(Violation(
                            use.rel, use.line, "JR402",
                            f"`{use.qualname}` static argnums/argnames="
                            f"{actual_static!r} but the registry declares "
                            f"{tuple(site.static)!r}"))
        if len(decl) > len(found):
            for site in decl[len(found):]:
                violations.append(Violation(
                    site.file, 0, "JR403",
                    f"stale registry entry for `{site.qualname}` — "
                    "declared but no matching jax.jit site found"))
    for (rel, qualname), sites in sorted(declared.items()):
        if files_scanned is not None and rel not in files_scanned:
            violations.append(Violation(
                rel, 0, "JR403",
                f"registry names `{qualname}` in a file the lint never "
                "scanned — moved or deleted?"))
            continue
        for _ in sites:
            violations.append(Violation(
                rel, 0, "JR403",
                f"stale registry entry for `{qualname}` — declared but no "
                "matching jax.jit site found"))
    return violations


def find_root() -> str:
    """The src/repro package directory this module lives in."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_tree(root: Optional[str] = None) -> List[Violation]:
    """Lint every module under ``root`` (default: this src/repro tree)."""
    root = root or find_root()
    violations: List[Violation] = []
    uses: List[JitUse] = []
    files: List[str] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            files.append(rel)
            with open(path, encoding="utf-8") as f:
                source = f.read()
            violations.extend(lint_source(source, rel, collect_jit=uses))
    violations.extend(check_registry(uses, files_scanned=files))
    return violations


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="repo-specific hot-path lint (DESIGN.md §10)")
    ap.add_argument("--root", default=None,
                    help="package root to lint (default: the src/repro "
                         "tree this module lives in)")
    args = ap.parse_args(argv)
    violations = lint_tree(args.root)
    for v in violations:
        print(v)
    if violations:
        print(f"FAIL: {len(violations)} lint violation(s)")
        return 1
    print("analysis lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
