"""Checkpointing: pytree <-> directory of .npz + msgpack tree structure.

Offline-friendly (no orbax/tensorstore): leaves go into a single compressed
.npz keyed by flattened path; the treedef and metadata (step, config) go
into a msgpack sidecar.  Atomic via tmp-dir rename.
"""
from __future__ import annotations

import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    metadata: Optional[dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    np.savez_compressed(os.path.join(tmp, "arrays.npz"),
                        **{k: v.astype(np.float32) if v.dtype == jnp.bfloat16
                           else v for k, v in flat.items()})
    meta = {"step": step, "dtypes": dtypes, "metadata": metadata or {}}
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_checkpoint(ckpt_dir: str, step: int, like: Any) -> Tuple[Any, dict]:
    """Restores into the structure of ``like`` (shapes/dtypes from template)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    flat_like = _flatten(like)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = list(flat_like.keys())
    assert len(keys) == len(leaves)
    restored = []
    for k, _leaf in zip(keys, leaves):
        arr = data[k]
        tgt = jnp.dtype(meta["dtypes"][k])
        restored.append(jnp.asarray(arr, dtype=tgt))
    return jax.tree_util.tree_unflatten(treedef, restored), meta


def latest_checkpoint(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None
